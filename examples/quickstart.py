"""Quickstart: the TEMPI datatype engine in five minutes.

Demonstrates the paper's pipeline end-to-end on one 3D object:
  1. describe the same non-contiguous object three different ways
  2. commit -> identical canonical StridedBlock (Fig. 2)
  3. MPI_Pack / MPI_Unpack with the Pallas kernels vs the baseline
  4. the §5 performance model picking a strategy per datatype

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    BYTE,
    commit,
    make_cuboid_hvector,
    make_cuboid_subarray,
    make_cuboid_vector_of_hvector,
    strided_block_of,
)
from repro.comm import Communicator
from repro.kernels import pack, unpack


def main():
    alloc, ext = (256, 64, 32), (100, 13, 7)

    print("=== 1+2. equivalent datatypes -> one canonical form (Fig. 2) ===")
    dts = {
        "subarray(3D)": make_cuboid_subarray(alloc, ext),
        "hvec(hvec(vector))": make_cuboid_hvector(alloc, ext),
        "vector(subarray(2D))": make_cuboid_vector_of_hvector(alloc, ext),
    }
    for name, dt in dts.items():
        print(f"  {name:22s} -> {strided_block_of(dt)}")

    ct = commit(dts["subarray(3D)"])
    print(f"  kernel={ct.kernel.value}  W={ct.word_bytes}B  "
          f"size={ct.size}B  extent={ct.extent}B")

    print("\n=== 3. MPI_Pack / MPI_Unpack ===")
    rng = np.random.default_rng(0)
    buf = jnp.asarray(rng.integers(0, 255, ct.extent + 64, dtype=np.uint8))
    packed = pack(buf, ct)                      # TEMPI kernels
    print(f"  packed {packed.shape[0]} bytes from a {buf.shape[0]}-byte buffer")
    restored = unpack(jnp.zeros_like(buf), packed, ct)
    from repro.comm.api import REF
    ref = pack(buf, ct, strategy=REF)
    assert (np.asarray(packed) == np.asarray(ref)).all()
    print("  kernel output == gather oracle: OK")

    print("\n=== 4. performance-model strategy selection (paper §5) ===")
    comm = Communicator()
    from repro.core import Subarray, Vector
    cases = {
        "large, tiny blocks": Vector(4096, 16, 512, BYTE),
        "small, dense": Subarray((64, 4), (60, 4), (0, 0), BYTE),
        "contiguous": Subarray((4096,), (4096,), (0,), BYTE),
    }
    print(f"  registered strategies: {', '.join(comm.strategies.names())}")
    for name, dt in cases.items():
        c = comm.commit(dt)
        est = comm.model.select(c, registry=comm.strategies)
        print(f"  {name:20s} -> {est.strategy:9s} "
              f"(pack {est.t_pack*1e6:6.1f}us + link {est.t_link*1e6:6.1f}us "
              f"+ unpack {est.t_unpack*1e6:6.1f}us)")
    print(f"  model cache: {comm.model.hits}/{comm.model.lookups} hits "
          "(repeat selections are dictionary lookups, paper §6.3)")


if __name__ == "__main__":
    main()
