"""Elastic restart demo (scale deliverable): train -> checkpoint ->
"lose" devices -> plan a smaller mesh -> restore the SAME checkpoint
onto the new mesh -> continue training, loss curve unbroken.

Run:  python examples/elastic_restart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import shutil

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import synthetic_batch
from repro.distributed.sharding import DEFAULT_RULES, tree_partition_specs, use_rules
from repro.launch.mesh import make_test_mesh
from repro.models.model import build_model
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.elastic import plan_remesh
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

CKPT = "/tmp/repro_elastic_demo"

CFG = ModelConfig(
    name="elastic-demo", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=503, remat=False,
)
SHAPE = ShapeConfig("train", 64, 8, "train")


def shardings_for(mesh, tree):
    specs = tree_partition_specs(tree, DEFAULT_RULES, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def run_steps(mesh, state, start, steps):
    model = build_model(CFG)
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100)
    step_fn = make_train_step(model, opt_cfg)
    losses = []
    with use_rules(mesh, DEFAULT_RULES):
        jit_step = jax.jit(step_fn)
        params, opt = state["params"], state["opt"]
        for s in range(start, start + steps):
            batch = synthetic_batch(CFG, SHAPE, s)
            params, opt, m = jit_step(params, opt, batch)
            losses.append(float(m["loss"]))
    return {"params": params, "opt": opt}, losses


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    model = build_model(CFG)
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=100)

    # phase 1: 8 devices, mesh (4 data x 2 model)
    mesh8 = make_test_mesh(data=4, model=2)
    with use_rules(mesh8, DEFAULT_RULES):
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": init_opt_state(params, opt_cfg)}
    state, l1 = run_steps(mesh8, state, 0, 6)
    save_checkpoint(CKPT, 6, state)
    print(f"phase 1 (4x2 mesh, 8 devices): loss {l1[0]:.3f} -> {l1[-1]:.3f}; "
          f"checkpointed at step 6")

    # phase 2: "lose" half the devices; plan + restore on a 2x2 mesh
    plan = plan_remesh(survivors=4, model_parallel=2, global_batch=8)
    print(f"elastic plan after failure: mesh {plan.shape} axes {plan.axes} "
          f"global_batch {plan.global_batch}")
    mesh4 = make_test_mesh(data=plan.shape[0], model=plan.shape[1])
    with use_rules(mesh4, DEFAULT_RULES):
        shard_tree = {
            "params": shardings_for(mesh4, state["params"]),
            "opt": {
                "mu": shardings_for(mesh4, state["opt"]["mu"]),
                "nu": shardings_for(mesh4, state["opt"]["nu"]),
                "step": NamedSharding(mesh4, P()),
            },
        }
        step0, restored = restore_checkpoint(CKPT, shardings=shard_tree)
    print(f"restored step {step0} onto {mesh4.devices.shape} mesh "
          f"(different sharding, same values)")

    state2, l2 = run_steps(mesh4, restored, step0, 6)
    print(f"phase 2 (2x2 mesh, 4 devices): loss {l2[0]:.3f} -> {l2[-1]:.3f}")
    assert l2[0] < l1[0], "restored run must continue from trained state"
    print("elastic restart OK: loss curve continues across the remesh")


if __name__ == "__main__":
    main()
