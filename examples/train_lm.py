"""End-to-end LM training example (deliverable b): trains the ~100M
`repro-100m` dense model with the full framework stack — sharded
params, AdamW, checkpointing, straggler monitor, synthetic data.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(a few hundred steps reproduce a clean loss curve; default kept short
so the example finishes quickly on CPU)
"""

import argparse
import sys

from repro.launch.train import REPRO_100M, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    out = train(
        REPRO_100M, args.steps, args.seq_len, args.global_batch, args.ckpt_dir
    )
    losses = out["losses"]
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
