"""3D stencil with datatype-described halo exchange (paper §6.4).

Reproduces the paper's case study on an emulated 8-device mesh:
a 26-point stencil over a periodic domain, radius-2 halos, each of the
26 halo regions described by an MPI-style subarray datatype, packed by
the TEMPI engine and exchanged through the Communicator's fused
neighborhood alltoallv (ONE collective per exchange — the paper's
MPI_Alltoallv transport).

``--overlap`` switches the iteration to the request-based pipeline
(`overlapped_stencil_iteration`): the fused collective is issued first,
the deep-interior stencil update — which reads no halo cells — runs
while the wire is in flight, and only the rim waits for the halos.

Run:  python examples/stencil3d.py [--mode tempi|baseline] [--iters 5]
                                   [--overlap]
"""

# the dry-run pattern: device count must be fixed before jax init
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm import Communicator, MODES, policy_for_mode
from repro.halo import (
    HaloSpec,
    halo_exchange,
    make_halo_plan,
    overlapped_stencil_iteration,
    stencil_iterations,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="tempi", choices=list(MODES))
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--interior", type=int, default=24)
    ap.add_argument("--overlap", action="store_true",
                    help="overlap the exchange with interior compute")
    args = ap.parse_args()

    grid = (2, 2, 2)
    n = args.interior
    spec = HaloSpec(grid=grid, interior=(n, n, n), radius=2)
    R = spec.nranks
    az, ay, ax = spec.alloc
    assert len(jax.devices()) >= R, "need 8 devices (XLA_FLAGS sets them)"

    comm = Communicator(axis_name="ranks", policy=policy_for_mode(args.mode))
    mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
    plan = make_halo_plan(spec, comm)  # types + strategies + wire layout, once

    def iteration(local):
        if args.overlap:
            return overlapped_stencil_iteration(
                local, spec, comm, "ranks", steps=2, plan=plan
            )
        local = halo_exchange(local, spec, comm, "ranks", plan=plan)
        return stencil_iterations(local, spec, steps=2)

    step = jax.jit(
        shard_map(
            iteration, mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"),
            check_vma=False,
        )
    )

    rng = np.random.default_rng(0)
    state = jnp.asarray(
        rng.normal(size=(R * az, ay, ax)).astype(np.float32)
    )

    state = step(state)  # compile
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _ in range(args.iters):
        state = step(state)
    jax.block_until_ready(state)
    dt = (time.perf_counter() - t0) / args.iters

    stats = comm.stats()
    print(f"mode={args.mode} overlap={args.overlap} ranks={R} "
          f"interior={spec.interior} radius={spec.radius}")
    print(f"committed datatypes: {stats['committed_types']} (52 send/recv regions)")
    print(f"wire schedule: {plan.wire.schedule} "
          f"({plan.wire.wire_ops} collectives, "
          f"{plan.wire_bytes} exact bytes, "
          f"padding {plan.wire.padding_bytes})")
    print(f"time per iteration (exchange + 2 stencil steps): {dt*1e3:.2f} ms")
    print(f"checksum: {float(jnp.sum(state)):.6e}")


if __name__ == "__main__":
    main()
