"""3D stencil with a deep-halo HaloProgram (paper §6.4, extended).

Reproduces the paper's case study on an emulated 8-device mesh — a
26-point stencil over a periodic domain, each halo region described by
an MPI-style subarray datatype, packed by the TEMPI engine and exchanged
through the Communicator's fused neighborhood alltoallv — and runs it as
a communication-avoiding ``HaloProgram``: one exchange at halo depth
``s * r`` amortized over ``s`` local stencil applications on a shrinking
valid region.

``--halo-steps N`` fixes the fusion depth (``2`` keeps the paper's
radius-2 / two-applications-per-exchange setup; ``1`` is the
step-per-exchange reference, bit-exact on the interior against any other
depth).  ``--halo-steps auto`` lets ``PerfModel.price_program`` pick the
depth from the measured wire/copy tables; with ``--decisions FILE`` the
choice is recorded there and reruns pin it.

``--cycle predictor-corrector`` fuses a heterogeneous two-op cycle —
a far-reaching predictor (radii (2,1,1)) then a local corrector — into
the same single exchange per iteration: the halo depth becomes
``steps * cycle_radii`` (the per-op radii summed) and each application
shrinks the valid region by its own op's radii.

``--overlap`` switches the iteration to the request-based pipeline:
the fused collective is issued first and the steps-deep interior chain
— which reads no halo cells — runs while the wire is in flight.

Run:  python examples/stencil3d.py [--mode tempi|baseline] [--iters 5]
          [--halo-steps auto|N] [--decisions FILE] [--overlap]
"""

# the dry-run pattern: device count must be fixed before jax init
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.comm import Communicator, MODES, policy_for_mode
from repro.halo import (
    STENCIL26,
    build_halo_program,
    make_program_step,
    parse_halo_steps,
)
from repro.launch.smoother import smoother_cycle
from repro.measure import DecisionCache

#: the demo cycles: the paper's single op, or the same
#: predictor/corrector pair the in-launch smoother workload fuses
CYCLES = {
    "single": (STENCIL26,),
    "predictor-corrector": smoother_cycle("predictor-corrector"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="tempi", choices=list(MODES))
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--interior", type=int, default=24)
    ap.add_argument("--halo-steps", default="2", metavar="auto|N",
                    help="cycle repeats fused per exchange; 'auto' prices "
                         "the depth with PerfModel.price_program")
    ap.add_argument("--cycle", default="single", choices=list(CYCLES),
                    help="op cycle fused per repeat (predictor-corrector "
                         "= a (2,1,1) predictor then a 26-point corrector "
                         "on one exchange)")
    ap.add_argument("--decisions", default=None, metavar="FILE",
                    help="decision-cache file: records the auto depth "
                         "choice (and every strategy selection); reruns "
                         "pin it")
    ap.add_argument("--overlap", action="store_true",
                    help="hide the exchange behind the interior chain")
    args = ap.parse_args()

    grid = (2, 2, 2)
    n = args.interior
    steps = parse_halo_steps(args.halo_steps)

    decisions = DecisionCache.load(args.decisions) if args.decisions else None
    comm = Communicator(axis_name="ranks", policy=policy_for_mode(args.mode),
                        decisions=decisions)
    program = build_halo_program(grid, (n, n, n), comm, steps=steps,
                                 ops=CYCLES[args.cycle])
    spec = program.spec
    R = spec.nranks
    az, ay, ax = spec.alloc
    assert len(jax.devices()) >= R, "need 8 devices (XLA_FLAGS sets them)"

    mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
    step = make_program_step(program, comm, mesh, "ranks",
                             overlap=args.overlap)

    # seed the INTERIORS only (depth-independent: the same physical field
    # regardless of --halo-steps; shells are filled by the first exchange)
    rng = np.random.default_rng(0)
    nz, ny, nx = spec.interior
    rz, ry, rx = spec.radii
    state_np = np.zeros((R, az, ay, ax), np.float32)
    state_np[:, rz:rz + nz, ry:ry + ny, rx:rx + nx] = rng.normal(
        size=(R, nz, ny, nx)
    ).astype(np.float32)
    state = jnp.asarray(state_np.reshape(R * az, ay, ax))

    jax.block_until_ready(step(state))  # compile (state not advanced)
    t0 = time.perf_counter()
    for _ in range(args.iters):
        state = step(state)
    jax.block_until_ready(state)
    dt = (time.perf_counter() - t0) / args.iters

    stats = comm.stats()
    est = program.estimate
    print(f"mode={args.mode} overlap={args.overlap} ranks={R} "
          f"interior={spec.interior} halo-radius={spec.radii}")
    print(f"program: cycle={args.cycle} ({program.cycle_len} op"
          f"{'s' if program.cycle_len > 1 else ''}) steps={program.steps} "
          f"({'pinned' if program.pinned else args.halo_steps}), "
          f"exchanges/step={program.exchanges_per_step:.3f}, "
          f"exchanges/cycle={program.exchanges_per_cycle:.3f}, "
          f"predicted per-step {est.per_step * 1e6:.2f} us "
          f"(exchange {est.t_exchange * 1e6:.2f} us, "
          f"redundant {est.t_redundant * 1e6:.2f} us)")
    print(f"committed datatypes: {stats['committed_types']} (52 send/recv regions)")
    print(f"wire schedule: {program.plan.wire.schedule} "
          f"({program.plan.wire.wire_ops} collectives per exchange, "
          f"{program.plan.wire_bytes} exact bytes, "
          f"padding {program.plan.wire.padding_bytes})")
    print(f"time per iteration (1 exchange + {program.applications} stencil "
          f"applications): {dt*1e3:.2f} ms")
    # interior checksum: comparable across fusion depths (same physical
    # state whenever iters * steps match — the halo shells and the alloc
    # itself are depth-dependent, the interior is bit-exact)
    interior = np.asarray(state).reshape(R, az, ay, ax)[
        :, rz:rz + nz, ry:ry + ny, rx:rx + nx
    ]
    print(f"stencil applications: {args.iters * program.applications}")
    print(f"interior checksum: {float(interior.sum()):.6e}")
    if decisions is not None:
        path = decisions.save(args.decisions)
        print(f"decisions ({len(decisions)} rows, "
              f"{decisions.pinned_hits} pinned hits) -> {path}")


if __name__ == "__main__":
    main()
