"""The paper's technique applied to MoE expert dispatch (DESIGN.md §4.3).

Tokens routed to experts form *strided block* patterns of the grouped
token buffer — exactly TEMPI's domain.  This example runs an
expert-parallel all_to_all dispatch on an 8-device mesh where each
expert's token run is described by a derived datatype, packed by the
engine, shipped with one collective, and unpacked — vs the baseline
per-run copies.

Run:  PYTHONPATH=src python examples/moe_dispatch.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm import BaselinePolicy, Communicator
from repro.core import FLOAT, Subarray


def main():
    E = 8              # experts == devices
    cap = 64           # expert capacity per rank
    D = 128            # features (fp32)
    ndev = E
    assert len(jax.devices()) >= ndev
    mesh = Mesh(np.array(jax.devices()[:ndev]), ("expert",))

    # each rank holds a TOKEN-MAJOR (cap, E, D) dispatch buffer: slot c
    # of expert e lives at [c, e, :].  Expert e's payload is therefore a
    # *strided* block (cap runs of D floats at stride E*D) — the
    # canonical TEMPI case, vs. the expert-major layout where rows are
    # contiguous and packing is trivial.
    results = {}
    comms = {
        "baseline": Communicator(axis_name="expert", policy=BaselinePolicy()),
        "tempi": Communicator(axis_name="expert"),
    }
    for mode, comm in comms.items():
        # datatype for "the capacity block destined to expert e":
        # subarray of the (E, cap, D) fp32 buffer selecting row e
        cts = []
        for e in range(E):
            dt = Subarray(
                sizes=(D, E, cap),      # innermost-first: D, then E, then cap
                subsizes=(D, 1, cap),
                starts=(0, e, 0),
                oldtype=FLOAT,
            )
            cts.append(comm.commit(dt))
        strategies = {comm.select(c, wire=False).name for c in cts}

        def dispatch(buf):
            # pack every expert's block, all_to_all, receive (E, seg)
            return comm.all_to_all_packed(buf, cts)

        fn = jax.jit(
            shard_map(
                dispatch, mesh=mesh,
                in_specs=P("expert"), out_specs=P("expert"),
                check_vma=False,
            )
        )
        rng = np.random.default_rng(0)
        buf = jnp.asarray(
            rng.normal(size=(ndev * cap, E, D)).astype(np.float32)
        )
        out = fn(buf)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            out = fn(buf)
        jax.block_until_ready(out)
        dt_s = (time.perf_counter() - t0) / 3
        results[mode] = np.asarray(out)
        print(f"mode={mode:9s} committed={len(cts)} datatypes "
              f"strategies={sorted(strategies)} "
              f"dispatch time={dt_s*1e3:.1f}ms")

    np.testing.assert_array_equal(results["baseline"], results["tempi"])
    print("baseline == tempi dispatch bytes: OK")


if __name__ == "__main__":
    main()
