"""Shared benchmark utilities.

All benchmarks print ``name,us_per_call,derived`` CSV rows (spec) and
run on the CPU container: Pallas kernels execute in interpret mode, so
absolute times are *proxies* — the quantities that transfer to TPU are
the relative orderings, the canonicalization/caching behavior (pure
host code), and the modeled values; every table notes which is which.
"""

from __future__ import annotations

import statistics
import time
from typing import Callable, List

import jax

__all__ = ["trimean", "time_host_us", "time_jax_us", "emit"]


def trimean(xs: List[float]) -> float:
    """Tukey trimean, as the paper reports for Fig. 6."""
    xs = sorted(xs)
    q1 = xs[len(xs) // 4]
    q2 = xs[len(xs) // 2]
    q3 = xs[(3 * len(xs)) // 4]
    return (q1 + 2 * q2 + q3) / 4.0


def time_host_us(fn: Callable, iters: int = 1000, repeats: int = 7) -> float:
    """Trimean of per-call host time in us (for pure-python paths:
    create/commit/model-query)."""
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        samples.append((time.perf_counter() - t0) / iters * 1e6)
    return trimean(samples)


def time_jax_us(fn: Callable, *args, iters: int = 3, repeats: int = 5) -> float:
    """Trimean of per-call device time in us (jitted fns; first call
    compiles)."""
    out = fn(*args)
    jax.block_until_ready(out)
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / iters * 1e6)
    return trimean(samples)


def emit(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.2f},{derived}")
