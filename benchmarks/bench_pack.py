"""Fig. 7 + 8: MPI_Pack bandwidth / latency over 2D objects.

Sweeps vector/subarray descriptions of 2D objects at 512 B pitch over
contiguous block sizes (the paper's x-axis) and object counts, for the
TEMPI kernel strategies vs the per-block-copy baseline.  Also reproduces
the Fig. 8 "fragility" table: vec x1 / sub x1 / vec x2 must be equally
fast in TEMPI (MVAPICH's specialized vector kernel is not).

CPU-interpret timings — relative orderings transfer; the modeled TPU
pack time from the §5 performance model is emitted alongside.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_jax_us
from repro.comm.perfmodel import PerfModel
from repro.core import BYTE, Subarray, TypeRegistry, Vector
from repro.kernels import pack

PITCH = 512
REG = TypeRegistry()
MODEL = PerfModel()


def bench_one(name: str, dt, strategy: str, incount: int = 1):
    ct = REG.commit(dt)
    buf = jnp.zeros((ct.extent * incount + 64,), jnp.uint8)
    fn = jax.jit(
        lambda b: pack(b, ct, incount=incount, strategy=strategy)
    )
    us = time_jax_us(fn, buf)
    total = ct.size * incount
    bw = total / (us * 1e-6) / 2**20  # MiB/s (cpu-interpret proxy)
    modeled = MODEL.t_pack(ct, incount, strategy if strategy != "auto" else
                           MODEL.select(ct, incount).strategy) * 1e6
    emit(f"fig7/{name}/{strategy}", us,
         f"MiB/s={bw:.1f};modeled_tpu_us={modeled:.2f}")


def run() -> None:
    # Fig. 7 sweep: object size x contiguous block size at 512B pitch
    for total_kib in (1, 16, 64):
        for blk in (8, 64, 256):
            n = total_kib * 1024 // blk
            dt = Vector(n, blk, PITCH, BYTE)
            for strat in ("rows", "dma", "xla"):
                if strat == "xla" and n > 512:
                    continue  # baseline HLO blowup; the paper's point
                bench_one(f"vec/{total_kib}KiB/blk{blk}", dt, strat)

    # Fig. 8 fragility: equivalent descriptions + multiple objects
    blk = 128
    n = 8  # 1 KiB objects
    vec1 = Vector(n, blk, PITCH, BYTE)
    sub1 = Subarray((PITCH, n), (blk, n), (0, 0), BYTE)
    for name, dt, inc in (
        ("vec/1KiB/x1", vec1, 1),
        ("sub/1KiB/x1", sub1, 1),
        ("vec/1KiB/x2", vec1, 2),
    ):
        for strat in ("auto",):
            ct = REG.commit(dt)
            buf = jnp.zeros((ct.extent * inc + 64,), jnp.uint8)
            fn = jax.jit(lambda b, ct=ct, inc=inc: pack(b, ct, incount=inc))
            us = time_jax_us(fn, buf)
            emit(f"fig8/{name}", us,
                 f"canonical={ct.block.counts}x{ct.block.strides}")


if __name__ == "__main__":
    run()
