"""Fig. 12: 3D stencil halo exchange, baseline vs TEMPI.

Runs the 26-neighbor exchange on an 8-rank emulated mesh in a
subprocess (device count must be set before jax init), reporting
per-iteration time for both interposer modes and the pack-only
latency (the paper's phase split), plus the exchange's wire-byte
accounting (exact ragged payload vs what the padded layout would move).

``--assert-ragged`` runs the wire-bytes regression gate instead (CI):
trace the fused halo step in interpret mode and FAIL (exit 1) if the
bytes its collectives move exceed the ragged optimum — the sum of
per-peer packed extents.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = r"""
import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator, policy_for_mode
from repro.halo import HaloSpec, halo_exchange, make_halo_plan

spec = HaloSpec(grid=(2, 2, 2), interior=(16, 16, 16), radius=2)
R = spec.nranks
az, ay, ax = spec.alloc
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
state0 = jnp.asarray(
    np.random.default_rng(0).normal(size=(R * az, ay, ax)).astype(np.float32))

for mode in ("baseline", "tempi"):
    comm = Communicator(axis_name="ranks", policy=policy_for_mode(mode))
    plan = make_halo_plan(spec, comm)
    fn = jax.jit(shard_map(
        lambda x: halo_exchange(x, spec, comm, "ranks", plan=plan),
        mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"),
        check_vma=False))
    print(f"fig12/wire-bytes/{mode},{plan.wire_bytes},"
          f"schedule={plan.wire.schedule};ops={plan.wire.wire_ops};"
          f"padded_layout_would_move={plan.wire.nranks * plan.wire.seg_bytes}")
    out = fn(state0); jax.block_until_ready(out)
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        out = fn(out)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig12/exchange/{mode},{us:.2f},"
          f"ranks=8;interior=16^3;r=2;wire_ops={comm.stats()['wire_ops']}")

    # pack-only phase (one face datatype, 26x per iteration in exchange)
    from repro.halo.exchange import _region_type
    ct = comm.commit(_region_type(spec, (0, 0, 1), "send"))
    local = jnp.zeros((az, ay, ax), jnp.float32)
    pfn = jax.jit(lambda b: comm.pack(b, ct))
    o = pfn(local); jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(10):
        o = pfn(local)
    jax.block_until_ready(o)
    us = (time.perf_counter() - t0) / 10 * 1e6
    print(f"fig12/pack-face/{mode},{us:.2f},single-face")
"""


#: the CI regression gate: fused-path bytes must equal the ragged
#: optimum — grows a diff the moment any padding creeps back in
_ASSERT_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import HaloSpec, halo_exchange, make_halo_plan

spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=2)
R = spec.nranks
az, ay, ax = spec.alloc
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
# forced pack strategy: the ragged optimum is exactly sum(ct.size)
comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
plan = make_halo_plan(spec, comm)
fn = jax.jit(shard_map(
    lambda x: halo_exchange(x, spec, comm, "ranks", plan=plan),
    mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"), check_vma=False))
x = jnp.zeros((R * az, ay, ax), jnp.float32)

ragged_optimum = sum(ct.packed_extent() for ct in plan.send_cts)
counts = collective_payload_bytes(fn, x)
print(f"wire-bytes-check: traced={counts['total']} "
      f"plan={plan.wire_bytes} optimum={ragged_optimum} "
      f"schedule={plan.wire.schedule} ops={counts['ops']}")
assert plan.wire_bytes == ragged_optimum, (plan.wire_bytes, ragged_optimum)
assert counts["total"] <= ragged_optimum, (
    f"fused path moves {counts['total']} B > ragged optimum "
    f"{ragged_optimum} B — padding has crept back into the wire layout")
# the exchange must still be correct, in interpret mode, end to end
out = np.asarray(fn(jnp.asarray(
    np.random.default_rng(0).normal(size=(R * az, ay, ax)).astype(np.float32))))
assert np.isfinite(out).all()
print("WIRE_BYTES_OK")
"""


def run(assert_ragged: bool = False) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    code = _ASSERT_CODE if assert_ragged else _CODE
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:
        print(f"fig12/FAILED,0,{proc.stderr.splitlines()[-1] if proc.stderr else 'unknown'}")
        if assert_ragged:
            sys.stderr.write(proc.stderr)
            sys.exit(1)
        return
    sys.stdout.write(proc.stdout)
    if assert_ragged and "WIRE_BYTES_OK" not in proc.stdout:
        sys.exit(1)


if __name__ == "__main__":
    run(assert_ragged="--assert-ragged" in sys.argv[1:])
