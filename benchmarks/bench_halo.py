"""Fig. 12: 3D stencil halo exchange, baseline vs TEMPI.

Runs the 26-neighbor exchange on an 8-rank emulated mesh in a
subprocess (device count must be set before jax init), reporting
per-iteration time for both interposer modes and the pack-only
latency (the paper's phase split).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = r"""
import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator, policy_for_mode
from repro.halo import HaloSpec, halo_exchange, make_halo_types

spec = HaloSpec(grid=(2, 2, 2), interior=(16, 16, 16), radius=2)
R = spec.nranks
az, ay, ax = spec.alloc
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
state0 = jnp.asarray(
    np.random.default_rng(0).normal(size=(R * az, ay, ax)).astype(np.float32))

for mode in ("baseline", "tempi"):
    comm = Communicator(axis_name="ranks", policy=policy_for_mode(mode))
    types = make_halo_types(spec, comm)
    fn = jax.jit(shard_map(
        lambda x: halo_exchange(x, spec, comm, "ranks", types),
        mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"),
        check_vma=False))
    out = fn(state0); jax.block_until_ready(out)
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        out = fn(out)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig12/exchange/{mode},{us:.2f},"
          f"ranks=8;interior=16^3;r=2;wire_ops={comm.stats()['wire_ops']}")

    # pack-only phase (one face datatype, 26x per iteration in exchange)
    from repro.halo.exchange import _region_type
    ct = comm.commit(_region_type(spec, (0, 0, 1), "send"))
    local = jnp.zeros((az, ay, ax), jnp.float32)
    pfn = jax.jit(lambda b: comm.pack(b, ct))
    o = pfn(local); jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(10):
        o = pfn(local)
    jax.block_until_ready(o)
    us = (time.perf_counter() - t0) / 10 * 1e6
    print(f"fig12/pack-face/{mode},{us:.2f},single-face")
"""


def run() -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CODE)],
        env=env, capture_output=True, text=True, timeout=1200,
    )
    if proc.returncode != 0:
        print(f"fig12/FAILED,0,{proc.stderr.splitlines()[-1] if proc.stderr else 'unknown'}")
        return
    sys.stdout.write(proc.stdout)


if __name__ == "__main__":
    run()
