"""Fig. 12: 3D stencil halo exchange, baseline vs TEMPI.

Runs the 26-neighbor exchange on an 8-rank emulated mesh in a
subprocess (device count must be set before jax init), reporting
per-iteration time for both interposer modes and the pack-only
latency (the paper's phase split), plus the exchange's wire-byte
accounting (exact ragged payload vs what the padded layout would move).

``--assert-ragged`` runs the wire-bytes regression gate instead (CI),
in two modes:

* **exact**: trace the fused halo step planned under
  ``schedule_policy="exact"`` and FAIL (exit 1) if the bytes its
  collectives move exceed the ragged optimum — the sum of per-peer
  packed extents;
* **padded allowance**: trace the step planned under the *default*
  (model-priced) policy and FAIL if the issued bytes exceed
  ``(1 + allowance) x`` the ragged optimum or the uniform row-equalized
  bound — the padding the model may legitimately buy is capped, so
  flipping the default to ``"model"`` stays byte-gated
  (``--padded-allowance X`` overrides the default 1.0).

``--assert-program`` runs the deep-halo HaloProgram gate (CI): for each
fusion depth ``s``, one traced program iteration must issue exactly ONE
exchange (exchanges-per-stencil-step <= 1/s), the deep-radius wire
layout must stay at the ragged optimum (the PR-3 wire-bytes gate, at the
new segment sizes), depths must agree bit-exactly on the interior, and
``price_program`` must never pick a depth whose predicted per-step cost
exceeds ``s=1``.  It also runs the heterogeneous-cycle gate: a fused
``[predictor, corrector]`` cycle with unequal per-dimension radii must
issue <= 1 exchange per cycle repeat, stay bit-exact against the
exchange-per-application reference, and price its auto depth no worse
per application than ``s=1``.

``--assert-overlap`` runs the region-split overlap gate (CI): region
mode must be bit-exact against the plain reference AND the monolithic
overlap path on the 2x2x2 grid, and ``choose_overlap_mode`` on the
checked-in ``ci_params.json`` tables must pick a mode priced no worse
than monolithic, record it as an ``overlap/mode=...`` decision, and pin
it on the rerun.

``--assert-compress`` runs the length-aware compressed-wire gate (CI):
a zero-heavy probed payload must select the lossless RLE wire and the
``varlen`` schedule, its traced collective bytes must equal
``plan.issued_bytes`` and land STRICTLY below the uncompressed ragged
optimum (the sum of packed extents — compressed bytes are the bytes on
the wire, not an accounting fiction), the exchange must stay bit-exact
against the capacity (grouped) transport, the model's probed choice on
the checked-in ``ci_params.json`` must never be priced worse than the
unprobed (uncompressed) choice of the same exchange, and the lossy
int8 wire must never be auto-picked.

``--assert-scale`` runs the simulated-scale gate (CI): sweep the
predicted schedule ladder (``PerfModel.at_scale``) over rank counts up
to the paper's 3072-process regime on the checked-in ``ci_params.json``
under a synthetic two-tier topology, and FAIL unless the model flips to
the ``tiered`` (inter-node coalesced) schedule at the large-rank end
with strictly fewer slow-tier messages than per-class grouped at equal
payload bytes, the best predicted cost is non-decreasing in rank count,
the flip is pinned as a topology-keyed decision that replays, and an
elastic remesh (``replan_on_remesh``) provably demotes the pin instead
of replaying it.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CODE = r"""
import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator, policy_for_mode
from repro.halo import HaloSpec, halo_exchange, make_halo_plan

spec = HaloSpec(grid=(2, 2, 2), interior=(16, 16, 16), radius=2)
R = spec.nranks
az, ay, ax = spec.alloc
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
state0 = jnp.asarray(
    np.random.default_rng(0).normal(size=(R * az, ay, ax)).astype(np.float32))

for mode in ("baseline", "tempi"):
    comm = Communicator(axis_name="ranks", policy=policy_for_mode(mode))
    plan = make_halo_plan(spec, comm)
    fn = jax.jit(shard_map(
        lambda x: halo_exchange(x, spec, comm, "ranks", plan=plan),
        mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"),
        check_vma=False))
    print(f"fig12/wire-bytes/{mode},{plan.wire_bytes},"
          f"schedule={plan.wire.schedule};ops={plan.wire.wire_ops};"
          f"padded_layout_would_move={plan.wire.nranks * plan.wire.seg_bytes}")
    out = fn(state0); jax.block_until_ready(out)
    t0 = time.perf_counter()
    iters = 3
    for _ in range(iters):
        out = fn(out)
    jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / iters * 1e6
    print(f"fig12/exchange/{mode},{us:.2f},"
          f"ranks=8;interior=16^3;r=2;wire_ops={comm.stats()['wire_ops']}")

    # pack-only phase (one face datatype, 26x per iteration in exchange)
    from repro.halo.exchange import _region_type
    ct = comm.commit(_region_type(spec, (0, 0, 1), "send"))
    local = jnp.zeros((az, ay, ax), jnp.float32)
    pfn = jax.jit(lambda b: comm.pack(b, ct))
    o = pfn(local); jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(10):
        o = pfn(local)
    jax.block_until_ready(o)
    us = (time.perf_counter() - t0) / 10 * 1e6
    print(f"fig12/pack-face/{mode},{us:.2f},single-face")
"""


#: the CI regression gate: exact-policy bytes must equal the ragged
#: optimum, and the default (model-priced) policy may buy at most the
#: declared padding allowance — grows a diff the moment uncontrolled
#: padding creeps back in
_ASSERT_CODE = r"""
import os
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import HaloSpec, halo_exchange, make_halo_plan

ALLOWANCE = float(os.environ.get("REPRO_PADDED_ALLOWANCE", "1.0"))

spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=2)
R = spec.nranks
az, ay, ax = spec.alloc
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
# forced pack strategy: the ragged optimum is exactly sum(ct.size)
comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
plan = make_halo_plan(spec, comm, schedule_policy="exact")
fn = jax.jit(shard_map(
    lambda x: halo_exchange(x, spec, comm, "ranks", plan=plan),
    mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"), check_vma=False))
x = jnp.zeros((R * az, ay, ax), jnp.float32)

ragged_optimum = sum(ct.packed_extent() for ct in plan.send_cts)
counts = collective_payload_bytes(fn, x)
print(f"wire-bytes-check: traced={counts['total']} "
      f"plan={plan.wire_bytes} optimum={ragged_optimum} "
      f"schedule={plan.wire.schedule} ops={counts['ops']}")
assert plan.wire_bytes == ragged_optimum, (plan.wire_bytes, ragged_optimum)
assert counts["total"] <= ragged_optimum, (
    f"exact-policy path moves {counts['total']} B > ragged optimum "
    f"{ragged_optimum} B — padding has crept back into the wire layout")
# the exchange must still be correct, in interpret mode, end to end
out = np.asarray(fn(jnp.asarray(
    np.random.default_rng(0).normal(size=(R * az, ay, ax)).astype(np.float32))))
assert np.isfinite(out).all()

# padded-allowance mode: the DEFAULT policy is model-priced and may buy
# uniform padding, but never more than the row-equalized bound nor the
# declared allowance over the ragged optimum
comm2 = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
plan2 = make_halo_plan(spec, comm2)
fn2 = jax.jit(shard_map(
    lambda x: halo_exchange(x, spec, comm2, "ranks", plan=plan2),
    mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"), check_vma=False))
counts2 = collective_payload_bytes(fn2, x)
uniform_bound = plan2.wire.nranks * plan2.wire.seg_bytes
print(f"padded-allowance-check: schedule={plan2.wire.schedule} "
      f"issued={plan2.wire.issued_bytes} traced={counts2['total']} "
      f"optimum={ragged_optimum} uniform_bound={uniform_bound} "
      f"allowance={ALLOWANCE}")
assert plan2.wire_bytes == ragged_optimum, (plan2.wire_bytes, ragged_optimum)
assert counts2["total"] == plan2.wire.issued_bytes, (counts2, plan2.wire.issued_bytes)
assert plan2.wire.issued_bytes <= uniform_bound, (
    "model policy issued more than the uniform row-equalized layout")
assert plan2.wire.issued_bytes <= (1.0 + ALLOWANCE) * ragged_optimum, (
    f"model policy buys {plan2.wire.padding_bytes} B padding — beyond the "
    f"{ALLOWANCE:.2f} allowance over the {ragged_optimum} B ragged optimum")
out2 = np.asarray(fn2(jnp.asarray(
    np.random.default_rng(0).normal(size=(R * az, ay, ax)).astype(np.float32))))
assert np.isfinite(out2).all()
print("WIRE_BYTES_OK")
"""


#: the deep-halo CI gate: a HaloProgram must actually avoid exchanges
#: (one per s stencil steps), keep the ragged-optimal wire layout at the
#: deep segment sizes, stay bit-exact across depths, and never let the
#: model pick a depth it predicts to be worse than step-per-exchange
_PROGRAM_ASSERT_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import build_halo_program, make_program_step
from repro.measure import DecisionCache

grid, interior = (2, 2, 2), (6, 5, 4)
nz, ny, nx = interior
R = 8
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
field = np.random.default_rng(0).normal(size=(R, nz, ny, nx)).astype(np.float32)

TOTAL_STEPS = 2
interiors = {}
for s in (1, 2):
    comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
    prog = build_halo_program(grid, interior, comm, steps=s,
                              schedule_policy="exact")
    fn = make_program_step(prog, comm, mesh)
    az, ay, ax = prog.spec.alloc
    rz, ry, rx = prog.spec.radii
    state = np.zeros((R, az, ay, ax), np.float32)
    state[:, rz:rz+nz, ry:ry+ny, rx:rx+nx] = field
    x = jnp.asarray(state.reshape(R * az, ay, ax))

    counts = collective_payload_bytes(fn, x)
    # one fused exchange (= plan.wire.wire_ops collectives) per s steps
    assert counts["ops"] == prog.plan.wire.wire_ops, (s, counts)
    exchanges_per_step = (counts["ops"] / prog.plan.wire.wire_ops) / s
    assert exchanges_per_step <= 1.0 / s + 1e-12, (s, exchanges_per_step)
    # wire-bytes gate (PR 3) at the deep radius: still the ragged optimum
    ragged_optimum = sum(ct.packed_extent() for ct in prog.plan.send_cts)
    assert prog.plan.wire_bytes == ragged_optimum, (s, prog.plan.wire_bytes)
    assert counts["total"] <= ragged_optimum, (s, counts, ragged_optimum)
    print(f"program/s={s}: ops={counts['ops']} "
          f"exchanges_per_step={exchanges_per_step:.3f} "
          f"wire_bytes={prog.plan.wire_bytes}")

    out = x
    for _ in range(TOTAL_STEPS // s):
        out = fn(out)
    interiors[s] = np.asarray(out).reshape(R, az, ay, ax)[
        :, rz:rz+nz, ry:ry+ny, rx:rx+nx]

np.testing.assert_array_equal(interiors[1], interiors[2])
print("program bit-exact across depths")

# the price_program oracle: auto never selects a depth predicted to be
# worse per stencil step than s=1 (and records the choice)
dc = DecisionCache()
comm = Communicator(axis_name="ranks", decisions=dc)
prog = build_halo_program(grid, interior, comm, steps="auto")
one = [e for e in prog.candidates if e.steps == 1]
assert one, prog.candidates
assert prog.estimate.per_step <= one[0].per_step, (
    prog.estimate, one[0])
assert any(d.strategy == f"program/s={prog.steps}" for d in dc.log)
print(f"auto depth s={prog.steps} per_step={prog.estimate.per_step:.3e} "
      f"(s=1 {one[0].per_step:.3e})")
print("PROGRAM_OK")
"""


#: the heterogeneous-cycle gate: a fused [predictor, corrector] cycle
#: with unequal per-dim radii must issue <= 1 exchange per cycle repeat,
#: keep the ragged-optimal deep wire layout (exact policy), stay
#: bit-exact against the exchange-per-application reference, and price
#: its auto depth no worse per application than s=1
_CYCLE_ASSERT_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import StencilOp, build_halo_program, make_program_step
from repro.measure import DecisionCache

ops = [StencilOp((2, 1, 1), weight=0.5), StencilOp((1, 1, 1), weight=0.25)]
grid, interior = (2, 2, 2), (8, 6, 6)   # cycle radii (3, 2, 2)
nz, ny, nx = interior
R = 8
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
field = np.random.default_rng(0).normal(size=(R, nz, ny, nx)).astype(np.float32)

def run_program(prog, comm, state_field, iters):
    fn = make_program_step(prog, comm, mesh)
    az, ay, ax = prog.spec.alloc
    rz, ry, rx = prog.spec.radii
    state = np.zeros((R, az, ay, ax), np.float32)
    state[:, rz:rz+nz, ry:ry+ny, rx:rx+nx] = state_field
    x = jnp.asarray(state.reshape(R * az, ay, ax))
    for _ in range(iters):
        x = fn(x)
    return np.asarray(x).reshape(R, az, ay, ax)[
        :, rz:rz+nz, ry:ry+ny, rx:rx+nx]

TOTAL = 2  # cycle repeats in every variant
interiors = {}
for s in (1, 2):
    comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
    prog = build_halo_program(grid, interior, comm, ops=ops, steps=s,
                              schedule_policy="exact")
    assert prog.spec.radii == (3 * s, 2 * s, 2 * s), prog.spec.radii
    assert prog.cycle_len == 2 and prog.applications == 2 * s
    fn = make_program_step(prog, comm, mesh)
    az, ay, ax = prog.spec.alloc
    x0 = jnp.zeros((R * az, ay, ax), jnp.float32)
    counts = collective_payload_bytes(fn, x0)
    assert counts["ops"] == prog.plan.wire.wire_ops, (s, counts)
    # wire-amortization measured over a FIXED amount of physical work:
    # TOTAL cycle repeats need TOTAL/s program iterations, so the
    # traced collective count must shrink to 1/s exchanges per repeat
    def total_work(x):
        for _ in range(TOTAL // s):
            x = fn(x)
        return x
    total_counts = collective_payload_bytes(total_work, x0)
    per_cycle = (total_counts["ops"] / prog.plan.wire.wire_ops) / TOTAL
    assert abs(per_cycle - 1.0 / s) < 1e-12, (s, per_cycle, total_counts)
    # exact-policy deep wire layout stays ragged-optimal
    ragged_optimum = sum(ct.packed_extent() for ct in prog.plan.send_cts)
    assert prog.plan.wire_bytes == ragged_optimum, (s, prog.plan.wire_bytes)
    assert counts["total"] <= ragged_optimum, (s, counts, ragged_optimum)
    print(f"cycle/s={s}: ops={counts['ops']} exchanges_per_cycle={per_cycle:.3f} "
          f"wire_bytes={prog.plan.wire_bytes}")
    interiors[s] = run_program(prog, comm, field, TOTAL // s)

np.testing.assert_array_equal(interiors[1], interiors[2])

# the per-application reference: exchange before EVERY op application
comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
ref_progs = [
    build_halo_program(grid, interior, comm, ops=[op], steps=1,
                       schedule_policy="exact")
    for op in ops
]
ref = field
for _ in range(TOTAL):
    for prog in ref_progs:
        ref = run_program(prog, comm, ref, 1)
np.testing.assert_array_equal(interiors[1], ref)
print("cycle bit-exact vs per-application reference")

# auto oracle + decision: never worse per application than s=1, and the
# cycle fingerprint lands in the decisions log
dc = DecisionCache()
comm = Communicator(axis_name="ranks", decisions=dc)
prog = build_halo_program(grid, interior, comm, ops=ops, steps="auto")
one = [e for e in prog.candidates if e.steps == 1]
assert one, prog.candidates
assert prog.estimate.per_step <= one[0].per_step, (prog.estimate, one[0])
rows = [d for d in dc.log if d.strategy == f"program/s={prog.steps}"]
assert rows and "cycle=[" in rows[0].signature, rows
print(f"cycle auto s={prog.steps} per_step={prog.estimate.per_step:.3e} "
      f"(s=1 {one[0].per_step:.3e})")
print("CYCLE_OK")
"""


#: the region-split overlap gate (CI): region mode must be bit-exact
#: against BOTH the plain exchange-then-cycle reference and the
#: monolithic overlap path, and the overlap/mode decision priced on the
#: checked-in ci_params.json must never choose a mode the model predicts
#: to be worse than monolithic (ties go to monolithic by construction)
_OVERLAP_ASSERT_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator
from repro.halo import (HaloSpec, STENCIL26, halo_exchange, make_halo_plan,
                        make_halo_types, overlap_region_descriptors,
                        overlapped_stencil_iteration, stencil_steps)
from repro.measure import DecisionCache, load_ci_params

spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=2)
R = spec.nranks
az, ay, ax = spec.alloc
mesh = Mesh(np.array(jax.devices()[:R]), ("ranks",))
comm = Communicator(axis_name="ranks")
types = make_halo_types(spec, comm)
plan = make_halo_plan(spec, comm, types, schedule_policy="exact")
probe = {}

def plain(local):
    local = halo_exchange(local, spec, comm, "ranks", types, plan=plan)
    return stencil_steps(local, spec, steps=2)

def region(local):
    return overlapped_stencil_iteration(
        local, spec, comm, "ranks", types, steps=2, probe=probe,
        plan=plan, mode="region")

def mono(local):
    return overlapped_stencil_iteration(
        local, spec, comm, "ranks", types, steps=2, plan=plan,
        mode="monolithic")

kw = dict(mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"),
          check_vma=False)
jp = jax.jit(shard_map(plain, **kw))
jr = jax.jit(shard_map(region, **kw))
jm = jax.jit(shard_map(mono, **kw))
x = jnp.asarray(np.random.default_rng(0).normal(
    size=(R * az, ay, ax)).astype(np.float32))
ref = np.asarray(jp(x))
np.testing.assert_array_equal(ref, np.asarray(jr(x)))
np.testing.assert_array_equal(ref, np.asarray(jm(x)))
assert probe["overlap_mode"] == "region"
assert probe["rim_regions"] == 26, probe
assert sorted(probe["class_drain_order"]) == list(range(plan.wire.ngroups))
print(f"overlap-exact-check: rims={probe['rim_regions']} "
      f"classes={plan.wire.ngroups} bit-exact vs plain and monolithic")

# the decision gate on the pinned CI tables: whatever mode the model
# chooses must be priced no worse than monolithic, and the choice must
# land in (and pin from) the decisions cache
dc = DecisionCache()
comm_ci = Communicator(axis_name="ranks", params=load_ci_params(),
                       decisions=dc)
types_ci = make_halo_types(spec, comm_ci)
plan_ci = make_halo_plan(spec, comm_ci, types_ci)
core_bytes, rims = overlap_region_descriptors(spec, STENCIL26, plan_ci.wire)
mode, ests, pinned = comm_ci.model.choose_overlap_mode(
    plan_ci.wire, rims, core_bytes, STENCIL26.nneighbors)
assert not pinned
assert ests[mode].t_total <= ests["monolithic"].t_total, (mode, ests)
rows = [d for d in dc.log if d.strategy == f"overlap/mode={mode}"]
assert rows and "regions=" in rows[0].signature, rows
mode2, _, pinned2 = comm_ci.model.choose_overlap_mode(
    plan_ci.wire, rims, core_bytes, STENCIL26.nneighbors)
assert (mode2, pinned2) == (mode, True)
print(f"overlap-mode-check: schedule={plan_ci.wire.schedule} "
      f"classes={plan_ci.wire.ngroups} chose={mode} "
      + " ".join(f"{m}={e.t_total:.3e}s" for m, e in sorted(ests.items())))
print("OVERLAP_MODE_OK")
"""


#: the length-aware compressed-wire gate (CI): varlen RLE must move
#: strictly fewer traced bytes than the uncompressed ragged optimum,
#: bit-exact against the capacity transport; on the checked-in CI
#: tables the probed choice is never priced worse than the unprobed
#: one, and the lossy wire is never auto-picked
_COMPRESS_ASSERT_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator, RleWire, collective_payload_bytes
from repro.comm.wireplan import reschedule
from repro.core import FLOAT, Subarray
from repro.measure import DecisionCache, load_ci_params

mesh = Mesh(np.array(jax.devices()[:1]), ("ranks",))
perms = [[(0, 0)]]
src = np.zeros((32, 32), np.float32)
src[10:12, 6:8] = 3.0  # zero-heavy halo shell: a compressible payload

comm = Communicator(axis_name="ranks")
ct = comm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
strats, plan = comm.plan_neighbor([ct], perms, probe=jnp.asarray(src))
assert strats[0].name == RleWire.name, strats
assert plan.schedule == "varlen", plan.schedule
assert plan.stream_bytes and plan.effective_wire_bytes < plan.wire_bytes

def exchange(p):
    def body(buf):
        return comm.neighbor_alltoallv(buf, [ct], [ct], perms,
                                       plan=p, strategies=strats)
    return jax.jit(shard_map(body, mesh=mesh, in_specs=P(),
                             out_specs=P(), check_vma=False))

fn = exchange(plan)
x = jnp.asarray(src)
counts = collective_payload_bytes(fn, x)
ragged_optimum = ct.packed_extent()  # the uncompressed exact-byte floor
print(f"compress-bytes-check: traced={counts['total']} "
      f"issued={plan.issued_bytes} capacity={plan.wire_bytes} "
      f"uncompressed_optimum={ragged_optimum} "
      f"ratio={plan.stream_ratio:.4f}")
assert counts["total"] == plan.issued_bytes, (counts, plan.issued_bytes)
assert counts["total"] < ragged_optimum, (
    f"varlen moves {counts['total']} B >= the {ragged_optimum} B "
    f"uncompressed optimum — the compressed bytes are not the bytes "
    f"on the wire")

# bit-exact against the capacity (grouped, untruncated) transport
cap = reschedule(plan, "grouped")
assert cap.issued_bytes == cap.wire_bytes
out = np.asarray(fn(x))
out_cap = np.asarray(exchange(cap)(x))
np.testing.assert_array_equal(out, out_cap)
np.testing.assert_array_equal(out[10:12, 6:8], src[10:12, 6:8])
print("compress bit-exact vs capacity transport")

# model-choice gate on the pinned CI tables: planning WITH the probe
# must never be priced worse than planning without it (the probe only
# adds options), and the lossy int8 wire is never auto-picked
dc = DecisionCache()
comm_ci = Communicator(axis_name="ranks", params=load_ci_params(),
                       decisions=dc)
ct_ci = comm_ci.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
s_probed, p_probed = comm_ci.plan_neighbor([ct_ci], perms,
                                           probe=jnp.asarray(src))
s_plain, p_plain = comm_ci.plan_neighbor([ct_ci], perms)
wire_rows = {d.fingerprint: d for d in dc.log
             if d.strategy.startswith("wire/")}
probed_cost = wire_rows[p_probed.fingerprint].total
plain_cost = wire_rows[p_plain.fingerprint].total
print(f"compress-model-check: probed={p_probed.schedule} "
      f"({probed_cost:.3e}s) plain={p_plain.schedule} "
      f"({plain_cost:.3e}s)")
assert probed_cost <= plain_cost + 1e-15, (
    f"the probed plan ({p_probed.schedule}, {probed_cost:.3e}s) is "
    f"priced worse than the uncompressed plan ({p_plain.schedule}, "
    f"{plain_cost:.3e}s)")
for ss in (s_probed, s_plain, strats):
    assert all(s.name != "int8wire" for s in ss), (
        "the lossy int8 wire was auto-picked")
print("COMPRESS_OK")
"""


#: the simulated-scale gate (CI): the measured tables + a synthetic
#: two-tier topology must predict the paper-regime behavior — the wire
#: schedule flips to tier-coalesced as ranks grow, with strictly fewer
#: slow-tier messages than per-class grouped at equal payload, pinned
#: as a topology-keyed decision an elastic remesh provably demotes
_SCALE_ASSERT_CODE = r"""
from types import SimpleNamespace

from repro.comm import PerfModel, Topology, scale_ladder, synthetic_two_tier
from repro.measure import DecisionCache, load_ci_params
from repro.train.elastic import replan_on_remesh

RPN = 8
RANKS = (8, 16, 64, 256, 1024, 3072)
params = synthetic_two_tier(load_ci_params())
dc = DecisionCache()
model = PerfModel(params, decisions=dc)
ladder = scale_ladder(model, RANKS, RPN)
for e in ladder:
    print(f"scale/{e.ranks}: nodes={e.nodes} grid={e.grid} "
          f"best={e.schedule} wire_bytes={e.wire_bytes} "
          f"corr={e.correction_bytes} inter={e.inter_messages} "
          + " ".join(f"{s}={c:.3e}" for s, c in sorted(e.costs.items())))

# the ladder flips: single-node scales plan flat, the 3072-rank end is
# tier-coalesced and stays tier-coalesced above the flip point
top = ladder[-1]
assert top.ranks == 3072 and top.schedule == "tiered", top
assert ladder[0].schedule != "tiered", ladder[0]
flip = next(e.ranks for e in ladder if e.schedule == "tiered")
assert all(e.schedule == "tiered" for e in ladder if e.ranks >= flip)
print(f"scale/flip: tiered from {flip} ranks")

# above the flip: tiered never worse than per-class grouped, and it
# sends strictly fewer slow-tier messages at the same payload bytes
# (the costs dict prices every schedule on the same ScalePlan, so
# wire_bytes is equal by construction; the correction bytes tiered
# buys ride the fast tier and are accounted separately)
for e in ladder:
    if e.ranks < flip:
        continue
    assert e.costs["tiered"] <= e.costs["grouped"], (e.ranks, e.costs)
    assert e.inter_messages["tiered"] < e.inter_messages["grouped"], e
    assert e.correction_bytes > 0, e

# the predicted best exchange cost is non-decreasing in rank count
best = [min(e.costs.values()) for e in ladder]
assert all(b >= a - 1e-15 for a, b in zip(best, best[1:])), best

# the flip is pinned as a topology-keyed decision and replays
rows = [d for d in dc.log
        if d.strategy == "wire/tiered" and "topo=" in d.signature]
assert rows, dc.report()
again = model.at_scale(3072, ranks_per_node=RPN)
assert again.pinned and again.schedule == "tiered", again
print(f"scale/pin: {rows[0].strategy}@{rows[0].fingerprint} replayed")

# elastic remesh: rebinding to a reshaped topology demotes every
# topology-sensitive pin recorded under the old shapes — the next
# at_scale re-prices from scratch instead of replaying a stale pin
npins = len(dc)
rep = replan_on_remesh(SimpleNamespace(model=model),
                       Topology.blocked(2048, RPN))
assert rep.npruned == npins, (rep.npruned, npins)
assert len(dc) == 0, dc.report()
redo = model.at_scale(3072, ranks_per_node=RPN)
assert not redo.pinned and redo.schedule == "tiered", redo
print(f"scale/replan: pruned {rep.npruned} pins, re-priced fresh")
print("SCALE_OK")
"""


def run(assert_ragged: bool = False, assert_program: bool = False,
        assert_overlap: bool = False, assert_scale: bool = False,
        assert_compress: bool = False,
        padded_allowance: float = None) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    if padded_allowance is not None:
        env["REPRO_PADDED_ALLOWANCE"] = str(padded_allowance)
    gate = (assert_ragged or assert_program or assert_overlap
            or assert_scale or assert_compress)
    # all requested gates run when several flags are given — combining
    # flags must never silently drop a regression check
    jobs = []
    if assert_ragged:
        jobs.append((_ASSERT_CODE, "WIRE_BYTES_OK"))
    if assert_program:
        jobs.append((_PROGRAM_ASSERT_CODE, "PROGRAM_OK"))
        jobs.append((_CYCLE_ASSERT_CODE, "CYCLE_OK"))
    if assert_overlap:
        jobs.append((_OVERLAP_ASSERT_CODE, "OVERLAP_MODE_OK"))
    if assert_scale:
        jobs.append((_SCALE_ASSERT_CODE, "SCALE_OK"))
    if assert_compress:
        jobs.append((_COMPRESS_ASSERT_CODE, "COMPRESS_OK"))
    if not jobs:
        jobs.append((_CODE, None))
    for code, ok_token in jobs:
        proc = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            env=env, capture_output=True, text=True, timeout=1200,
        )
        if proc.returncode != 0:
            print(f"fig12/FAILED,0,{proc.stderr.splitlines()[-1] if proc.stderr else 'unknown'}")
            if gate:
                sys.stderr.write(proc.stderr)
                sys.exit(1)
            return
        sys.stdout.write(proc.stdout)
        if ok_token is not None and ok_token not in proc.stdout:
            sys.exit(1)


if __name__ == "__main__":
    argv = sys.argv[1:]
    allowance = None
    if "--padded-allowance" in argv:
        allowance = float(argv[argv.index("--padded-allowance") + 1])
    run(
        assert_ragged="--assert-ragged" in argv,
        assert_program="--assert-program" in argv,
        assert_overlap="--assert-overlap" in argv,
        assert_scale="--assert-scale" in argv,
        assert_compress="--assert-compress" in argv,
        padded_allowance=allowance,
    )
