"""Measurement subsystem benchmark: the calibrate -> store -> select
lifecycle on the running backend (paper §6.3's "record once, reuse"
binary, here over ALL model terms).

Reports the reduced-grid calibration cost, the measured term values the
model will interpolate, and the effect on selection: how often the
measured tables flip the decision the analytic constants would make.

``--telemetry-overhead`` measures the fleet layer's own cost: the
per-call price of the :class:`repro.fleet.ExchangeTelemetry` probe
against a pinned-decision exchange loop (the smoother's compiled deep-
halo step), so the observability layer is held to the same standard as
everything else it observes.  ``--assert-telemetry-overhead`` gates it
at <2%.  ``--trace-overhead`` / ``--assert-trace-overhead`` do the same
for the :mod:`repro.obs` tracer: the per-iteration cost of recording a
compiled iteration's attributed span tree, held to the SAME budget.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, time_host_us
from repro.comm.perfmodel import PerfModel, TPU_V5E
from repro.core import BYTE, TypeRegistry, Vector
from repro.measure import DecisionCache, calibrate_params

REG = TypeRegistry()

#: the probe may add at most this fraction to a pinned-decision
#: exchange iteration (the --assert-telemetry-overhead gate)
TELEMETRY_OVERHEAD_BUDGET = 0.02


def run() -> None:
    t0 = time.perf_counter()
    params = calibrate_params(name="bench_reduced", reduced=True)
    emit("measure/calibrate-reduced", (time.perf_counter() - t0) * 1e6, "host")

    for strat, rows in sorted((params.pack_table or {}).items()):
        emit(f"measure/pack-table/{strat}", rows[0][2] * 1e6,
             f"points={len(rows)}")
    for strat, rows in sorted((params.unpack_table or {}).items()):
        emit(f"measure/unpack-table/{strat}", rows[0][2] * 1e6,
             f"points={len(rows)}")
    if params.wire_table:
        emit("measure/wire-smallest", params.wire_table[0][1] * 1e6,
             f"fit_lat={params.wire_latency};fit_bw={params.wire_bw}")

    # selection flips: measured tables vs analytic constants
    analytic = PerfModel(TPU_V5E)
    measured = PerfModel(params, decisions=DecisionCache())
    flips = 0
    cases = [(blk, kib) for blk in (8, 64, 512) for kib in (1, 16, 256)]
    for blk, kib in cases:
        count = max(kib * 1024 // blk, 1)
        ct = REG.commit(Vector(count, blk, max(512, 2 * blk), BYTE))
        a = analytic.select(ct).strategy
        m = measured.select(ct).strategy
        flips += a != m
        emit(f"measure/select/blk{blk}/{kib}KiB",
             measured.select(ct).total * 1e6, f"analytic={a};measured={m}")
    emit("measure/selection-flips", float(flips), f"of={len(cases)}")
    # the audit log doubles as the report artifact
    emit("measure/decisions-recorded", float(len(measured.decisions)), "audit")


def telemetry_overhead(iters: int = 30) -> float:
    """The probe's cost relative to one pinned-decision exchange loop
    iteration.

    The loop is the smoother's compiled deep-halo step — every strategy
    and depth decision pinned after the first iteration — timed by the
    probe itself (its ``mean`` is the per-iteration wall cost).  The
    probe's own per-call price is measured directly (one dict lookup +
    one ring write) rather than by differencing two noisy loop timings:
    the ratio is the overhead the probe adds when every iteration is
    observed, without the gate flapping on loop-to-loop noise.
    """
    from repro.comm.api import Communicator
    from repro.fleet import ExchangeTelemetry
    from repro.launch.smoother import run_smoother

    tel = ExchangeTelemetry()
    comm = Communicator(
        axis_name="data", decisions=DecisionCache(), telemetry=tel
    )
    report = run_smoother(
        comm, iters=iters, interior=(8, 8, 8), cycle="smooth", halo_steps=2
    )
    agg = tel.get(report.program.fingerprint)
    assert agg is not None and agg.count == iters
    t_iter = agg.mean
    t_probe = time_host_us(
        lambda: tel.observe(agg.key, t_iter), iters=2000
    ) * 1e-6
    overhead = t_probe / t_iter
    emit("measure/telemetry/exchange-iter", t_iter * 1e6,
         f"iters={iters};pinned={report.program.pinned}")
    emit("measure/telemetry/probe-call", t_probe * 1e6, "observe()")
    emit("measure/telemetry/overhead-pct", overhead * 100.0,
         f"budget={TELEMETRY_OVERHEAD_BUDGET * 100:.0f}%")
    return overhead


def trace_overhead(iters: int = 30) -> float:
    """The tracer's per-iteration cost relative to one pinned-decision
    exchange loop iteration — the span-recording analog of
    :func:`telemetry_overhead`, held to the same budget.

    A compiled deep-halo iteration records its whole span tree through
    ONE :func:`repro.obs.trace.attribute_program_iteration` call (the
    launch layer's per-iteration tracer hook), so that call's host cost
    *is* the probe price; it is measured directly against the loop's
    observed iteration time, like the telemetry probe.
    """
    from repro.comm.api import Communicator
    from repro.fleet import ExchangeTelemetry, predict_program_phases
    from repro.launch.smoother import run_smoother
    from repro.obs.trace import Tracer, attribute_program_iteration

    tel = ExchangeTelemetry()
    comm = Communicator(
        axis_name="data", decisions=DecisionCache(), telemetry=tel
    )
    report = run_smoother(
        comm, iters=iters, interior=(8, 8, 8), cycle="smooth", halo_steps=2
    )
    agg = tel.get(report.program.fingerprint)
    assert agg is not None and agg.count == iters
    t_iter = agg.mean
    tracer = Tracer()
    phases = predict_program_phases(report.program, comm.model)
    t_probe = time_host_us(
        lambda: attribute_program_iteration(
            tracer, report.program, 0.0, t_iter, phases
        ),
        iters=500,
    ) * 1e-6
    overhead = t_probe / t_iter
    emit("measure/trace/exchange-iter", t_iter * 1e6,
         f"iters={iters};pinned={report.program.pinned}")
    emit("measure/trace/probe-call", t_probe * 1e6,
         "attribute_program_iteration()")
    emit("measure/trace/overhead-pct", overhead * 100.0,
         f"budget={TELEMETRY_OVERHEAD_BUDGET * 100:.0f}%")
    return overhead


def main() -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_measure",
                                 description=__doc__)
    ap.add_argument("--telemetry-overhead", action="store_true",
                    help="measure only the telemetry probe's relative "
                         "cost (skips the calibration lifecycle rows)")
    ap.add_argument("--assert-telemetry-overhead", action="store_true",
                    help="exit 1 when the probe adds >= "
                         f"{TELEMETRY_OVERHEAD_BUDGET:.0%} to a pinned-"
                         "decision exchange iteration (implies "
                         "--telemetry-overhead)")
    ap.add_argument("--trace-overhead", action="store_true",
                    help="measure only the span tracer's relative cost "
                         "per compiled iteration (skips the calibration "
                         "lifecycle rows)")
    ap.add_argument("--assert-trace-overhead", action="store_true",
                    help="exit 1 when the tracer adds >= "
                         f"{TELEMETRY_OVERHEAD_BUDGET:.0%} to a pinned-"
                         "decision exchange iteration (implies "
                         "--trace-overhead)")
    args = ap.parse_args()
    probes_only = False
    if args.telemetry_overhead or args.assert_telemetry_overhead:
        probes_only = True
        overhead = telemetry_overhead()
        if (
            args.assert_telemetry_overhead
            and overhead >= TELEMETRY_OVERHEAD_BUDGET
        ):
            raise SystemExit(
                f"telemetry probe overhead {overhead:.2%} >= "
                f"{TELEMETRY_OVERHEAD_BUDGET:.0%} budget"
            )
    if args.trace_overhead or args.assert_trace_overhead:
        probes_only = True
        overhead = trace_overhead()
        if (
            args.assert_trace_overhead
            and overhead >= TELEMETRY_OVERHEAD_BUDGET
        ):
            raise SystemExit(
                f"trace probe overhead {overhead:.2%} >= "
                f"{TELEMETRY_OVERHEAD_BUDGET:.0%} budget"
            )
    if probes_only:
        return
    run()


if __name__ == "__main__":
    main()
