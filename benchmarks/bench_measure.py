"""Measurement subsystem benchmark: the calibrate -> store -> select
lifecycle on the running backend (paper §6.3's "record once, reuse"
binary, here over ALL model terms).

Reports the reduced-grid calibration cost, the measured term values the
model will interpolate, and the effect on selection: how often the
measured tables flip the decision the analytic constants would make.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.comm.perfmodel import PerfModel, TPU_V5E
from repro.core import BYTE, TypeRegistry, Vector
from repro.measure import DecisionCache, calibrate_params

REG = TypeRegistry()


def run() -> None:
    t0 = time.perf_counter()
    params = calibrate_params(name="bench_reduced", reduced=True)
    emit("measure/calibrate-reduced", (time.perf_counter() - t0) * 1e6, "host")

    for strat, rows in sorted((params.pack_table or {}).items()):
        emit(f"measure/pack-table/{strat}", rows[0][2] * 1e6,
             f"points={len(rows)}")
    for strat, rows in sorted((params.unpack_table or {}).items()):
        emit(f"measure/unpack-table/{strat}", rows[0][2] * 1e6,
             f"points={len(rows)}")
    if params.wire_table:
        emit("measure/wire-smallest", params.wire_table[0][1] * 1e6,
             f"fit_lat={params.wire_latency};fit_bw={params.wire_bw}")

    # selection flips: measured tables vs analytic constants
    analytic = PerfModel(TPU_V5E)
    measured = PerfModel(params, decisions=DecisionCache())
    flips = 0
    cases = [(blk, kib) for blk in (8, 64, 512) for kib in (1, 16, 256)]
    for blk, kib in cases:
        count = max(kib * 1024 // blk, 1)
        ct = REG.commit(Vector(count, blk, max(512, 2 * blk), BYTE))
        a = analytic.select(ct).strategy
        m = measured.select(ct).strategy
        flips += a != m
        emit(f"measure/select/blk{blk}/{kib}KiB",
             measured.select(ct).total * 1e6, f"analytic={a};measured={m}")
    emit("measure/selection-flips", float(flips), f"of={len(cases)}")
    # the audit log doubles as the report artifact
    emit("measure/decisions-recorded", float(len(measured.decisions)), "audit")


if __name__ == "__main__":
    run()
