"""Machine-readable perf snapshot: ``BENCH_8.json``.

The CSV suites report human-scannable tables; this suite records the
numbers a perf *trajectory* needs — one JSON file per run, stable keys,
diffable run over run.  Times are CPU-container proxies (see
``benchmarks/common.py``): the values that transfer to TPU are the
byte counts, the relative orderings, and the probe overhead ratios.

Schema (``"format": 1``)::

    {
      "format": 1,                      # bump on incompatible change
      "suite": "snapshot",
      "halo": {                         # the smoother's fused program
        "fingerprint": str,             # program decision key
        "strategy": "program/s=N",      # pinned decision row strategy
        "schedule": str,                # wire schedule the plan chose
        "wire_bytes": int,              # issued bytes per exchange
        "steps": int,                   # fused halo depth s
        "cycle_len": int,
        "pinned": bool                  # True: depth came from the
      },                                #   decisions file, not the model
      "program_iteration": {            # compiled-iteration wall time
        "mean_s": float,                # telemetry window mean
        "p95_s": float,
        "samples": int,
        "predicted_s": float            # model's per-iteration price
      },
      "overlap": {                      # region-split overlap (PR 8)
        "chosen_mode": str,             # what mode="auto" resolved to
        "predicted_s": {                # price_overlap, both modes
          "monolithic": float,
          "region": float
        },
        "iteration_mean_s": {           # wall time per compiled
          "off": float,                 #   iteration, per overlap mode
          "monolithic": float,          #   (all bit-identical; the
          "region": float               #   checksum gate asserts it)
        }
      },
      "probes": {                       # observability self-cost
        "telemetry_overhead": float,    # probe cost / iteration cost
        "trace_overhead": float,
        "budget": float                 # the <2% gate both live under
      }
    }

Run via ``python -m benchmarks.run snapshot`` (writes ``BENCH_8.json``
in the CWD) or ``python -m benchmarks.bench_snapshot --out PATH``.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.bench_measure import (
    TELEMETRY_OVERHEAD_BUDGET,
    telemetry_overhead,
    trace_overhead,
)
from benchmarks.common import emit

SNAPSHOT_FORMAT = 1
SNAPSHOT_FILENAME = "BENCH_8.json"


def snapshot(iters: int = 10) -> dict:
    """Collect the snapshot dict (schema in the module docstring)."""
    from repro.comm.api import Communicator
    from repro.fleet import ExchangeTelemetry
    from repro.launch.smoother import run_smoother
    from repro.measure import DecisionCache

    # two runs over one DecisionCache: the first records the program
    # decision, the second pins it — the snapshot reports the *pinned*
    # path, the steady state a production job lives in
    decisions = DecisionCache()
    tel = ExchangeTelemetry()
    comm = Communicator(
        axis_name="data", decisions=decisions, telemetry=tel
    )
    run_smoother(comm, iters=1, interior=(8, 8, 8), cycle="smooth",
                 halo_steps="auto")
    tel2 = ExchangeTelemetry()
    comm2 = Communicator(
        axis_name="data", decisions=decisions, telemetry=tel2
    )
    report = run_smoother(comm2, iters=iters, interior=(8, 8, 8),
                          cycle="smooth", halo_steps="auto")
    program = report.program
    agg = tel2.get(program.fingerprint)

    # region-split overlap rows: the model's pricing of both modes on
    # this program's exchange, what "auto" resolves to, and per-mode
    # compiled-iteration wall time on the SAME pinned program — the
    # modes are bit-identical, so any spread is pure scheduling
    from repro.halo import overlap_region_descriptors

    core_bytes, rims = overlap_region_descriptors(
        program.spec, program.ops, program.plan.wire
    )
    chosen, ests, _ = comm2.model.choose_overlap_mode(
        program.plan.wire, rims, core_bytes, program.ops[0].nneighbors
    )
    overlap_iter = {}
    checksums = set()
    for m in ("off", "monolithic", "region"):
        telm = ExchangeTelemetry()
        commm = Communicator(
            axis_name="data", decisions=decisions, telemetry=telm
        )
        rep = run_smoother(commm, iters=iters, interior=(8, 8, 8),
                           cycle="smooth", halo_steps="auto", overlap=m)
        aggm = telm.get(rep.program.fingerprint)
        overlap_iter[m] = aggm.mean if aggm else 0.0
        checksums.add(rep.checksum)
    assert len(checksums) == 1, (
        f"overlap modes disagree on the checksum: {checksums}"
    )
    return {
        "format": SNAPSHOT_FORMAT,
        "suite": "snapshot",
        "halo": {
            "fingerprint": program.fingerprint,
            "strategy": f"program/s={program.steps}",
            "schedule": program.plan.wire.schedule,
            "wire_bytes": int(program.plan.wire.issued_bytes),
            "steps": int(program.steps),
            "cycle_len": int(program.cycle_len),
            "pinned": bool(program.pinned),
        },
        "program_iteration": {
            "mean_s": agg.mean if agg else 0.0,
            "p95_s": agg.p95 if agg else 0.0,
            "samples": agg.count if agg else 0,
            "predicted_s": agg.predicted if agg else 0.0,
        },
        "overlap": {
            "chosen_mode": chosen,
            "predicted_s": {
                m: e.t_total for m, e in sorted(ests.items())
            },
            "iteration_mean_s": overlap_iter,
        },
        "probes": {
            "telemetry_overhead": telemetry_overhead(iters=iters),
            "trace_overhead": trace_overhead(iters=iters),
            "budget": TELEMETRY_OVERHEAD_BUDGET,
        },
    }


def run(out: str = SNAPSHOT_FILENAME) -> Path:
    """The ``benchmarks.run snapshot`` entry: write the JSON, echo the
    headline numbers as CSV rows like every other suite."""
    snap = snapshot()
    path = Path(out)
    path.write_text(json.dumps(snap, indent=2, sort_keys=True) + "\n")
    emit("snapshot/halo-wire-bytes", float(snap["halo"]["wire_bytes"]),
         f"{snap['halo']['strategy']};{snap['halo']['schedule']}"
         f";pinned={snap['halo']['pinned']}")
    emit("snapshot/program-iter", snap["program_iteration"]["mean_s"] * 1e6,
         f"samples={snap['program_iteration']['samples']}")
    for m, v in snap["overlap"]["iteration_mean_s"].items():
        emit(f"snapshot/overlap-iter-{m}", v * 1e6,
             f"chosen={snap['overlap']['chosen_mode']}")
    emit("snapshot/telemetry-overhead-pct",
         snap["probes"]["telemetry_overhead"] * 100.0,
         f"budget={snap['probes']['budget'] * 100:.0f}%")
    emit("snapshot/trace-overhead-pct",
         snap["probes"]["trace_overhead"] * 100.0,
         f"budget={snap['probes']['budget'] * 100:.0f}%")
    emit("snapshot/json", 0.0, str(path))
    return path


def main() -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_snapshot",
                                 description=__doc__)
    ap.add_argument("--out", default=SNAPSHOT_FILENAME, metavar="PATH",
                    help=f"where to write the JSON "
                         f"(default: ./{SNAPSHOT_FILENAME})")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out)


if __name__ == "__main__":
    main()
