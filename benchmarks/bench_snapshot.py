"""Machine-readable perf snapshot: ``BENCH_10.json``.

The CSV suites report human-scannable tables; this suite records the
numbers a perf *trajectory* needs — one JSON file per run, stable keys,
diffable run over run.  Times are CPU-container proxies (see
``benchmarks/common.py``): the values that transfer to TPU are the
byte counts, the relative orderings, and the probe overhead ratios.

Schema (``"format": 3``)::

    {
      "format": 3,                      # bump on incompatible change
      "suite": "snapshot",
      "halo": {                         # the smoother's fused program
        "fingerprint": str,             # program decision key
        "strategy": "program/s=N",      # pinned decision row strategy
        "schedule": str,                # wire schedule the plan chose
        "wire_bytes": int,              # issued bytes per exchange
        "steps": int,                   # fused halo depth s
        "cycle_len": int,
        "pinned": bool                  # True: depth came from the
      },                                #   decisions file, not the model
      "program_iteration": {            # compiled-iteration wall time
        "mean_s": float,                # telemetry window mean
        "p95_s": float,
        "samples": int,
        "predicted_s": float            # model's per-iteration price
      },
      "overlap": {                      # region-split overlap (PR 8)
        "chosen_mode": str,             # what mode="auto" resolved to
        "predicted_s": {                # price_overlap, both modes
          "monolithic": float,
          "region": float
        },
        "iteration_mean_s": {           # wall time per compiled
          "off": float,                 #   iteration, per overlap mode
          "monolithic": float,          #   (all bit-identical; the
          "region": float               #   checksum gate asserts it)
        },
        "drift": {                      # measured-vs-pinned audit (PR 9):
          "observed_ratio": float,      #   chosen / best alternative mode
          "margin": float,              #   DEFAULT_OVERLAP_MARGIN
          "drifted": bool,              #   ratio > margin
          "demoted": [str]              #   pins demote_stale_modes pruned
        }
      },
      "scale": {                        # simulated-scale ladder (PR 9):
        "ranks_per_node": int,          #   ci_params + synthetic two-tier
        "flip_ranks": int,              # first rung planning tiered
        "ladder": [{                    # one row per simulated rank count
          "ranks": int, "nodes": int,
          "schedule": str,              # model-cheapest wire schedule
          "costs": {str: float},        # schedule -> predicted seconds
          "wire_bytes": int,
          "correction_bytes": int,      # tiered's extra fast-tier bytes
          "inter_messages": {str: int}  # slow-tier messages per rank
        }]
      },
      "compress": {                     # length-aware wire (PR 10):
        "strategy": str,                #   what the probe selected
        "schedule": str,                #   "varlen" when it truncates
        "capacity_bytes": int,          # stored-mode wire bound
        "stream_bytes": int,            # probed effective bytes moved
        "ratio": float,                 # stream / capacity
        "achieved_ratio_mean": float,   # per-exchange telemetry ring
        "samples": int,
        "exchanges": int,               # Communicator compress counters
        "codec": {str: [{               # measure_compress_table rows
          "log2_total": float,
          "compress_s": float,
          "decompress_s": float,
          "ratio_sample": float
        }]}
      },
      "probes": {                       # observability self-cost
        "telemetry_overhead": float,    # probe cost / iteration cost
        "trace_overhead": float,
        "budget": float                 # the <2% gate both live under
      }
    }

Run via ``python -m benchmarks.run snapshot`` (writes ``BENCH_10.json``
in the CWD) or ``python -m benchmarks.bench_snapshot --out PATH``.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from benchmarks.bench_measure import (
    TELEMETRY_OVERHEAD_BUDGET,
    telemetry_overhead,
    trace_overhead,
)
from benchmarks.common import emit

SNAPSHOT_FORMAT = 3
SNAPSHOT_FILENAME = "BENCH_10.json"

#: the simulated-scale sweep: fixed ranks-per-node, rank counts up to
#: the paper's 3072-process regime (same sweep --assert-scale gates on)
SCALE_RANKS = (8, 16, 64, 256, 1024, 3072)
SCALE_RANKS_PER_NODE = 8


def snapshot(iters: int = 10) -> dict:
    """Collect the snapshot dict (schema in the module docstring)."""
    from repro.comm.api import Communicator
    from repro.fleet import ExchangeTelemetry
    from repro.launch.smoother import run_smoother
    from repro.measure import DecisionCache

    # two runs over one DecisionCache: the first records the program
    # decision, the second pins it — the snapshot reports the *pinned*
    # path, the steady state a production job lives in
    decisions = DecisionCache()
    tel = ExchangeTelemetry()
    comm = Communicator(
        axis_name="data", decisions=decisions, telemetry=tel
    )
    run_smoother(comm, iters=1, interior=(8, 8, 8), cycle="smooth",
                 halo_steps="auto")
    tel2 = ExchangeTelemetry()
    comm2 = Communicator(
        axis_name="data", decisions=decisions, telemetry=tel2
    )
    report = run_smoother(comm2, iters=iters, interior=(8, 8, 8),
                          cycle="smooth", halo_steps="auto")
    program = report.program
    agg = tel2.get(program.fingerprint)

    # region-split overlap rows: the model's pricing of both modes on
    # this program's exchange, what "auto" resolves to, and per-mode
    # compiled-iteration wall time on the SAME pinned program — the
    # modes are bit-identical, so any spread is pure scheduling
    from repro.halo import overlap_region_descriptors

    core_bytes, rims = overlap_region_descriptors(
        program.spec, program.ops, program.plan.wire
    )
    chosen, ests, _ = comm2.model.choose_overlap_mode(
        program.plan.wire, rims, core_bytes, program.ops[0].nneighbors
    )
    overlap_iter = {}
    checksums = set()
    for m in ("off", "monolithic", "region"):
        telm = ExchangeTelemetry()
        commm = Communicator(
            axis_name="data", decisions=decisions, telemetry=telm
        )
        rep = run_smoother(commm, iters=iters, interior=(8, 8, 8),
                           cycle="smooth", halo_steps="auto", overlap=m)
        aggm = telm.get(rep.program.fingerprint)
        overlap_iter[m] = aggm.mean if aggm else 0.0
        checksums.add(rep.checksum)
    assert len(checksums) == 1, (
        f"overlap modes disagree on the checksum: {checksums}"
    )

    # measured-vs-pinned overlap audit: the per-mode wall times just
    # collected are the ground truth the pinned overlap/mode= decision
    # claims to have won — feed them to the drift detector; an
    # out-of-band pin is demoted so the next run re-prices
    from repro.fleet.drift import (
        DEFAULT_OVERLAP_MARGIN,
        DriftDetector,
        demote_stale_modes,
    )

    overlap_rows = [
        d for d in decisions.log if d.strategy.startswith("overlap/mode=")
    ]
    audit = DriftDetector().audit(
        decisions, comm2.model.params, system="snapshot",
        overlap_timings={d.fingerprint: overlap_iter for d in overlap_rows},
    )
    overlap_findings = [
        f for f in audit.findings if f.strategy.startswith("overlap/mode=")
    ]
    demoted = demote_stale_modes(decisions, audit)
    overlap_drift = {
        "observed_ratio": (
            overlap_findings[0].observed_ratio if overlap_findings else 0.0
        ),
        "margin": DEFAULT_OVERLAP_MARGIN,
        "drifted": any(f.drifted for f in overlap_findings),
        "demoted": demoted,
    }

    # the simulated-scale ladder on the checked-in CI tables under a
    # synthetic two-tier topology — the trajectory record of where the
    # schedule flips to tier-coalesced (--assert-scale gates the shape)
    from repro.comm import PerfModel, scale_ladder, synthetic_two_tier
    from repro.measure import load_ci_params

    smodel = PerfModel(synthetic_two_tier(load_ci_params()))
    ladder = scale_ladder(
        smodel, SCALE_RANKS, SCALE_RANKS_PER_NODE, pin=False
    )
    flip = next(
        (e.ranks for e in ladder if e.schedule == "tiered"), 0
    )
    scale = {
        "ranks_per_node": SCALE_RANKS_PER_NODE,
        "flip_ranks": int(flip),
        "ladder": [
            {
                "ranks": e.ranks,
                "nodes": e.nodes,
                "schedule": e.schedule,
                "costs": {s: c for s, c in sorted(e.costs.items())},
                "wire_bytes": int(e.wire_bytes),
                "correction_bytes": int(e.correction_bytes),
                "inter_messages": dict(e.inter_messages),
            }
            for e in ladder
        ],
    }
    # the length-aware compressed wire on the canonical zero-heavy
    # probe: plan with the payload sample, run the varlen exchange a few
    # times eagerly so the compress counters and the achieved-ratio
    # telemetry ring carry real samples, then sweep the codec timings
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import FLOAT, Subarray
    from repro.measure.bench import measure_compress_table

    ctel = ExchangeTelemetry()
    ccomm = Communicator(axis_name="data", telemetry=ctel)
    cct = ccomm.commit(Subarray((32, 32), (16, 16), (4, 4), FLOAT))
    csrc = np.zeros((32, 32), np.float32)
    csrc[10:12, 6:8] = 3.0
    cperms = [[(0, 0)]]
    cstrats, cplan = ccomm.plan_neighbor(
        [cct], cperms, probe=jnp.asarray(csrc)
    )
    cfn = jax.jit(shard_map(
        lambda b: ccomm.neighbor_alltoallv(
            b, [cct], [cct], cperms, plan=cplan, strategies=cstrats
        ),
        mesh=Mesh(np.array(jax.devices()[:1]), ("data",)),
        in_specs=P(), out_specs=P(), check_vma=False,
    ))
    cx = jnp.asarray(csrc)
    for _ in range(iters):
        jax.block_until_ready(cfn(cx))
    cring = ctel.get(f"{cplan.fingerprint}/ratio")
    cstats = ccomm.stats()
    ctable = measure_compress_table(
        total_bytes=(1 << 12, 1 << 16), iters=3
    )
    compress = {
        "strategy": cstrats[0].name,
        "schedule": cplan.schedule,
        "capacity_bytes": int(cplan.wire_bytes),
        "stream_bytes": int(cplan.effective_wire_bytes),
        "ratio": float(cplan.stream_ratio),
        "achieved_ratio_mean": cring.mean if cring else 0.0,
        "samples": cring.count if cring else 0,
        "exchanges": int(cstats["compress_exchanges"]),
        "codec": {
            name: [
                {
                    "log2_total": r[0],
                    "compress_s": r[1],
                    "decompress_s": r[2],
                    "ratio_sample": r[3],
                }
                for r in rows
            ]
            for name, rows in sorted(ctable.items())
        },
    }
    return {
        "format": SNAPSHOT_FORMAT,
        "suite": "snapshot",
        "halo": {
            "fingerprint": program.fingerprint,
            "strategy": f"program/s={program.steps}",
            "schedule": program.plan.wire.schedule,
            "wire_bytes": int(program.plan.wire.issued_bytes),
            "steps": int(program.steps),
            "cycle_len": int(program.cycle_len),
            "pinned": bool(program.pinned),
        },
        "program_iteration": {
            "mean_s": agg.mean if agg else 0.0,
            "p95_s": agg.p95 if agg else 0.0,
            "samples": agg.count if agg else 0,
            "predicted_s": agg.predicted if agg else 0.0,
        },
        "overlap": {
            "chosen_mode": chosen,
            "predicted_s": {
                m: e.t_total for m, e in sorted(ests.items())
            },
            "iteration_mean_s": overlap_iter,
            "drift": overlap_drift,
        },
        "scale": scale,
        "compress": compress,
        "probes": {
            "telemetry_overhead": telemetry_overhead(iters=iters),
            "trace_overhead": trace_overhead(iters=iters),
            "budget": TELEMETRY_OVERHEAD_BUDGET,
        },
    }


def run(out: str = SNAPSHOT_FILENAME) -> Path:
    """The ``benchmarks.run snapshot`` entry: write the JSON, echo the
    headline numbers as CSV rows like every other suite."""
    snap = snapshot()
    path = Path(out)
    path.write_text(json.dumps(snap, indent=2, sort_keys=True) + "\n")
    emit("snapshot/halo-wire-bytes", float(snap["halo"]["wire_bytes"]),
         f"{snap['halo']['strategy']};{snap['halo']['schedule']}"
         f";pinned={snap['halo']['pinned']}")
    emit("snapshot/program-iter", snap["program_iteration"]["mean_s"] * 1e6,
         f"samples={snap['program_iteration']['samples']}")
    for m, v in snap["overlap"]["iteration_mean_s"].items():
        emit(f"snapshot/overlap-iter-{m}", v * 1e6,
             f"chosen={snap['overlap']['chosen_mode']}")
    od = snap["overlap"]["drift"]
    emit("snapshot/overlap-drift-ratio", od["observed_ratio"],
         f"margin={od['margin']};drifted={od['drifted']}"
         f";demoted={len(od['demoted'])}")
    emit("snapshot/scale-flip-ranks", float(snap["scale"]["flip_ranks"]),
         f"ranks_per_node={snap['scale']['ranks_per_node']}")
    for row in snap["scale"]["ladder"]:
        emit(f"snapshot/scale-{row['ranks']}",
             row["costs"][row["schedule"]] * 1e6,
             f"schedule={row['schedule']};nodes={row['nodes']}"
             f";inter={row['inter_messages'].get('tiered', 0)}")
    cm = snap["compress"]
    emit("snapshot/compress-stream-bytes", float(cm["stream_bytes"]),
         f"capacity={cm['capacity_bytes']};schedule={cm['schedule']}"
         f";strategy={cm['strategy']}")
    emit("snapshot/compress-ratio", cm["ratio"],
         f"achieved={cm['achieved_ratio_mean']:.4f}"
         f";samples={cm['samples']}")
    for name, rows in cm["codec"].items():
        emit(f"snapshot/compress-codec-{name}",
             rows[-1]["compress_s"] * 1e6,
             f"log2n={rows[-1]['log2_total']:.0f}"
             f";decode_us={rows[-1]['decompress_s'] * 1e6:.2f}"
             f";ratio={rows[-1]['ratio_sample']:.4f}")
    emit("snapshot/telemetry-overhead-pct",
         snap["probes"]["telemetry_overhead"] * 100.0,
         f"budget={snap['probes']['budget'] * 100:.0f}%")
    emit("snapshot/trace-overhead-pct",
         snap["probes"]["trace_overhead"] * 100.0,
         f"budget={snap['probes']['budget'] * 100:.0f}%")
    emit("snapshot/json", 0.0, str(path))
    return path


def main() -> None:
    ap = argparse.ArgumentParser(prog="benchmarks.bench_snapshot",
                                 description=__doc__)
    ap.add_argument("--out", default=SNAPSHOT_FILENAME, metavar="PATH",
                    help=f"where to write the JSON "
                         f"(default: ./{SNAPSHOT_FILENAME})")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(args.out)


if __name__ == "__main__":
    main()
