"""Benchmark harness entry point (deliverable d).

One module per paper table/figure; every row is ``name,us_per_call,
derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run \
        [fig6|fig7|fig9|fig12|measure|snapshot]

``snapshot`` additionally writes the machine-readable ``BENCH_10.json``
perf snapshot (schema: ``benchmarks/bench_snapshot.py``).
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    from benchmarks import (
        bench_commit,
        bench_halo,
        bench_measure,
        bench_pack,
        bench_send_model,
        bench_snapshot,
    )

    suites = {
        "fig6": bench_commit.run,
        "fig7": bench_pack.run,        # + fig8
        "fig9": bench_send_model.run,  # + fig10/11
        "fig12": bench_halo.run,
        "measure": bench_measure.run,
        "snapshot": bench_snapshot.run,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if which not in ("all", name):
            continue
        try:
            fn()
        except Exception:
            failures += 1
            print(f"{name}/SUITE-FAILED,0,", file=sys.stdout)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
