"""Fig. 9/10/11: transfer-primitive model + strategy selection.

Fig. 9 analogue — the modeled link terms for each strategy over object
sizes (analytic v5e table; the paper's measured Summit curves play this
role).

Fig. 10 analogue — pack/unpack cost per strategy over (object size x
contiguous block size), from the §5 model and cross-checked with
measured CPU-interpret kernel times.

Fig. 11 analogue — model-based automatic selection: for each datatype,
the strategy the model picks, its modeled end-to-end latency vs the
best/worst alternative, and the selection overhead (cached and
uncached).
"""

from __future__ import annotations

import time

from benchmarks.common import emit, time_host_us
from repro.comm import default_registry
from repro.comm.api import ROWS
from repro.comm.perfmodel import PerfModel, TPU_V5E
from repro.core import BYTE, TypeRegistry, Vector

REG = TypeRegistry()
STRATEGIES = default_registry()
PITCH = 512


def run() -> None:
    model = PerfModel(TPU_V5E)

    # Fig. 9: link terms
    for kib in (1, 64, 1024, 4096):
        n = kib * 1024
        emit(f"fig9/link/{kib}KiB", model.t_link(n) * 1e6, "modeled_tpu")

    # Fig. 10: pack/unpack per registered kernel strategy over
    # (size x block)
    for kib in (1, 64, 1024):
        for blk in (8, 32, 128, 512):
            count = max(kib * 1024 // blk, 1)
            ct = REG.commit(Vector(count, blk, max(PITCH, 2 * blk), BYTE))
            for strat in STRATEGIES.measurable():
                emit(
                    f"fig10/pack/{kib}KiB/blk{blk}/{strat.name}",
                    strat.model_pack(model, ct, 1) * 1e6,
                    "modeled_tpu",
                )
            emit(
                f"fig10/unpack/{kib}KiB/blk{blk}/{ROWS.name}",
                ROWS.model_unpack(model, ct, 1) * 1e6,
                "modeled_tpu",
            )

    # Fig. 11: automatic selection quality + overhead over every
    # applicable registered strategy
    for kib, blk in ((1, 8), (1, 512), (1024, 8), (1024, 512), (4096, 32)):
        count = max(kib * 1024 // blk, 1)
        ct = REG.commit(Vector(count, blk, max(PITCH, 2 * blk), BYTE))
        ests = {
            s.name: s.plan(model, ct, 1).total
            for s in STRATEGIES.selectable()
            if s.applicable(ct)
        }
        pick = model.select(ct)
        best = min(ests.values())
        worst = max(ests.values())
        emit(
            f"fig11/select/{kib}KiB/blk{blk}",
            pick.total * 1e6,
            f"picked={pick.strategy};best_us={best*1e6:.1f};"
            f"worst_us={worst*1e6:.1f};optimal={pick.total <= best * 1.001}",
        )

    # selection overhead: cold vs cached (paper: 277 ns)
    ct = REG.commit(Vector(128, 64, 512, BYTE))
    model2 = PerfModel(TPU_V5E)
    us_cold = time_host_us(lambda: PerfModel(TPU_V5E).select(ct), iters=200)
    us_hot = time_host_us(lambda: model2.select(ct), iters=10000)
    emit("fig11/select-overhead/cold", us_cold, "host")
    emit("fig11/select-overhead/cached", us_hot, "host")


if __name__ == "__main__":
    run()
