"""Fig. 6: datatype create + commit time for equivalent 3D objects.

Four constructions of the paper's Fig. 1 cuboid — subarray, hvector of
vector, hvector of hvector of vector, subarray of vector — timed
separately for "create" (describe the type) and "commit" (translate +
canonicalize + kernel select, cached).  Pure host code: these numbers
are directly comparable to the paper's (no device involved).
"""

from __future__ import annotations

from benchmarks.common import emit, time_host_us
from repro.core import (
    BYTE,
    Hvector,
    Subarray,
    TypeRegistry,
    Vector,
)

ALLOC = (256, 512, 1024)
EXT = (100, 13, 47)


def construct_subarray():
    return Subarray(ALLOC, EXT, (0, 0, 0), BYTE)


def construct_hvec_vec():
    row = Vector(EXT[0], 1, 1, BYTE)
    plane = Hvector(EXT[1], 1, ALLOC[0], row)
    return Hvector(EXT[2], 1, ALLOC[0] * ALLOC[1], plane)


def construct_hvec_hvec_vec():
    row = Vector(EXT[0], 1, 1, BYTE)
    plane = Hvector(EXT[1], 1, ALLOC[0], row)
    cuboid = Hvector(EXT[2], 1, ALLOC[0] * ALLOC[1], plane)
    return cuboid


def construct_sub_of_vec():
    plane = Subarray(ALLOC[:2], EXT[:2], (0, 0), BYTE)
    return Vector(EXT[2], 1, 1, plane)


CASES = {
    "subarray": construct_subarray,
    "hvec(vec)": construct_hvec_vec,
    "hvec(hvec(vec))": construct_hvec_hvec_vec,
    "sub(vec)": construct_sub_of_vec,
}


def run() -> None:
    for name, make in CASES.items():
        us_create = time_host_us(make, iters=2000)
        emit(f"fig6/create/{name}", us_create, "host")

        def commit_fresh(make=make):
            # fresh registry per call: measures the full translate +
            # canonicalize + kernel-select pipeline (cache miss)
            TypeRegistry().commit(make())

        us_commit = time_host_us(commit_fresh, iters=500)
        emit(f"fig6/commit/{name}", us_commit, "host,cache-miss")

        reg = TypeRegistry()
        dt = make()
        reg.commit(dt)

        def commit_cached(reg=reg, dt=dt):
            reg.commit(dt)

        us_hit = time_host_us(commit_cached, iters=5000)
        emit(f"fig6/commit-cached/{name}", us_hit, "host,cache-hit")


if __name__ == "__main__":
    run()
