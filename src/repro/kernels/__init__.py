"""repro.kernels — Pallas TPU pack/unpack kernels for canonical
StridedBlocks (paper §3.3), with ops.py wrappers and ref.py oracles."""

from repro.kernels.geometry import PackGeometry, plan_geometry
from repro.kernels.ops import (
    byte_view,
    default_strategy,
    pack,
    unpack,
)

__all__ = [
    "PackGeometry",
    "plan_geometry",
    "byte_view",
    "default_strategy",
    "pack",
    "unpack",
]
