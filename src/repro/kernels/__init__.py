"""repro.kernels — Pallas TPU pack/unpack kernels for canonical
StridedBlocks (paper §3.3), with ops.py wrappers and ref.py oracles."""

# import the kernel submodules BEFORE re-exporting ops' pack/unpack
# functions: `repro.kernels.pack`/`.unpack` are also module names, and a
# first-time submodule import would otherwise clobber the function
# bindings on the package.
from repro.kernels import pack as _pack_kernels  # noqa: F401
from repro.kernels import unpack as _unpack_kernels  # noqa: F401
from repro.kernels.geometry import PackGeometry, plan_geometry
from repro.kernels.ops import (
    byte_view,
    default_strategy,
    pack,
    unpack,
)

__all__ = [
    "PackGeometry",
    "plan_geometry",
    "byte_view",
    "default_strategy",
    "pack",
    "unpack",
]
