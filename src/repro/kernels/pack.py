"""Pallas TPU pack kernels (paper §3.3, TPU-adapted).

Two generic kernels cover every canonical 2D/3D StridedBlock — mirroring
the paper's claim that "each MPI datatype is mapped to one of two kernel
implementations parameterized by W":

* ``pack_rows``  — *pitched row kernel.*  The flat buffer is viewed as a
  ``(rows, pitch)`` 2D array (pitch = strides[1]/W); the BlockSpec index
  map jumps straight to each block's row-group, so Pallas's automatic
  double-buffered pipeline streams HBM->VMEM.  Reads the full pitch
  (over-fetch factor pitch/lanes) — cheap when blocks are a large
  fraction of the pitch.

* ``pack_dma``   — *strided descriptor kernel.*  The source stays in
  HBM (memory_space=ANY) and each grid step issues one strided DMA for
  exactly the bytes of a row-chunk of blocks.  No over-fetch, but the
  copies are manually synchronized (single-buffered v1).  Preferred for
  small blocks at large strides — the regime where the paper's Fig. 10
  shows naive methods collapsing.

The runtime performance model (``repro.comm.perfmodel``) chooses between
them, as the paper chooses between one-shot/device/staged.

Both kernels are parameterized by host scalars only — **no per-type
metadata is stored in device memory** (the paper's key property of the
canonical representation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.geometry import PackGeometry

__all__ = [
    "pack_rows",
    "pack_dma",
    "pack_ragged",
    "pack_compress_ragged",
    "choose_chunk",
]

# pinned-JAX compat: the memory-space enum was renamed
# TPUMemorySpace -> MemorySpace in newer Pallas releases
_MemorySpace = getattr(pltpu, "MemorySpace", None) or pltpu.TPUMemorySpace


# ---------------------------------------------------------------------------
# ragged wire assembly
# ---------------------------------------------------------------------------

def pack_ragged(buf: jax.Array, leaves, total: int) -> jax.Array:
    """Scatter packed leaves directly into a flat wire buffer.

    ``leaves`` is a sequence of ``(offset, pack_fn)`` pairs: ``pack_fn``
    produces one leaf's packed ``uint8`` payload from ``buf`` (any of
    the strategy pack kernels above, already specialized), and the
    payload lands at its exact byte ``offset`` in a ``uint8[total]``
    buffer.  Offsets come from a wire plan's
    :class:`~repro.core.commit.WireSegment` descriptors — the buffer is
    exactly ``sum(segment extents)`` bytes, with no per-class padding
    rows and no intermediate per-destination concatenation.
    """
    wire = jnp.zeros((total,), jnp.uint8)
    for offset, pack_fn in leaves:
        wire = jax.lax.dynamic_update_slice(wire, pack_fn(buf), (offset,))
    return wire


def pack_compress_ragged(buf: jax.Array, leaves, total: int) -> jax.Array:
    """Fused pack+compress wire assembly.

    Like :func:`pack_ragged`, but each leaf is ``(offset, pack_fn,
    encode_fn)``: the gathered member bytes flow straight through the
    leaf's wire encoder (``encode_fn``, e.g.
    :meth:`repro.comm.compress.RleWire.encode_wire`) inside the same
    traced expression — compression adds no extra materialized pass
    over the buffer.  ``encode_fn=None`` means the wire format *is* the
    packed bytes (the uncompressed strategies), degenerating to
    :func:`pack_ragged` exactly.
    """
    wire = jnp.zeros((total,), jnp.uint8)
    for offset, pack_fn, encode_fn in leaves:
        part = pack_fn(buf)
        if encode_fn is not None:
            part = encode_fn(part)
        wire = jax.lax.dynamic_update_slice(wire, part, (offset,))
    return wire


# ---------------------------------------------------------------------------
# pitched row kernel
# ---------------------------------------------------------------------------

def _pack_rows_kernel(src_ref, out_ref, *, r: int, lanes: int):
    # src_ref: (G, pitch) VMEM tile of full-pitch rows
    # out_ref: (1, G, lanes) packed tile
    out_ref[0] = src_ref[:, r : r + lanes]


def pack_rows(src2d: jax.Array, geom: PackGeometry, interpret: bool = False):
    """Pack via pitched BlockSpec row-groups.

    ``src2d`` is the W-word view reshaped to (rows_padded, pitch).
    Returns the packed array of shape (planes, rows, lanes).
    """
    g = geom.group
    qb = geom.q // g
    prb = geom.plane_rows // g if geom.plane_rows else 0

    return pl.pallas_call(
        functools.partial(_pack_rows_kernel, r=geom.r, lanes=geom.lanes),
        grid=(geom.planes, geom.rows // g),
        in_specs=[
            pl.BlockSpec((g, geom.pitch), lambda p, i: (qb + p * prb + i, 0))
        ],
        out_specs=pl.BlockSpec((1, g, geom.lanes), lambda p, i: (p, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (geom.planes, geom.rows, geom.lanes), src2d.dtype
        ),
        interpret=interpret,
    )(src2d)


# ---------------------------------------------------------------------------
# strided-descriptor DMA kernel
# ---------------------------------------------------------------------------

def choose_chunk(rows: int, lanes: int, word: int, budget: int) -> int:
    """Rows of blocks per DMA step: largest divisor of ``rows`` from a
    pow2 ladder whose (chunk, lanes) scratch fits the VMEM budget."""
    for c in (512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if rows % c == 0 and c * lanes * word <= budget:
            return c
    return 1


def _pack_dma_kernel(
    src_ref, out_ref, scratch, sem, *, q, r, plane_rows, chunk, lanes
):
    p = pl.program_id(0)
    ib = pl.program_id(1)
    row0 = q + p * plane_rows + ib * chunk
    cp = pltpu.make_async_copy(
        src_ref.at[pl.ds(row0, chunk), pl.ds(r, lanes)], scratch, sem
    )
    cp.start()
    cp.wait()
    out_ref[0] = scratch[...]


def pack_dma(
    src2d: jax.Array,
    geom: PackGeometry,
    vmem_budget: int,
    interpret: bool = False,
):
    """Pack via one strided DMA per row-chunk; fetches exactly the block
    bytes (no pitch over-fetch).  ``src2d`` as in :func:`pack_rows`."""
    chunk = choose_chunk(geom.rows, geom.lanes, geom.word_bytes, vmem_budget)
    kern = functools.partial(
        _pack_dma_kernel,
        q=geom.q,
        r=geom.r,
        plane_rows=geom.plane_rows,
        chunk=chunk,
        lanes=geom.lanes,
    )
    return pl.pallas_call(
        kern,
        grid=(geom.planes, geom.rows // chunk),
        in_specs=[pl.BlockSpec(memory_space=_MemorySpace.ANY)],
        out_specs=pl.BlockSpec((1, chunk, geom.lanes), lambda p, i: (p, i, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (geom.planes, geom.rows, geom.lanes), src2d.dtype
        ),
        scratch_shapes=[
            pltpu.VMEM((chunk, geom.lanes), src2d.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(src2d)
