"""Kernel geometry planning: StridedBlock -> TPU grid/BlockSpec parameters.

This is the TPU adaptation of the paper's §3.3 kernel selection.  On CUDA
the paper maps counts[0..2] to thread-block X/Y/Z and specializes a word
size W.  On TPU the equivalents are:

* word width W  -> re-view the byte buffer as uint{8,16,32}[.] so the
  128-lane axis moves W bytes per lane (``repro.kernels.ops``);
* thread grid   -> a Pallas grid over (planes, row-groups) with BlockSpec
  index maps that jump by the block stride — possible *because* the
  canonical StridedBlock has regular scalar strides (no per-block
  metadata, the paper's key property);
* block size    -> a row-group G (sublane dimension) chosen so the VMEM
  working set fits and G | rows.

All planning happens on host scalars at commit time; nothing here touches
device memory.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.strided_block import StridedBlock

__all__ = ["PackGeometry", "plan_geometry", "VMEM_BUDGET_BYTES"]

# Per-kernel-step VMEM working-set budget (v5e has 16 MiB less framework
# reserves; stay comfortably below half).
VMEM_BUDGET_BYTES = 4 * 1024 * 1024


@dataclass(frozen=True)
class PackGeometry:
    """Scalar parameters of the strided pack/unpack kernels.

    All units are W-byte words unless suffixed ``_bytes``.  The source is
    reshaped to a (row-pitch) 2D view ``(R, pitch)``; block ``(p, i)``'s
    first word then lives at row ``q + p*plane_rows + i`` column ``r``.
    """

    word_bytes: int      # W
    lanes: int           # counts[0] // W — words per contiguous block
    rows: int            # counts[1]     — blocks per plane
    planes: int          # counts[2]     — plane count (1 for 2D)
    pitch: int           # strides[1] // W
    q: int               # start row of the 2D view
    r: int               # column offset within a row
    plane_rows: int      # strides[2] // strides[1] (0 for 2D)
    group: int           # G: rows handled per grid step
    rows_padded: int     # 2D-view rows after tail padding (multiple of G)

    @property
    def out_words(self) -> int:
        return self.planes * self.rows * self.lanes

    @property
    def grid(self):
        return (self.planes, self.rows // self.group)

    @property
    def overfetch(self) -> float:
        """HBM words fetched per useful word (row-kernel reads the full
        pitch).  Feeds the §5 performance model."""
        return self.pitch / max(self.lanes, 1)


def _choose_group(rows: int, q: int, plane_rows: int, pitch: int, word: int) -> int:
    """Largest G in {64..1} with G | rows, G | q, G | plane_rows, and a
    G*pitch working set within the VMEM budget."""
    for g in (64, 32, 16, 8, 4, 2, 1):
        if rows % g or q % g or (plane_rows % g if plane_rows else 0):
            continue
        if g * pitch * word <= VMEM_BUDGET_BYTES:
            return g
    return 1


def plan_geometry(
    sb: StridedBlock,
    word_bytes: Optional[int] = None,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> Optional[PackGeometry]:
    """Plan the aligned row-kernel geometry for a 2D/3D StridedBlock.

    Returns None when the aligned path does not apply; callers fall back
    to the generic gather path.  Conditions (each checked on host
    scalars):

    * 2 <= ndims <= 3
    * W | start, strides, counts[0] (guaranteed by word_bytes selection)
    * the contiguous block does not straddle a pitch boundary
    * 3D: the plane stride is a whole number of pitches
    * one pitch row fits in VMEM
    """
    if sb.ndims not in (2, 3):
        return None
    w = sb.word_bytes(max_word=4) if word_bytes is None else word_bytes
    c0, c1 = sb.counts[0], sb.counts[1]
    s1 = sb.strides[1]
    c2 = sb.counts[2] if sb.ndims == 3 else 1
    s2 = sb.strides[2] if sb.ndims == 3 else 0

    if s1 % w or sb.start % w or c0 % w or (s2 % w):
        return None
    lanes, pitch = c0 // w, s1 // w
    q, r = (sb.start // w) // pitch, (sb.start // w) % pitch
    if r + lanes > pitch:
        return None  # block straddles a pitch row
    if sb.ndims == 3:
        if s2 % s1:
            return None  # plane stride not a whole number of rows
        plane_rows = s2 // s1
    else:
        plane_rows = 0
    if pitch * w > vmem_budget:
        return None  # a single pitch row blows the VMEM budget

    g = _choose_group(c1, q, plane_rows, pitch, w)
    rows_needed = q + (c2 - 1) * plane_rows + c1
    rows_padded = math.ceil(rows_needed / g) * g
    return PackGeometry(
        word_bytes=w,
        lanes=lanes,
        rows=c1,
        planes=c2,
        pitch=pitch,
        q=q,
        r=r,
        plane_rows=plane_rows,
        group=g,
        rows_padded=rows_padded,
    )
