"""Public pack/unpack operations: jit'd wrappers + plan caching.

This is TEMPI's ``MPI_Pack``/``MPI_Unpack`` (paper §6.2) for JAX arrays.
The committed type's canonical StridedBlock drives everything:

    kind CONTIG     -> one contiguous copy (cudaMemcpyAsync analogue)
    kind KERNEL_2D/3D -> Pallas kernel, chosen by the strategy plugin
    kind KERNEL_ND  -> python loop of 3D kernels over the outer dims
    kind GENERIC or unplannable geometry -> gather fallback (ref path)

``incount`` repeats the datatype at ``extent`` strides, handled as an
extra outer dimension exactly as the paper describes (§3.3 last ¶).

Strategy *dispatch* lives in ``repro.comm.api`` (the strategy registry);
this module owns the strategy-independent machinery: plan caching, the
1D fast paths, repetition loops, and the word-view plumbing the strategy
kernels share.  ``strategy`` arguments accept a Strategy object, a
registered name, or None (the static-auto heuristic).

Buffers can be any dtype/shape; they are re-viewed as bytes and then as
W-byte words (the paper's word-size specialization) without copying.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.commit import CommittedType, KernelKind
from repro.core.strided_block import StridedBlock
from repro.kernels import ref as refk
from repro.kernels.geometry import PackGeometry, plan_geometry

__all__ = [
    "byte_view",
    "unbyte_view",
    "as_words",
    "words_to_bytes",
    "pack",
    "unpack",
    "pack_block",
    "run_pack_kernel",
    "run_unpack_kernel",
    "default_strategy",
    "shifted_window_sum",
    "stencil_window_update",
    "stencil_window_chain",
    "STRATEGIES",
]

_UINT = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}

#: geometry plan cache — the paper's §4 "caching layer": keyed by the
#: committed type's content fingerprint + incount, so repeated
#: Pack/Unpack of the same structure re-dispatch in a dict lookup.
_PLAN_CACHE: Dict[Tuple[str, int], Optional["_Plan"]] = {}


def _resolve(strategy):
    from repro.comm.api import resolve_strategy

    return resolve_strategy(strategy)


def __getattr__(name):
    if name == "STRATEGIES":
        # legacy constant: the registered strategy names (now sourced
        # from the registry so plugins appear automatically)
        from repro.comm.api import default_registry

        return default_registry().names()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def default_strategy(geom: Optional[PackGeometry]) -> str:
    """Name of the kernel the static geometry heuristic picks (the
    calibrated model refines this crossover)."""
    from repro.comm.api import static_choice

    return static_choice(geom).name


def _interpret_default() -> bool:
    # Pallas TPU kernels run in interpret mode anywhere but real TPUs.
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# shifted-window stencil primitives (per-dimension radii)
# ---------------------------------------------------------------------------
#
# The halo layer's stencil kernels are all instances of one operation:
# accumulate dynamic slices of an N-D array shifted by a set of offsets,
# over a window whose origin/shape the caller picks.  Keeping the
# primitive here (rather than inside repro.halo) lets every consumer —
# full-allocation applications, shrinking-region deep-halo steps, and
# the dense interior chain of the overlap pipeline — share one
# accumulation order, which is what makes their results bit-identical
# on the overlapping regions.

def shifted_window_sum(arr, offsets, origin, shape):
    """Sum of ``arr`` windows at ``origin + d`` for each offset ``d``.

    Offsets may be negative; the caller guarantees every shifted window
    stays in bounds.  Accumulation is in ``offsets`` order, so two calls
    with the same offsets and values produce bit-identical results.
    """
    acc = jnp.zeros(shape, arr.dtype)
    for d in offsets:
        acc = acc + jax.lax.dynamic_slice(
            arr, tuple(o + di for o, di in zip(origin, d)), shape
        )
    return acc


def stencil_window_update(arr, offsets, weight, origin, shape):
    """One weighted-neighborhood stencil update of the window
    ``arr[origin : origin + shape]``:

        new = (1 - w) * center + (w / len(offsets)) * sum(shifted views)

    Returns the updated window only (the caller splices it back, or uses
    it directly as a deep-interior block).  ``offsets`` carries the
    per-dimension stencil radii implicitly — any box neighborhood,
    symmetric or not, is just a different offset list.
    """
    w = jnp.asarray(weight, arr.dtype)
    acc = shifted_window_sum(arr, offsets, origin, shape)
    center = jax.lax.dynamic_slice(arr, tuple(origin), shape)
    return (1 - w) * center + (w / len(offsets)) * acc


def stencil_window_chain(arr, stages):
    """Apply a *sequence* of stencil window updates, each stage consuming
    the previous stage's window: stage ``(offsets, weight, radii)``
    shrinks the current window by ``radii`` per side and applies
    :func:`stencil_window_update` to it.  Returns every intermediate
    block, so the caller can splice each one over its region of a wider
    computation (the deep-interior overlap chain does exactly that).

    The stages need not share radii — a heterogeneous op cycle (e.g. a
    predictor/corrector pair) is just a different stage list.  Because
    every stage goes through the same primitive, the chain's blocks are
    bit-identical to the matching regions of the full-allocation path.
    """
    blocks = []
    x = arr
    for k, (offsets, weight, radii) in enumerate(stages):
        shape = tuple(s - 2 * r for s, r in zip(x.shape, radii))
        if any(s < 1 for s in shape):
            raise ValueError(
                f"window {arr.shape} too small for stage {k + 1} of the "
                f"chain (radii {tuple(radii)})"
            )
        x = stencil_window_update(x, offsets, weight, tuple(radii), shape)
        blocks.append(x)
    return blocks


# ---------------------------------------------------------------------------
# byte / word re-viewing (no data movement under XLA)
# ---------------------------------------------------------------------------

def byte_view(arr: jax.Array) -> jax.Array:
    """Flat uint8 view of any (non-bool) array's underlying bytes."""
    if arr.dtype == jnp.bool_:
        raise TypeError("bool buffers are not byte-addressable; cast first")
    flat = arr.reshape(-1)
    if arr.dtype == jnp.uint8:
        return flat
    return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)


def unbyte_view(b: jax.Array, dtype, shape) -> jax.Array:
    """Inverse of :func:`byte_view`."""
    if dtype == jnp.uint8:
        return b.reshape(shape)
    w = jnp.dtype(dtype).itemsize
    return jax.lax.bitcast_convert_type(b.reshape(-1, w), dtype).reshape(shape)


def as_words(b: jax.Array, w: int) -> jax.Array:
    """uint8[n] -> uintW[n/w] (n already padded to a multiple of w)."""
    if w == 1:
        return b
    return jax.lax.bitcast_convert_type(b.reshape(-1, w), _UINT[w])


def words_to_bytes(x: jax.Array) -> jax.Array:
    w = x.dtype.itemsize
    if w == 1:
        return x.reshape(-1)
    return jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

class _Plan:
    """Host-side execution plan for one (committed type, incount)."""

    __slots__ = ("sb", "reps", "rep_extent", "geom", "kind")

    def __init__(self, ct: CommittedType, incount: int):
        sb = ct.block
        self.kind = ct.kernel
        self.reps = 1
        self.rep_extent = ct.extent
        if sb is not None and incount > 1:
            if sb.ndims == 1:
                if ct.extent == sb.counts[0] and sb.start == 0:
                    # contiguous repetitions stay contiguous
                    sb = StridedBlock(0, (sb.counts[0] * incount,), (1,))
                else:
                    sb = StridedBlock(
                        sb.start,
                        (sb.counts[0], incount),
                        (1, ct.extent),
                    )
            elif sb.ndims == 2:
                sb = StridedBlock(
                    sb.start,
                    sb.counts + (incount,),
                    sb.strides + (ct.extent,),
                )
            else:
                # 3D+ repeated: loop reps on host (paper: "handled
                # dynamically" — known only at the call site)
                self.reps = incount
        self.sb = sb
        self.geom = (
            plan_geometry(sb) if sb is not None and sb.ndims in (2, 3) else None
        )


def _plan(ct: CommittedType, incount: int) -> _Plan:
    # content-fingerprint key: id(ct) can be recycled after a committed
    # type is garbage-collected, silently serving a stale plan for a
    # structurally different type; equal structures share a plan instead
    key = (ct.fingerprint, incount)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = _Plan(ct, incount)
        _PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# shared word-view plumbing for the Pallas strategy kernels
# ---------------------------------------------------------------------------

def _prep_words(b: jax.Array, geom: PackGeometry) -> jax.Array:
    """bytes -> padded (rows_padded, pitch) word view."""
    w = geom.word_bytes
    n = b.shape[0]
    need_bytes = geom.rows_padded * geom.pitch * w
    if n % w or n < need_bytes:
        pad = max(need_bytes, ((n + w - 1) // w) * w) - n
        b = jnp.pad(b, (0, pad))
    words = as_words(b, w)
    words = words[: geom.rows_padded * geom.pitch]
    return words.reshape(geom.rows_padded, geom.pitch)


def run_pack_kernel(b: jax.Array, geom: PackGeometry, kernel, interpret: bool):
    """Drive a (src2d, geom, interpret) -> (planes, rows, lanes) pack
    kernel through the shared word-view prep, returning packed bytes."""
    src2d = _prep_words(b, geom)
    out = kernel(src2d, geom, interpret=interpret)
    return words_to_bytes(out.reshape(-1))


def run_unpack_kernel(
    b: jax.Array, packed: jax.Array, geom: PackGeometry, kernel, interpret: bool
):
    """Drive a (dst2d, pk3, geom, interpret) -> dst2d unpack kernel:
    word-view prep, kernel, and tail reassembly for bytes the 2D view
    does not cover."""
    n = b.shape[0]
    covered = geom.rows_padded * geom.pitch * geom.word_bytes
    dst2d = _prep_words(b, geom)
    pk3 = as_words(packed, geom.word_bytes).reshape(
        geom.planes, geom.rows, geom.lanes
    )
    out2d = kernel(dst2d, pk3, geom, interpret=interpret)
    out_b = words_to_bytes(out2d.reshape(-1))
    if covered >= n:
        return out_b[:n]
    # the 2D word view only covers the strided region; keep the tail
    return jnp.concatenate([out_b, b[covered:]])


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def _pack_one(
    b: jax.Array, plan: _Plan, strat, interpret: bool, base: int
) -> jax.Array:
    """Pack one repetition (byte offsets shifted by ``base``)."""
    sb = plan.sb
    if base:
        sb = StridedBlock(sb.start + base, sb.counts, sb.strides)
    if sb.ndims == 1:
        return jax.lax.dynamic_slice(b, (sb.start,), (sb.counts[0],))
    geom = plan_geometry(sb) if base else plan.geom
    return strat.pack_leaf(b, sb, geom, interpret)


def pack(
    buf: jax.Array,
    ct: CommittedType,
    incount: int = 1,
    strategy=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """MPI_Pack: gather the non-contiguous bytes ``ct`` describes from
    ``buf`` into a contiguous uint8 buffer of ``ct.size * incount``."""
    strat = _resolve(strategy)
    if interpret is None:
        interpret = _interpret_default()
    plan = _plan(ct, incount)
    b = byte_view(buf)
    if plan.kind is KernelKind.GENERIC or plan.sb is None:
        return refk.pack_ref(b, ct.block, incount, ct.extent)  # pragma: no cover
    if plan.reps == 1:
        return _pack_one(b, plan, strat, interpret, 0)
    parts = [
        _pack_one(b, plan, strat, interpret, r * plan.rep_extent)
        for r in range(plan.reps)
    ]
    return jnp.concatenate(parts)


def pack_block(
    buf: jax.Array,
    sb: StridedBlock,
    strategy=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Low-level pack straight from a StridedBlock (no committed type).

    Used by the comm layer for shifted/derived blocks (e.g. extracting
    member bytes out of a received bounding window)."""
    strat = _resolve(strategy)
    if interpret is None:
        interpret = _interpret_default()
    b = byte_view(buf)
    if sb.ndims == 1:
        return jax.lax.dynamic_slice(b, (sb.start,), (sb.counts[0],))
    return strat.pack_leaf(b, sb, plan_geometry(sb), interpret)


def _unpack_one(
    b: jax.Array,
    packed: jax.Array,
    plan: _Plan,
    strat,
    interpret: bool,
    base: int,
) -> jax.Array:
    sb = plan.sb
    if base:
        sb = StridedBlock(sb.start + base, sb.counts, sb.strides)
    if sb.ndims == 1:
        return jax.lax.dynamic_update_slice(b, packed, (sb.start,))
    geom = plan_geometry(sb) if base else plan.geom
    return strat.unpack_leaf(b, packed, sb, geom, interpret)


def unpack(
    buf: jax.Array,
    packed: jax.Array,
    ct: CommittedType,
    incount: int = 1,
    strategy=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """MPI_Unpack: scatter ``packed`` (uint8[size*incount]) into ``buf``
    per the committed datatype; returns the updated buffer (same
    shape/dtype as ``buf``)."""
    strat = _resolve(strategy)
    if interpret is None:
        interpret = _interpret_default()
    plan = _plan(ct, incount)
    b = byte_view(buf)
    packed = byte_view(packed)
    if plan.kind is KernelKind.GENERIC or plan.sb is None:  # pragma: no cover
        out = refk.unpack_ref(b, packed, ct.block, incount, ct.extent)
        return unbyte_view(out, buf.dtype, buf.shape)
    if plan.reps == 1:
        out = _unpack_one(b, packed, plan, strat, interpret, 0)
    else:
        out = b
        step = plan.sb.size
        for rep in range(plan.reps):
            out = _unpack_one(
                out,
                jax.lax.dynamic_slice(packed, (rep * step,), (step,)),
                plan,
                strat,
                interpret,
                rep * plan.rep_extent,
            )
    return unbyte_view(out, buf.dtype, buf.shape)
