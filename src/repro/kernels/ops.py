"""Public pack/unpack operations: jit'd wrappers + strategy dispatch.

This is TEMPI's ``MPI_Pack``/``MPI_Unpack`` (paper §6.2) for JAX arrays.
The committed type's canonical StridedBlock drives everything:

    kind CONTIG     -> one contiguous copy (cudaMemcpyAsync analogue)
    kind KERNEL_2D/3D -> Pallas kernel, strategy chosen among
                         'rows' (pitched) / 'dma' (strided descriptor)
    kind KERNEL_ND  -> python loop of 3D kernels over the outer dims
    kind GENERIC or unplannable geometry -> gather fallback (ref path)

``incount`` repeats the datatype at ``extent`` strides, handled as an
extra outer dimension exactly as the paper describes (§3.3 last ¶).

Buffers can be any dtype/shape; they are re-viewed as bytes and then as
W-byte words (the paper's word-size specialization) without copying.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.commit import CommittedType, KernelKind
from repro.core.strided_block import StridedBlock
from repro.kernels import ref as refk
from repro.kernels.geometry import (
    VMEM_BUDGET_BYTES,
    PackGeometry,
    plan_geometry,
)
from repro.kernels.pack import pack_dma, pack_rows
from repro.kernels.unpack import unpack_dma, unpack_rows

__all__ = [
    "byte_view",
    "unbyte_view",
    "as_words",
    "words_to_bytes",
    "pack",
    "unpack",
    "default_strategy",
    "STRATEGIES",
]

STRATEGIES = ("auto", "rows", "dma", "xla", "ref")

_UINT = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32}

#: geometry plan cache — the paper's §4 "caching layer": keyed by the
#: committed datatype + incount, so repeated Pack/Unpack of the same type
#: re-dispatch in a dict lookup.
_PLAN_CACHE: Dict[Tuple[int, int], Optional["_Plan"]] = {}


def _interpret_default() -> bool:
    # Pallas TPU kernels run in interpret mode anywhere but real TPUs.
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# byte / word re-viewing (no data movement under XLA)
# ---------------------------------------------------------------------------

def byte_view(arr: jax.Array) -> jax.Array:
    """Flat uint8 view of any (non-bool) array's underlying bytes."""
    if arr.dtype == jnp.bool_:
        raise TypeError("bool buffers are not byte-addressable; cast first")
    flat = arr.reshape(-1)
    if arr.dtype == jnp.uint8:
        return flat
    return jax.lax.bitcast_convert_type(flat, jnp.uint8).reshape(-1)


def unbyte_view(b: jax.Array, dtype, shape) -> jax.Array:
    """Inverse of :func:`byte_view`."""
    if dtype == jnp.uint8:
        return b.reshape(shape)
    w = jnp.dtype(dtype).itemsize
    return jax.lax.bitcast_convert_type(b.reshape(-1, w), dtype).reshape(shape)


def as_words(b: jax.Array, w: int) -> jax.Array:
    """uint8[n] -> uintW[n/w] (n already padded to a multiple of w)."""
    if w == 1:
        return b
    return jax.lax.bitcast_convert_type(b.reshape(-1, w), _UINT[w])


def words_to_bytes(x: jax.Array) -> jax.Array:
    w = x.dtype.itemsize
    if w == 1:
        return x.reshape(-1)
    return jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------

class _Plan:
    """Host-side execution plan for one (committed type, incount)."""

    __slots__ = ("sb", "reps", "rep_extent", "geom", "kind")

    def __init__(self, ct: CommittedType, incount: int):
        sb = ct.block
        self.kind = ct.kernel
        self.reps = 1
        self.rep_extent = ct.extent
        if sb is not None and incount > 1:
            if sb.ndims == 1:
                if ct.extent == sb.counts[0] and sb.start == 0:
                    # contiguous repetitions stay contiguous
                    sb = StridedBlock(0, (sb.counts[0] * incount,), (1,))
                else:
                    sb = StridedBlock(
                        sb.start,
                        (sb.counts[0], incount),
                        (1, ct.extent),
                    )
            elif sb.ndims == 2:
                sb = StridedBlock(
                    sb.start,
                    sb.counts + (incount,),
                    sb.strides + (ct.extent,),
                )
            else:
                # 3D+ repeated: loop reps on host (paper: "handled
                # dynamically" — known only at the call site)
                self.reps = incount
        self.sb = sb
        self.geom = (
            plan_geometry(sb) if sb is not None and sb.ndims in (2, 3) else None
        )


def _plan(ct: CommittedType, incount: int) -> _Plan:
    key = (id(ct), incount)
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = _Plan(ct, incount)
        _PLAN_CACHE[key] = plan
    return plan


def default_strategy(geom: Optional[PackGeometry]) -> str:
    """Static heuristic used when no calibrated model is loaded: the
    pitched row kernel wins while its over-fetch stays moderate (it gets
    automatic double-buffering); the strided-DMA kernel wins for small
    blocks at large pitches.  The calibrated model (repro.comm.perfmodel)
    refines this crossover, as the paper's model picks one-shot vs
    device."""
    if geom is None:
        return "ref"
    return "rows" if geom.overfetch <= 4.0 else "dma"


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def _prep_words(b: jax.Array, geom: PackGeometry) -> jax.Array:
    """bytes -> padded (rows_padded, pitch) word view."""
    w = geom.word_bytes
    n = b.shape[0]
    need_bytes = geom.rows_padded * geom.pitch * w
    if n % w or n < need_bytes:
        pad = max(need_bytes, ((n + w - 1) // w) * w) - n
        b = jnp.pad(b, (0, pad))
    words = as_words(b, w)
    words = words[: geom.rows_padded * geom.pitch]
    return words.reshape(geom.rows_padded, geom.pitch)


def _pack_one(
    b: jax.Array, plan: _Plan, strategy: str, interpret: bool, base: int
) -> jax.Array:
    """Pack one repetition (byte offsets shifted by ``base``)."""
    sb = plan.sb
    if base:
        sb = StridedBlock(sb.start + base, sb.counts, sb.strides)
    if sb.ndims == 1:
        return jax.lax.dynamic_slice(b, (sb.start,), (sb.counts[0],))
    geom = plan_geometry(sb) if base else plan.geom
    if strategy == "auto":
        strategy = default_strategy(geom)
    if geom is None or strategy == "ref":
        return refk.pack_ref(b, sb)
    if strategy == "xla":
        return refk.pack_xla_blocks(b, sb)
    src2d = _prep_words(b, geom)
    if strategy == "rows":
        out = pack_rows(src2d, geom, interpret=interpret)
    elif strategy == "dma":
        out = pack_dma(src2d, geom, VMEM_BUDGET_BYTES, interpret=interpret)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    return words_to_bytes(out.reshape(-1))


def pack(
    buf: jax.Array,
    ct: CommittedType,
    incount: int = 1,
    strategy: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """MPI_Pack: gather the non-contiguous bytes ``ct`` describes from
    ``buf`` into a contiguous uint8 buffer of ``ct.size * incount``."""
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    if interpret is None:
        interpret = _interpret_default()
    plan = _plan(ct, incount)
    b = byte_view(buf)
    if plan.kind is KernelKind.GENERIC or plan.sb is None:
        return refk.pack_ref(b, ct.block, incount, ct.extent)  # pragma: no cover
    if plan.reps == 1:
        return _pack_one(b, plan, strategy, interpret, 0)
    parts = [
        _pack_one(b, plan, strategy, interpret, r * plan.rep_extent)
        for r in range(plan.reps)
    ]
    return jnp.concatenate(parts)


def pack_block(
    buf: jax.Array,
    sb: StridedBlock,
    strategy: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Low-level pack straight from a StridedBlock (no committed type).

    Used by the comm layer for shifted/derived blocks (e.g. extracting
    member bytes out of a received bounding window)."""
    if interpret is None:
        interpret = _interpret_default()
    b = byte_view(buf)
    if sb.ndims == 1:
        return jax.lax.dynamic_slice(b, (sb.start,), (sb.counts[0],))
    geom = plan_geometry(sb)
    if strategy == "auto":
        strategy = default_strategy(geom)
    if geom is None or strategy == "ref":
        return refk.pack_ref(b, sb)
    if strategy == "xla":
        return refk.pack_xla_blocks(b, sb)
    src2d = _prep_words(b, geom)
    if strategy == "rows":
        out = pack_rows(src2d, geom, interpret=interpret)
    else:
        out = pack_dma(src2d, geom, VMEM_BUDGET_BYTES, interpret=interpret)
    return words_to_bytes(out.reshape(-1))


def _unpack_one(
    b: jax.Array,
    packed: jax.Array,
    plan: _Plan,
    strategy: str,
    interpret: bool,
    base: int,
) -> jax.Array:
    sb = plan.sb
    if base:
        sb = StridedBlock(sb.start + base, sb.counts, sb.strides)
    if sb.ndims == 1:
        return jax.lax.dynamic_update_slice(b, packed, (sb.start,))
    geom = plan_geometry(sb) if base else plan.geom
    if strategy == "auto":
        strategy = default_strategy(geom)
    if geom is None or strategy == "ref":
        return refk.unpack_ref(b, packed, sb)
    if strategy == "xla":
        return refk.unpack_xla_blocks(b, packed, sb)
    n = b.shape[0]
    covered = geom.rows_padded * geom.pitch * geom.word_bytes
    dst2d = _prep_words(b, geom)
    pk3 = as_words(packed, geom.word_bytes).reshape(
        geom.planes, geom.rows, geom.lanes
    )
    if strategy == "rows":
        if geom.planes > 1 and geom.plane_rows < geom.rows:
            # interleaved planes: row read-modify-write would lose
            # updates; use the windowed DMA kernel instead
            out2d = unpack_dma(dst2d, pk3, geom, VMEM_BUDGET_BYTES, interpret)
        else:
            out2d = unpack_rows(dst2d, pk3, geom, interpret=interpret)
    elif strategy == "dma":
        out2d = unpack_dma(dst2d, pk3, geom, VMEM_BUDGET_BYTES, interpret)
    else:
        raise ValueError(f"unknown strategy {strategy!r}")
    out_b = words_to_bytes(out2d.reshape(-1))
    if covered >= n:
        return out_b[:n]
    # the 2D word view only covers the strided region; keep the tail
    return jnp.concatenate([out_b, b[covered:]])


def unpack(
    buf: jax.Array,
    packed: jax.Array,
    ct: CommittedType,
    incount: int = 1,
    strategy: str = "auto",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """MPI_Unpack: scatter ``packed`` (uint8[size*incount]) into ``buf``
    per the committed datatype; returns the updated buffer (same
    shape/dtype as ``buf``)."""
    if strategy not in STRATEGIES:
        raise ValueError(f"strategy must be one of {STRATEGIES}")
    if interpret is None:
        interpret = _interpret_default()
    plan = _plan(ct, incount)
    b = byte_view(buf)
    packed = byte_view(packed)
    if plan.kind is KernelKind.GENERIC or plan.sb is None:  # pragma: no cover
        out = refk.unpack_ref(b, packed, ct.block, incount, ct.extent)
        return unbyte_view(out, buf.dtype, buf.shape)
    if plan.reps == 1:
        out = _unpack_one(b, packed, plan, strategy, interpret, 0)
    else:
        out = b
        step = plan.sb.size
        for rep in range(plan.reps):
            out = _unpack_one(
                out,
                jax.lax.dynamic_slice(packed, (rep * step,), (step,)),
                plan,
                strategy,
                interpret,
                rep * plan.rep_extent,
            )
    return unbyte_view(out, buf.dtype, buf.shape)
