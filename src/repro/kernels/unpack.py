"""Pallas TPU unpack kernels — inverses of ``repro.kernels.pack``.

Unpack writes *into* an existing buffer, so both kernels are in-place
(``input_output_aliases``):

* ``unpack_rows`` — read-modify-write of full-pitch row-groups.  Each
  grid step fetches the destination rows, splices the packed lanes in
  registers/VMEM and stores the rows back.  Requires the plane row
  ranges to be disjoint (guaranteed for well-formed strided types where
  ``strides[2] >= counts[1]*strides[1]``; checked by the planner).

* ``unpack_dma``  — the destination stays in HBM (ANY); each step copies
  a packed row-chunk to VMEM scratch and issues one strided DMA into the
  destination window.  Touches exactly the block bytes.

The paper notes unpack is slower than pack ("non-contiguous writes
instead of non-contiguous reads"); the same asymmetry exists here —
``unpack_rows`` moves 2x the pitch bytes (read + write-back).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.geometry import PackGeometry
from repro.kernels.pack import _MemorySpace, choose_chunk

__all__ = ["unpack_rows", "unpack_dma", "unpack_ragged", "decode_unpack_ragged"]


def unpack_ragged(dst: jax.Array, wire: jax.Array, leaves) -> jax.Array:
    """Inverse of :func:`repro.kernels.pack.pack_ragged`: slice each
    leaf's exact wire segment out of the flat received buffer and
    scatter it into ``dst``.

    ``leaves`` is a sequence of ``(offset, nbytes, unpack_fn)``:
    ``unpack_fn(dst, payload)`` consumes one leaf's ``uint8[nbytes]``
    wire payload (a strategy's ``unpack_wire`` path, already bound to
    its committed type) and returns the updated destination.  Offsets
    are the wire plan's exact segment offsets — no padding is skipped
    because none was sent.
    """
    for offset, nbytes, unpack_fn in leaves:
        part = jax.lax.dynamic_slice(wire, (offset,), (nbytes,))
        dst = unpack_fn(dst, part)
    return dst


def decode_unpack_ragged(dst: jax.Array, wire: jax.Array, leaves) -> jax.Array:
    """Fused decompress+unpack: inverse of
    :func:`repro.kernels.pack.pack_compress_ragged`.

    ``leaves`` is a sequence of ``(offset, nbytes, decode_fn,
    unpack_fn)``: each leaf's ``nbytes`` wire bytes (for a length-aware
    transport this is the *stream* length, not the capacity) are sliced
    out, decoded to member bytes by ``decode_fn`` (e.g.
    :meth:`repro.comm.compress.RleWire.decode_wire` bound to the member
    size) and scattered by ``unpack_fn(dst, member)`` — decode and
    scatter stay in one traced expression, no extra materialized pass.
    ``decode_fn=None`` means the wire bytes *are* the payload
    ``unpack_fn`` consumes (the uncompressed strategies' ``unpack_wire``
    path), degenerating to :func:`unpack_ragged` exactly.
    """
    for offset, nbytes, decode_fn, unpack_fn in leaves:
        part = jax.lax.dynamic_slice(wire, (offset,), (nbytes,))
        if decode_fn is not None:
            part = decode_fn(part)
        dst = unpack_fn(dst, part)
    return dst


def _unpack_rows_kernel(dst_ref, pk_ref, out_ref, *, r: int, lanes: int):
    # dst_ref/out_ref: (G, pitch); pk_ref: (1, G, lanes)
    tmp = dst_ref[...]
    out_ref[...] = tmp.at[:, r : r + lanes].set(pk_ref[0])


def unpack_rows(
    dst2d: jax.Array,
    packed3d: jax.Array,
    geom: PackGeometry,
    interpret: bool = False,
):
    """In-place splice of packed blocks into full-pitch row-groups.

    ``dst2d``: (rows_padded, pitch) word view of the destination buffer.
    ``packed3d``: (planes, rows, lanes).  Returns the updated 2D view.
    """
    g = geom.group
    qb = geom.q // g
    prb = geom.plane_rows // g if geom.plane_rows else 0
    row_idx = lambda p, i: (qb + p * prb + i, 0)

    return pl.pallas_call(
        functools.partial(_unpack_rows_kernel, r=geom.r, lanes=geom.lanes),
        grid=(geom.planes, geom.rows // g),
        in_specs=[
            pl.BlockSpec((g, geom.pitch), row_idx),
            pl.BlockSpec((1, g, geom.lanes), lambda p, i: (p, i, 0)),
        ],
        out_specs=pl.BlockSpec((g, geom.pitch), row_idx),
        out_shape=jax.ShapeDtypeStruct(dst2d.shape, dst2d.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(dst2d, packed3d)


def _unpack_dma_kernel(
    pk_ref, dst_ref, out_ref, scratch, sem, *, q, r, plane_rows, chunk, lanes
):
    del dst_ref  # aliased with out_ref; present only for donation
    p = pl.program_id(0)
    ib = pl.program_id(1)
    row0 = q + p * plane_rows + ib * chunk
    scratch[...] = pk_ref[0]
    cp = pltpu.make_async_copy(
        scratch, out_ref.at[pl.ds(row0, chunk), pl.ds(r, lanes)], sem
    )
    cp.start()
    cp.wait()


def unpack_dma(
    dst2d: jax.Array,
    packed3d: jax.Array,
    geom: PackGeometry,
    vmem_budget: int,
    interpret: bool = False,
):
    """In-place strided-DMA scatter of packed blocks (no pitch traffic)."""
    chunk = choose_chunk(geom.rows, geom.lanes, geom.word_bytes, vmem_budget)
    kern = functools.partial(
        _unpack_dma_kernel,
        q=geom.q,
        r=geom.r,
        plane_rows=geom.plane_rows,
        chunk=chunk,
        lanes=geom.lanes,
    )
    return pl.pallas_call(
        kern,
        grid=(geom.planes, geom.rows // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, geom.lanes), lambda p, i: (p, i, 0)),
            pl.BlockSpec(memory_space=_MemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec(memory_space=_MemorySpace.ANY),
        out_shape=jax.ShapeDtypeStruct(dst2d.shape, dst2d.dtype),
        input_output_aliases={1: 0},
        scratch_shapes=[
            pltpu.VMEM((chunk, geom.lanes), dst2d.dtype),
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(packed3d, dst2d)
