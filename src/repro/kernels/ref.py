"""Pure-jnp oracles for pack/unpack (the correctness ground truth).

Two reference paths are provided:

* ``pack_ref``/``unpack_ref`` — gather/scatter through a host-materialized
  index array.  This is exactly the "list of offsets and lengths"
  representation the paper criticizes (§2: metadata may consume as much
  memory as the data) — kept as the oracle and as the GENERIC fallback.

* ``pack_xla_blocks``/``unpack_xla_blocks`` — one ``dynamic_slice`` /
  ``dynamic_update_slice`` per contiguous block, emulating the
  cudaMemcpyAsync-per-block baseline that OpenMPI / Spectrum MPI /
  MVAPICH share (paper §6.2).  Used as the *baseline mode* in benchmarks.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.strided_block import StridedBlock, block_offsets

__all__ = [
    "offsets_array",
    "pack_ref",
    "unpack_ref",
    "pack_xla_blocks",
    "unpack_xla_blocks",
]


def offsets_array(sb: StridedBlock, incount: int = 1, extent: int = 0) -> np.ndarray:
    """Host-side (numpy) array of block offsets in packing order."""
    return np.fromiter(
        block_offsets(sb, incount=incount, extent=extent), dtype=np.int64
    )


def _byte_index(sb: StridedBlock, incount: int, extent: int) -> np.ndarray:
    offs = offsets_array(sb, incount, extent)
    return (offs[:, None] + np.arange(sb.counts[0], dtype=np.int64)[None, :]).reshape(
        -1
    )


def pack_ref(
    src_bytes: jax.Array, sb: StridedBlock, incount: int = 1, extent: int = 0
) -> jax.Array:
    """Gather every byte the datatype touches, in packing order."""
    idx = _byte_index(sb, incount, extent)
    return src_bytes[jnp.asarray(idx)]


def unpack_ref(
    dst_bytes: jax.Array,
    packed: jax.Array,
    sb: StridedBlock,
    incount: int = 1,
    extent: int = 0,
) -> jax.Array:
    """Scatter the packed bytes back into a copy of ``dst_bytes``."""
    idx = _byte_index(sb, incount, extent)
    return dst_bytes.at[jnp.asarray(idx)].set(packed.reshape(-1))


def pack_xla_blocks(
    src_bytes: jax.Array, sb: StridedBlock, incount: int = 1, extent: int = 0
) -> jax.Array:
    """Baseline: one XLA copy per contiguous block (static offsets)."""
    c0 = sb.counts[0]
    parts = [
        jax.lax.dynamic_slice(src_bytes, (int(off),), (c0,))
        for off in offsets_array(sb, incount, extent)
    ]
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unpack_xla_blocks(
    dst_bytes: jax.Array,
    packed: jax.Array,
    sb: StridedBlock,
    incount: int = 1,
    extent: int = 0,
) -> jax.Array:
    """Baseline: one XLA update per contiguous block."""
    c0 = sb.counts[0]
    out = dst_bytes
    for i, off in enumerate(offsets_array(sb, incount, extent)):
        out = jax.lax.dynamic_update_slice(
            out, jax.lax.dynamic_slice(packed, (i * c0,), (c0,)), (int(off),)
        )
    return out
