"""Assigned architecture config: qwen3-32b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936,
    qk_norm=True, head_dim=128, rope_theta=1e6,
    fsdp=True, microbatches=8, opt_moment_dtype="bfloat16",
)
