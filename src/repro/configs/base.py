"""Model/run configuration schema for all assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "shape_for"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | rwkv | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    rope_theta: float = 1e4
    mrope: bool = False
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 128
    # "tp": expert FFN hidden sharded over model (tokens re-partitioned
    #       to (pod,data) groups) — GShard-style baseline.
    # "dp": tokens stay fully sharded through the expert FFN; expert
    #       weights are gathered on use (expert-DP / pure-FSDP MoE).
    moe_parallel: str = "tp"
    # SSM / linear attention
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    attn_every: int = 0        # hybrid: shared attention every k layers
    # encoder-decoder
    encoder_layers: int = 0
    # frontends (stubs per assignment)
    frontend: Optional[str] = None   # "audio" | "vision"
    num_patches: int = 256           # vlm: vision tokens per sample
    # numerics / scale
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # distribution knobs (overridable per run)
    fsdp: bool = False               # shard weight d_model over "data"
    microbatches: int = 1            # gradient accumulation steps
    remat: bool = True
    opt_moment_dtype: str = "float32"  # bf16 moments for the giants
    kv_cache_dtype: str = "bfloat16"
    # decode KV-cache write: "onehot" (masked full rewrite — the naive
    # baseline) or "dus" (in-place dynamic-update-slice on the donated
    # cache; touches only the written row)
    cache_update: str = "dus"
    activation: str = "silu"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family in ("ssm", "rwkv")

    @property
    def sub_quadratic(self) -> bool:
        """Can decode at 500k context with O(window|state) memory?"""
        return self.attention_free or self.family == "hybrid" or (
            self.sliding_window is not None
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (roofline §: MODEL_FLOPS = 6 N D) --------------
    def param_count(self, active_only: bool = False) -> int:
        D, F, H, KV, hd = self.d_model, self.d_ff, self.num_heads, self.num_kv_heads, self.hd
        embed = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        attn = D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
        mlp = 3 * D * F
        if self.family == "moe":
            e = self.experts_per_token if active_only else self.num_experts
            mlp = 3 * D * F * e + D * self.num_experts  # + router
        per_layer = attn + mlp + 2 * D
        if self.family in ("ssm", "rwkv"):
            d_inner = 2 * D
            per_layer = (
                D * (2 * d_inner + 2 * self.ssm_state + 32)
                + d_inner * D
                + 3 * D * F
            ) if self.family == "ssm" else (
                6 * D * D + 3 * D * F  # rwkv time-mix + channel-mix approx
            )
        if self.family == "hybrid":
            d_inner = 2 * D
            mamba = D * (2 * d_inner + 2 * self.ssm_state + 32) + d_inner * D
            shared = attn + mlp
            return self.num_layers * mamba + shared + embed
        total = self.num_layers * per_layer + embed
        if self.family == "encdec":
            # encoder layers + decoder cross-attention
            total += self.encoder_layers * per_layer + self.num_layers * attn
        return total


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode" | "long-decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "long-decode"),
)


def shape_for(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
