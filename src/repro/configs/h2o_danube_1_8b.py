"""Assigned architecture config: h2o-danube-1.8b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000,
    sliding_window=4096, rope_theta=1e4,
)
