"""Assigned architecture config: qwen2-vl-2b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, mrope=True, mrope_sections=(16, 24, 24),
    frontend="vision", rope_theta=1e6, tie_embeddings=True,
)
