"""Architecture registry: the 10 assigned configs (one module per arch in
this package) + reduced smoke variants.  ``--arch <id>`` everywhere
resolves through here.

Assigned sources:
  qwen2-0.5b [arXiv:2407.10671; hf]     h2o-danube-1.8b [arXiv:2401.16818; hf]
  qwen3-32b [hf:Qwen/Qwen3-8B; hf]      yi-6b [arXiv:2403.04652; hf]
  seamless-m4t-large-v2 [arXiv:2308.11596; hf]
  zamba2-2.7b [arXiv:2411.15242; hf]    grok-1-314b [hf:xai-org/grok-1; unverified]
  mixtral-8x22b [arXiv:2401.04088; hf]  rwkv6-7b [arXiv:2404.05892; hf]
  qwen2-vl-2b [arXiv:2409.12191; hf]
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs import (  # noqa: F401  (one module per assigned arch)
    grok_1_314b,
    h2o_danube_1_8b,
    mixtral_8x22b,
    qwen2_0_5b,
    qwen2_vl_2b,
    qwen3_32b,
    rwkv6_7b,
    seamless_m4t_large_v2,
    yi_6b,
    zamba2_2_7b,
)

__all__ = ["ARCHS", "get_config", "smoke_config", "ARCH_IDS"]

ARCHS: Dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


for _mod in (
    qwen2_0_5b, h2o_danube_1_8b, qwen3_32b, yi_6b, seamless_m4t_large_v2,
    zamba2_2_7b, grok_1_314b, mixtral_8x22b, rwkv6_7b, qwen2_vl_2b,
):
    _reg(_mod.CONFIG)












ARCH_IDS = tuple(ARCHS.keys())


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small layers/width,
    few experts, tiny vocab — structure preserved."""
    cfg = get_config(name)
    kw = dict(
        num_layers=4 if cfg.family != "hybrid" else 4,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab_size=503,
        microbatches=1,
        fsdp=False,
        remat=False,
        moe_group_size=32,
    )
    if cfg.family == "moe":
        kw.update(num_experts=4, experts_per_token=2)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.family == "rwkv":
        kw.update(ssm_head_dim=16, num_heads=4, num_kv_heads=4)
    if cfg.family == "hybrid":
        kw.update(attn_every=2)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2)
    if cfg.family == "vlm":
        kw.update(num_patches=16, mrope_sections=(2, 3, 3))  # head_dim 16
    if cfg.sliding_window:
        kw.update(sliding_window=32)
    return cfg.replace(**kw)
