"""Assigned architecture config: yi-6b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000,
    rope_theta=5e6, microbatches=2,
)
