"""Assigned architecture config: seamless-m4t-large-v2 (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    encoder_layers=24, frontend="audio", activation="gelu",
)
