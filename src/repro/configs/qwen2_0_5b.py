"""Assigned architecture config: qwen2-0.5b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
)
