"""Assigned architecture config: mixtral-8x22b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=32768,
    num_experts=8, experts_per_token=2, sliding_window=4096,
    fsdp=True, microbatches=8, opt_moment_dtype="bfloat16",
)
