"""Assigned architecture config: zamba2-2.7b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, attn_every=6, microbatches=2,
)
