"""Assigned architecture config: rwkv6-7b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    ssm_head_dim=64, microbatches=2,
)
