"""Assigned architecture config: grok-1-314b (see registry for the
source tier annotations in the assignment)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    num_experts=8, experts_per_token=2, activation="gelu",
    fsdp=True, microbatches=16, opt_moment_dtype="bfloat16",
)
