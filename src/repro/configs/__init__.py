"""repro.configs — assigned architecture configs + shapes."""

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_for
