"""repro.distributed — sharding rules and mesh utilities."""

from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    constrain,
    param_partition_spec,
    tree_partition_specs,
    use_rules,
)
