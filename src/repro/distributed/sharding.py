"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Model code never names physical mesh axes; it annotates activations with
*logical* axes via :func:`constrain` and parameters are partitioned by
:func:`param_partition_spec`.  The launcher installs a rule set mapping
logical -> physical axes for the current mesh; axes absent from the mesh
are dropped, so the same model code runs on the 16x16 single-pod mesh,
the 2x16x16 multi-pod mesh, and a 1-device CPU test mesh unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "use_rules",
    "active",
    "constrain",
    "logical_spec",
    "param_partition_spec",
]

Physical = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """Mapping of logical axis names to physical mesh axes."""

    batch: Physical = ("pod", "data")
    seq: Physical = "model"          # activation sequence sharding (SP)
    kv_seq: Physical = "model"       # KV-cache sequence sharding
    heads: Physical = "model"        # attention heads / tp
    d_ff: Physical = "model"         # MLP hidden
    vocab: Physical = "model"        # embedding/logits vocab dim
    d_model: Physical = None         # hidden size (kept replicated)
    fsdp: Physical = None            # weight d_model dim (ZeRO-3 style)
    expert: Physical = None          # MoE expert dim
    moe_groups: Physical = ("pod", "data", "model")  # grouped-dispatch dim
    moe_groups_ff: Physical = ("pod", "data")  # groups dim inside expert FFN
    state: Physical = "model"        # SSM / linear-attn state heads

    def resolve(
        self, logical: Optional[str], mesh: Mesh, dim: Optional[int] = None
    ) -> Physical:
        """Logical -> physical axes; axes absent from the mesh are
        dropped, and (when ``dim`` is given) trailing axes are dropped
        until the axis-size product divides the dimension — so e.g. a
        batch of 1 or 2 KV heads silently falls back to replication
        instead of GSPMD padding."""
        if logical is None:
            return None
        phys = getattr(self, logical)
        if phys is None:
            return None
        if isinstance(phys, str):
            phys = (phys,)
        avail = list(a for a in phys if a in mesh.axis_names)
        if dim is not None:
            import math

            while avail and dim % math.prod(
                mesh.shape[a] for a in avail
            ):
                avail.pop()
        if not avail:
            return None
        return tuple(avail) if len(avail) > 1 else avail[0]


DEFAULT_RULES = ShardingRules()


class _Active(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: ShardingRules = DEFAULT_RULES


_ACTIVE = _Active()


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: ShardingRules = DEFAULT_RULES):
    """Install (mesh, rules) for model-code sharding annotations."""
    prev = (_ACTIVE.mesh, _ACTIVE.rules)
    _ACTIVE.mesh, _ACTIVE.rules = mesh, rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ACTIVE.mesh, _ACTIVE.rules = prev


def active() -> Tuple[Optional[Mesh], ShardingRules]:
    return _ACTIVE.mesh, _ACTIVE.rules


def logical_spec(logical_axes: Sequence[Optional[str]]) -> P:
    """PartitionSpec for a tuple of logical axis names (None = replicated),
    resolved against the active mesh."""
    mesh, rules = active()
    if mesh is None:
        return P()
    return P(*(rules.resolve(a, mesh) for a in logical_axes))


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh.

    Non-divisible dims fall back to unconstrained (see resolve) — and if
    NO dim resolves to a real axis the constraint is dropped entirely:
    P(None,...) would *force* replication, whereas saying nothing leaves
    XLA's sharding inference free (e.g. qwen2's 14 heads on a 16-way
    model axis: forcing replication of the attention score tensors
    costs 4x collective on train_4k)."""
    mesh, rules = active()
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    resolved = tuple(
        rules.resolve(a, mesh, d) for a, d in zip(logical_axes, x.shape)
    )
    if all(r is None for r in resolved):
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# parameter partitioning by path
# ---------------------------------------------------------------------------

#: path-substring -> logical axes for the *trailing* dims (leading stacked
#: layer dims are never sharded).  First match wins.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # replicated small parameters must match before family catch-alls
    ("norm", (None,)),
    ("bias", (None,)),
    ("mu_", (None,)),
    ("/w0", (None,)),
    ("/u", (None, None)),
    ("lora_a", (None, None)),
    ("conv", (None, None)),
    ("A_log", (None,)),
    ("dt_", (None,)),
    ("/D", (None,)),
    ("embed/vocab", ("vocab", "fsdp")),
    ("lm_head", ("fsdp", "vocab")),
    ("attn/wqkv", ("fsdp", "heads")),
    ("attn/wq", ("fsdp", "heads")),
    ("attn/wk", ("fsdp", "heads")),
    ("attn/wv", ("fsdp", "heads")),
    ("attn/wo", ("heads", "fsdp")),
    ("mlp/w_in", ("fsdp", "d_ff")),
    ("mlp/w_gate", ("fsdp", "d_ff")),
    ("mlp/w_out", ("d_ff", "fsdp")),
    ("moe/router", ("fsdp", None)),
    ("moe/w_in", ("expert", "fsdp", "d_ff")),
    ("moe/w_gate", ("expert", "fsdp", "d_ff")),
    ("moe/w_out", ("expert", "d_ff", "fsdp")),
    ("ssm/in_proj", ("fsdp", "heads")),
    ("ssm/out_proj", ("heads", "fsdp")),
    ("ln_", (None,)),
    ("rwkv/ck", ("fsdp", "d_ff")),
    ("rwkv/cv", ("d_ff", "fsdp")),
    ("rwkv/wo", ("heads", "fsdp")),
    ("rwkv/", ("fsdp", "heads")),
)


def param_logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a parameter; unmatched paths are replicated."""
    for key, trailing in _PARAM_RULES:
        if key in path:
            t = trailing[-ndim:] if len(trailing) >= ndim else trailing
            lead = ndim - len(t)
            return (None,) * lead + tuple(t)
    return (None,) * ndim


def param_partition_spec(
    path: str, ndim: int, rules: ShardingRules, mesh: Mesh, shape=None
) -> P:
    axes = param_logical_axes(path, ndim)
    dims = shape if shape is not None else (None,) * ndim
    return P(*(rules.resolve(a, mesh, d) for a, d in zip(axes, dims)))


def tree_paths(tree) -> "dict[str, jax.ShapeDtypeStruct]":
    """Flatten a param pytree into {'a/b/c': leaf} with '/'-joined keys."""
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}/{k}" if prefix else k, v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def tree_partition_specs(tree, rules: ShardingRules, mesh: Mesh):
    """Param pytree -> matching pytree of PartitionSpecs (divisibility-
    checked against leaf shapes)."""

    def walk(prefix, node):
        if isinstance(node, dict):
            return {
                k: walk(f"{prefix}/{k}" if prefix else k, v)
                for k, v in node.items()
            }
        return param_partition_spec(prefix, node.ndim, rules, mesh, node.shape)

    return walk("", tree)
