"""repro.roofline — three-term roofline analysis from compiled dry-runs."""

from repro.roofline.analysis import HW_V5E, Hardware, RooflineReport, analyze, collective_bytes
