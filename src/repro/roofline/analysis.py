"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory     = HLO_bytes / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

``cost_analysis`` provides FLOPs and bytes for the *partitioned,
per-device* program, so the per-chip terms divide by 1 (the chips factor
is already applied by SPMD partitioning); collective bytes are parsed
out of the (partitioned) HLO text since cost_analysis does not count
them.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (45 effective).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

__all__ = ["HW_V5E", "Hardware", "collective_bytes", "RooflineReport",
           "analyze"]


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per ICI link
    dcn_bw: float = 25e9       # bytes/s per host cross-pod


HW_V5E = Hardware("tpu_v5e", peak_flops=197e12, hbm_bw=819e9, link_bw=45e9)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

#: ops we count as collectives, with an approximate wire-bytes multiplier
#: per *operand shard byte* (ring algorithms)
_COLLECTIVES = {
    "all-reduce": 2.0,          # reduce-scatter + all-gather ring
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' -> bytes."""
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_TUPLE_SHAPE_RE = re.compile(r"(\w+\[[\d,]*\])")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum (approximate wire) bytes of every collective in the
    partitioned HLO, by op kind.  Handles tuple-shaped results and the
    async -start/-done forms (done ops are not double counted)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        result, kind = m.groups()
        nbytes = sum(_shape_bytes(s) for s in _TUPLE_SHAPE_RE.findall(result))
        out[kind] += nbytes * _COLLECTIVES[kind]
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_bytes: float           # per device (wire estimate)
    coll_by_kind: Dict[str, float]
    model_flops: float          # 6 N D (global, useful)
    hw: Hardware = HW_V5E

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips x HLO_FLOPs): remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """fraction of the dominant-term-bound step time that is the
        compute term — i.e. how close the step is to compute-roofline."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return self.t_compute / t if t else 0.0

    def row(self) -> str:
        return (
            f"| {self.arch} | {self.shape} | {self.mesh} | "
            f"{self.t_compute*1e3:.2f} | {self.t_memory*1e3:.2f} | "
            f"{self.t_collective*1e3:.2f} | {self.bottleneck} | "
            f"{self.useful_flops_ratio:.2f} | {self.roofline_fraction:.2f} |"
        )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train; for
    inference shapes, 2 N D per generated/prefilled token."""
    n = cfg.param_count(active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind in
                                   ("train", "prefill") else 1)
    per_tok = 6 * n if shape.kind == "train" else 2 * n
    return float(per_tok) * tokens


def analyze(arch, shape, mesh_name, chips, cost, hlo_text, cfg, shape_cfg,
            hw: Hardware = HW_V5E) -> RooflineReport:
    """Build the report from the *loop-aware* HLO walk (hlo_cost) — the
    builtin cost_analysis is trip-count-blind for while loops (see
    tests/test_roofline.py) and is kept only as a cross-check field."""
    from repro.roofline.hlo_cost import parse_hlo_cost

    parsed = parse_hlo_cost(hlo_text)
    coll = {
        k: v * _COLLECTIVES.get(k, 1.0) for k, v in parsed.coll.items()
    }
    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=parsed.flops,
        hlo_bytes=parsed.bytes,
        coll_bytes=sum(coll.values()),
        coll_by_kind=coll,
        model_flops=model_flops_estimate(cfg, shape_cfg),
        hw=hw,
    )
