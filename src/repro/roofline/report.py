"""Render EXPERIMENTS.md tables from dry-run JSONL records.

    PYTHONPATH=src python -m repro.roofline.report results/*.jsonl
"""

from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(paths) -> List[Dict]:
    recs = []
    for p in paths:
        with open(p) as f:
            recs.extend(json.loads(l) for l in f if l.strip())
    return recs


def fmt_bytes(n) -> str:
    return f"{n/2**30:.2f}"


def dryrun_table(recs: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | status | args GiB/dev | temp GiB/dev | "
        "flops/dev | bytes/dev | coll bytes/dev | top collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "OK":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']}: {r.get('reason', r.get('error', ''))[:60]} "
                f"| | | | | | |"
            )
            continue
        coll = r.get("coll_by_kind", {})
        top = ", ".join(
            f"{k}:{v:.2e}" for k, v in
            sorted(coll.items(), key=lambda kv: -kv[1])[:2]
        )
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{fmt_bytes(r.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(r.get('temp_size_in_bytes', 0))} | "
            f"{r['flops_per_device']:.2e} | {r['bytes_per_device']:.2e} | "
            f"{r['coll_bytes_per_device']:.2e} | {top} |"
        )
    return "\n".join(rows)


def roofline_table(recs: List[Dict]) -> str:
    rows = [
        "| arch | shape | mesh | compute ms | memory ms | collective ms | "
        "bottleneck | MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] != "OK":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} | | | | | |"
            )
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_ms']:.2f} | {r['t_memory_ms']:.2f} | "
            f"{r['t_collective_ms']:.2f} | {r['bottleneck']} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.2f} |"
        )
    return "\n".join(rows)


def main():
    recs = load(sys.argv[1:])
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
