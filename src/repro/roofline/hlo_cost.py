"""Loop-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
regardless of trip count — with scan-over-layers (and microbatch /
flash-chunk scans) that under-counts FLOPs, bytes, and collectives by
orders of magnitude (verified in tests/test_roofline.py).  This module
re-derives the three roofline inputs by walking the partitioned HLO:

* computations are parsed into blocks; a module-wide symbol table maps
  every ``%value`` to its result shape (operands are printed without
  inline shapes in scheduled HLO dumps);
* ``while`` ops multiply body+condition cost by the trip count recovered
  from the largest integer constant in the loop condition computation
  (jax scans lower to ``compare(iter, constant(N)), direction=LT``);
* ``dot``/``convolution`` FLOPs come from operand shapes + contraction
  dims;
* bytes = operand + output bytes of top-level ops (fusion internals stay
  in registers/VMEM; the fusion call-site operands/outputs are the HBM
  traffic);
* collective bytes are accumulated per kind with the same trip
  multipliers.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "parse_hlo_cost", "cost_analysis_dict"]


def cost_analysis_dict(compiled_or_cost) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older releases return a one-element list of per-program dicts;
    newer ones return the dict directly.  Accepts either a ``Compiled``
    object or the raw ``cost_analysis()`` result and always returns a
    flat ``{metric: value}`` dict (empty when unavailable).
    """
    cost = compiled_or_cost
    ca = getattr(cost, "cost_analysis", None)
    if callable(ca):
        cost = ca()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COMP_START = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\w+\[[\d,]*\]\S*)\s+"
    r"([\w\-]+)\("
)
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")

_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_OPS = frozenset((
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "custom-call",
    "rng-bit-generator", "opt-barrier",
))


def _shapes_in(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    return [
        (m.group(1), tuple(int(d) for d in m.group(2).split(",") if d))
        for m in _SHAPE.finditer(text)
        if m.group(1) in _DTYPE_BYTES
    ]


def _bytes_of_shape_text(text: Optional[str]) -> int:
    if not text:
        return 0
    return sum(
        _DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1)
        for dt, dims in _shapes_in(text)
    )


def _elems_of_result(text: str) -> int:
    s = _shapes_in(text)
    if not s:
        return 0
    return math.prod(s[0][1]) if s[0][1] else 1


@dataclass
class _Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)
    by_op: Dict[str, float] = field(default_factory=dict)  # op -> bytes
    coll_shapes: Dict[str, float] = field(default_factory=dict)

    def add_coll(self, kind: str, v: float, mult: float = 1.0):
        self.coll[kind] = self.coll.get(kind, 0.0) + v * mult

    def add_op(self, op: str, nbytes: float, mult: float = 1.0):
        if nbytes:
            self.by_op[op] = self.by_op.get(op, 0.0) + nbytes * mult


@dataclass
class HloCost:
    flops: float
    bytes: float
    coll: Dict[str, float]
    by_op: Dict[str, float] = field(default_factory=dict)
    coll_shapes: Dict[str, float] = field(default_factory=dict)

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())

    def top_ops(self, n: int = 8):
        return sorted(self.by_op.items(), key=lambda kv: -kv[1])[:n]


def _fusion_out_bytes(comp_lines, shapes, result_text) -> int:
    """Call-site output traffic of a fusion, honoring XLA's in-place
    dynamic-update-slice outputs (aliased buffers: traffic = update
    region only, which the internal walk already counted).

    Handles single-DUS roots, bitcast/copy-wrapped DUS roots, and
    multi-output fusions whose ROOT is a tuple mixing DUS and non-DUS
    elements (scan ys-stacking produces these).
    """
    root_line = None
    dus_values = set()
    defs = {}
    for line in comp_lines:
        om = _OP_LINE.match(line)
        if om:
            name, res, op = om.groups()
            defs[name] = (op, res)
            if op == "dynamic-update-slice":
                dus_values.add(name)
        if line.lstrip().startswith("ROOT"):
            root_line = line
    if root_line is None or not dus_values:
        return 2 * _bytes_of_shape_text(result_text)

    rm = _OP_LINE.match(root_line)
    if rm is None:
        return 2 * _bytes_of_shape_text(result_text)
    _, root_res, root_op = rm.groups()

    def is_dus_chain(name, depth=0):
        if depth > 4 or name not in defs:
            return False
        op, _ = defs[name]
        if op == "dynamic-update-slice":
            return True
        if op in ("bitcast", "copy", "reshape", "convert"):
            ops_ = _OPERAND.findall(
                comp_line_for(name)
            )
            return bool(ops_) and is_dus_chain(ops_[0], depth + 1)
        return False

    def comp_line_for(name):
        for line in comp_lines:
            om = _OP_LINE.match(line)
            if om and om.group(1) == name:
                idx = line.index("(", line.index(om.group(3)))
                return line[idx:]
        return ""

    if root_op == "dynamic-update-slice" or (
        root_op in ("bitcast", "copy", "reshape", "convert")
        and is_dus_chain(_OPERAND.findall(comp_line_for(rm.group(1)))[0]
                         if _OPERAND.findall(comp_line_for(rm.group(1)))
                         else "", 0)
    ):
        return 0
    if root_op == "tuple":
        # count only the non-DUS tuple elements
        nb = 0
        operands = _OPERAND.findall(comp_line_for(rm.group(1)))
        for name in operands:
            if is_dus_chain(name):
                continue
            op_res = defs.get(name)
            nb += 2 * _bytes_of_shape_text(op_res[1] if op_res else None)
        return nb
    return 2 * _bytes_of_shape_text(result_text)


def parse_hlo_cost(hlo: str) -> HloCost:
    # --- split into computations + build the symbol table -----------------
    comps: Dict[str, List[str]] = {}
    entry: Optional[str] = None
    shapes: Dict[str, str] = {}  # %value -> result type text
    cur: Optional[str] = None
    for line in hlo.splitlines():
        m = _COMP_START.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            s = line.strip()
            if s == "}":
                cur = None
                continue
            comps[cur].append(line)
            om = _OP_LINE.match(line)
            if om:
                shapes[om.group(1)] = om.group(2)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""

    memo: Dict[str, _Cost] = {}

    def operand_bytes(line: str, op: str) -> int:
        idx = line.index(op + "(")
        inside = line[idx + len(op) + 1 :]
        inside = inside.split("), ")[0]
        total = 0
        for name in _OPERAND.findall(inside):
            total += _bytes_of_shape_text(shapes.get(name))
        return total

    def first_operand_shape(line: str, op: str) -> Tuple[int, ...]:
        idx = line.index(op + "(")
        m = _OPERAND.search(line[idx:])
        if not m:
            return ()
        s = _shapes_in(shapes.get(m.group(1), ""))
        return s[0][1] if s else ()

    def trip_count(cond_name: str) -> int:
        consts = [
            int(c)
            for line in comps.get(cond_name, ())
            for c in _CONST.findall(line)
        ]
        return max(consts) if consts else 1

    def comp_cost(name: str, fused: bool = False) -> _Cost:
        key = f"{name}|{fused}"
        if key in memo:
            return memo[key]
        total = _Cost()
        memo[key] = total  # cycle guard
        for line in comps.get(name, ()):
            m = _OP_LINE.match(line)
            if not m:
                continue
            _, result, op = m.groups()
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", line)
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                trips = trip_count(cm.group(1)) if cm else 1
                for sub_name in ([bm.group(1)] if bm else []) :
                    sub = comp_cost(sub_name)
                    total.flops += trips * sub.flops
                    total.bytes += trips * sub.bytes
                    for k, v in sub.coll.items():
                        total.add_coll(k, v, trips)
                    for k, v in sub.by_op.items():
                        total.add_op(k, v, trips)
                    for k, v in sub.coll_shapes.items():
                        total.coll_shapes[k] = total.coll_shapes.get(k, 0.0) + v * trips
                continue
            if op in ("fusion", "call"):
                cm = _CALLS.search(line) or re.search(
                    r"to_apply=%?([\w.\-]+)", line
                )
                if cm and cm.group(1) in comps:
                    # inside a fusion only slicing/dots/collectives touch
                    # memory; elementwise stays in registers
                    sub = comp_cost(cm.group(1), fused=True)
                    total.flops += sub.flops
                    total.bytes += sub.bytes
                    for k, v in sub.coll.items():
                        total.add_coll(k, v)
                    for k, v in sub.by_op.items():
                        total.add_op(k, v)
                    for k, v in sub.coll_shapes.items():
                        total.coll_shapes[k] = total.coll_shapes.get(k, 0.0) + v
                if cm and cm.group(1) in comps:
                    nb = _fusion_out_bytes(comps[cm.group(1)], shapes, result)
                else:
                    nb = 2 * _bytes_of_shape_text(result)
                total.bytes += nb
                total.add_op("fusion-io", nb)
                if nb >= (1 << 20):
                    total.add_op(f"fusion-io {result[:44]}", nb)
                continue
            if op == "dot":
                lhs = first_operand_shape(line, "dot")
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if cm and cm.group(1):
                    for d in cm.group(1).split(","):
                        if int(d) < len(lhs):
                            contract *= lhs[int(d)]
                total.flops += 2.0 * _elems_of_result(result) * contract
                nb = operand_bytes(line, "dot") + _bytes_of_shape_text(result)
                total.bytes += nb
                total.add_op("dot", nb)
                continue
            if fused and op not in ("dynamic-slice", "dynamic-update-slice",
                                    "convolution", "gather", "scatter"):
                # register-resident elementwise inside a fusion
                total.flops += _elems_of_result(result)
                continue
            if op == "convolution":
                wm = re.search(r"window=\{size=([\dx]+)", line)
                window = 1
                if wm:
                    for d in wm.group(1).split("x"):
                        window *= int(d)
                total.flops += 2.0 * _elems_of_result(result) * window
                total.bytes += operand_bytes(line, "convolution") + \
                    _bytes_of_shape_text(result)
                continue
            base = op.replace("-start", "")
            if base in _COLLECTIVE_KINDS:
                if op.endswith("-done"):
                    continue
                nb = _bytes_of_shape_text(result)
                total.add_coll(base, nb)
                total.bytes += nb
                total.add_op(base, nb)
                key = f"{base} {result[:48]}"
                total.coll_shapes[key] = total.coll_shapes.get(key, 0.0) + nb
                continue
            if op in _SKIP_OPS:
                continue
            if op == "dynamic-slice":
                # traffic is the slice, not the sliced-from operand
                nb = 2 * _bytes_of_shape_text(result)
                total.bytes += nb
                total.add_op("dynamic-slice", nb)
                continue
            if op == "dynamic-update-slice":
                # in-place on TPU: read + write the update region only
                idx = line.index(op + "(")
                ops_ = _OPERAND.findall(line[idx:])
                upd = _bytes_of_shape_text(shapes.get(ops_[1])) if len(ops_) > 1 else 0
                total.bytes += 2 * upd
                total.add_op("dynamic-update-slice", 2 * upd)
                continue
            if op in ("broadcast", "convert"):
                # always fused into consumers on TPU (and CPU): no HBM
                # traffic of their own; count the (tiny) flops only
                total.flops += _elems_of_result(result)
                continue
            # other top-level op (copy, transpose, reduce, elementwise...)
            out_b = _bytes_of_shape_text(result)
            total.flops += out_b / 4.0  # ~1 flop per element (minor)
            nb = operand_bytes(line, op) + out_b
            total.bytes += nb
            total.add_op(op, nb)
        return total

    root = comp_cost(entry)
    return HloCost(flops=root.flops, bytes=root.bytes, coll=dict(root.coll),
                   by_op=dict(root.by_op), coll_shapes=dict(root.coll_shapes))
