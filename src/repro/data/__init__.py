"""repro.data — sharded synthetic data pipeline."""

from repro.data.pipeline import DataConfig, batch_iterator, input_specs_train, synthetic_batch
