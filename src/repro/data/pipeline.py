"""Data pipeline: deterministic sharded synthetic token streams.

Production framing (scale deliverable): each host materializes only its
slice of the global batch (``host_batch = global_batch / num_hosts``),
keyed by (seed, step, host) so restarts resume mid-stream with no
coordination — the data layer's contribution to checkpoint/restart fault
tolerance.  Swap ``synthetic_batch`` for a real tokenized corpus reader
with the same interface to train on data.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["DataConfig", "synthetic_batch", "batch_iterator", "input_specs_train"]


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0


def synthetic_batch(
    cfg: ModelConfig,
    shape: ShapeConfig,
    step: int,
    data: DataConfig = DataConfig(),
) -> Dict[str, jnp.ndarray]:
    """Deterministic per-(step, host) batch.  Token stream is a mixture
    of zipf-ish draws so the loss curve is non-degenerate."""
    host_batch = shape.global_batch // data.num_hosts
    rng = np.random.default_rng(
        (data.seed * 1_000_003 + step) * 4099 + data.host_id
    )
    # zipf-like marginal over the vocab, cheap to sample
    u = rng.random((host_batch, shape.seq_len))
    toks = np.minimum(
        (u ** -1.2).astype(np.int64) % cfg.vocab_size, cfg.vocab_size - 1
    ).astype(np.int32)
    batch = {
        "tokens": jnp.asarray(toks),
        "labels": jnp.asarray(np.roll(toks, -1, axis=1)),
    }
    if cfg.frontend == "audio":
        emb = rng.standard_normal((host_batch, shape.seq_len, cfg.d_model)) * 0.02
        batch["enc_embeds"] = jnp.asarray(emb, jnp.bfloat16)
    elif cfg.frontend == "vision":
        emb = rng.standard_normal((host_batch, cfg.num_patches, cfg.d_model)) * 0.02
        batch["patch_embeds"] = jnp.asarray(emb, jnp.bfloat16)
    return batch


def batch_iterator(
    cfg: ModelConfig,
    shape: ShapeConfig,
    start_step: int = 0,
    data: DataConfig = DataConfig(),
) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, shape, step, data)
        step += 1


def input_specs_train(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.frontend == "audio":
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S), jnp.int32)  # replaced below
        specs["enc_embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "vision":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), jnp.bfloat16
        )
        specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
    return specs
