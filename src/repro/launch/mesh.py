"""Production mesh construction (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axis semantics: "pod" = pure data parallelism over the cross-pod DCN
    (gradient all-reduce only, int8-compressible); "data" = within-pod
    data/FSDP axis; "model" = tensor/sequence parallel axis on ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI: same axis names, tiny shapes."""
    if pod:
        axes = ("pod", "data", "model")
        return jax.make_mesh(
            (pod, data, model), axes,
            axis_types=(jax.sharding.AxisType.Auto,) * 3,
        )
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def batch_axes(mesh) -> tuple:
    """Physical axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
