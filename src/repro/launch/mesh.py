"""Production mesh construction (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state.
"""

from __future__ import annotations

from repro.compat import make_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axis semantics: "pod" = pure data parallelism over the cross-pod DCN
    (gradient all-reduce only, int8-compressible); "data" = within-pod
    data/FSDP axis; "model" = tensor/sequence parallel axis on ICI.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CI: same axis names, tiny shapes."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Physical axes the global batch shards over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
