"""Batched serving driver: prefill + decode loop with a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
        --scale smoke --requests 8 --max-new 16

Implements the serving side of the framework: continuous batching
(slots are re-filled from the queue as sequences finish), family-aware
caches (KV ring buffer / SSM state / RWKV shift state), greedy sampling.

Like ``launch.train``, the server's datatype communication seam is a
*production* Communicator (``repro.measure.production``): calibrated
tables + a pinned decisions file mean the strategy model runs at most
once per deployment, not once per process.
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models.model import build_model

__all__ = ["ServeLoop", "Request"]


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeLoop:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, cfg: ModelConfig, batch_size: int, max_len: int,
                 comm=None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = self.model.init(jax.random.PRNGKey(0))
        self.B = batch_size
        self.max_len = max_len
        self.cache = self.model.init_cache(batch_size, max_len)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.slot_pos = np.zeros(batch_size, np.int32)
        self._decode = jax.jit(self.model.decode_step)
        #: datatype-communication seam (production Communicator); every
        #: cross-device exchange a deployment adds goes through it so
        #: calibrated params + pinned decisions apply uniformly
        self.comm = comm

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        self.slots[slot] = req
        self.slot_pos[slot] = 0
        return True

    def step(self, t: int):
        """One global decode step: each active slot feeds its next
        prompt token (teacher-forced prefill-by-decode, family-agnostic)
        or its last generated token."""
        toks = np.zeros(self.B, np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = self.slot_pos[i]
            if p < len(req.prompt):
                toks[i] = req.prompt[p]
            else:
                toks[i] = req.out[-1] if req.out else 0
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), jnp.int32(t)
        )
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pos[i] >= len(req.prompt):
                req.out.append(int(nxt[i]))
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.slots[i] = None

    def run(self, queue: List[Request]) -> Dict[int, List[int]]:
        pending = list(queue)
        t = 0
        done: Dict[int, List[int]] = {}
        while pending or any(self.slots):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.step(t)
            t += 1
            for r in queue:
                if r.done and r.rid not in done:
                    done[r.rid] = r.out
            if t >= self.max_len:
                break
        return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCHS))
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--comm-cache", default=None, metavar="DIR",
                    help="measure-store root for the production "
                         "communicator")
    ap.add_argument("--no-comm-cache", action="store_true",
                    help="skip calibration/decision pinning entirely")
    ap.add_argument("--halo-steps", default="auto", metavar="auto|N",
                    help="fusion depth for any deep-halo stencil program "
                         "the deployment builds; 'auto' is model-priced "
                         "and pinned through the decisions file")
    ap.add_argument("--smoother-iters", type=int, default=1,
                    help="iterations of the data-axis smoother workload "
                         "(the in-launch HaloProgram exercising "
                         "--halo-steps end to end; 0 disables)")
    ap.add_argument("--smoother-cycle", default="smooth",
                    help="op cycle the smoother fuses (see "
                         "repro.launch.smoother.CYCLES)")
    ap.add_argument("--ranks-per-node", type=int, default=None,
                    metavar="N",
                    help="declare the two-level machine shape: ranks "
                         "blocked N-per-node (repro.comm.topology); the "
                         "model prices intra- vs inter-node links "
                         "separately and keys wire/program pins by the "
                         "topology fingerprint (default: flat)")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach the runtime exchange probe "
                         "(repro.fleet): observed-vs-predicted wall time "
                         "per decision key, persisted to telemetry.json "
                         "in the measure store on save")
    ap.add_argument("--drift-report", default=None, metavar="FILE",
                    help="write a repro.fleet DriftReport JSON after the "
                         "run (implies --telemetry)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record hierarchical exchange spans (repro.obs) "
                         "and export a Chrome-trace JSON here (render "
                         "with `python -m repro.obs summary`)")
    args = ap.parse_args()

    from repro.halo.program import parse_halo_steps, set_default_halo_steps

    halo_steps = parse_halo_steps(args.halo_steps)

    cfg = get_config(args.arch) if args.scale == "full" else smoke_config(args.arch)
    comm = save_decisions = None
    want_telemetry = bool(args.telemetry or args.drift_report)
    if not args.no_comm_cache:
        from repro.measure.production import production_communicator

        topology = None
        if args.ranks_per_node:
            from repro.comm.topology import Topology

            topology = Topology.blocked(
                jax.device_count(), args.ranks_per_node
            )
        comm, save_decisions = production_communicator(
            args.comm_cache, halo_steps=halo_steps,
            telemetry=want_telemetry or None,
            tracer=bool(args.trace) or None,
            topology=topology,
        )
        dc = comm.model.decisions
        topo_note = (
            f" topo={topology.fingerprint}({topology.nnodes} nodes)"
            if topology is not None else ""
        )
        print(f"comm: params={comm.model.params.name} "
              f"pinned_decisions={len(dc)} halo_steps={halo_steps} "
              f"pinned_programs={len(dc.program_rows())}{topo_note}")
    else:
        set_default_halo_steps(halo_steps)
    if args.smoother_iters > 0 and comm is not None:
        # the deployment's deep-halo workload: a state-smoothing pass
        # over the data axis through the same production communicator,
        # so the --halo-steps seam is exercised (and pinned) in serving
        # jobs too
        from repro.launch.smoother import run_smoother

        report = run_smoother(comm, iters=args.smoother_iters,
                              cycle=args.smoother_cycle, axis_name="data")
        print(report.summary)
    loop = ServeLoop(cfg, args.batch, args.max_len, comm=comm)
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=list(rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12))),
            max_new=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = loop.run(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(v) for v in done.values())
    print(f"served {len(done)}/{args.requests} requests, "
          f"{total_new} tokens in {dt:.1f}s "
          f"({total_new/dt:.1f} tok/s, batch={args.batch}, {cfg.name})")
    for rid in sorted(done)[:3]:
        print(f"  req {rid}: {done[rid][:8]}...")
    if save_decisions is not None:
        path = save_decisions()
        print(f"comm: decisions -> {path}")
    if args.trace and comm is not None and comm.tracer is not None:
        from repro.obs.export import save_chrome_trace

        tpath = save_chrome_trace(comm.tracer, args.trace)
        print(f"trace ({len(comm.tracer)} spans) -> {tpath}")
    if comm is not None and want_telemetry:
        print(comm.telemetry.report())
        if args.drift_report:
            from repro.fleet.drift import DriftDetector

            drift = DriftDetector().audit(
                comm.model.decisions, comm.model.params,
                telemetry=comm.telemetry, system="serve",
            )
            print(f"drift report -> {drift.save(args.drift_report)}")


if __name__ == "__main__":
    main()
