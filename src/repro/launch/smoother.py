"""In-launch diffusion-style smoother over the data axis.

The deep-halo :class:`~repro.halo.program.HaloProgram` layer existed
(PR 4) but no in-tree launch workload built one — ``--halo-steps`` on
``launch.train`` / ``launch.serve`` installed a default nobody read.
This module is that workload: a 3D scalar field sharded over the data
axis, smoothed by a stencil cycle compiled into ONE fused deep-halo
program — so the production communicator's calibrated tables price the
fusion depth, the choice lands in the job's decisions file as a
``program/s=N`` row, and a rerun pins it.  The train driver runs it as
a data-conditioning pass before the step loop; the serve driver runs it
once at deployment startup, before the serve loop is built; CI runs it
one step and asserts the decision row exists.

Cycles:

``smooth``
    the paper's 26-point op applied each repeat — the classic diffusion
    smoother.
``predictor-corrector``
    a two-op cycle: a far-reaching predictor (radii ``(2, 1, 1)`` —
    deeper along the slow/sharded axis) followed by a local corrector
    (the 26-point op at a lighter weight).  Unequal per-dimension radii
    exercise the cumulative-radii halo end to end.

    PYTHONPATH=src python -m repro.launch.smoother --iters 1 \
        --halo-steps auto --comm-cache /tmp/ci_store --assert-decision
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass
from typing import Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.comm.api import as_communicator
from repro.halo.program import (
    HaloProgram,
    build_halo_program,
    make_program_step,
)
from repro.halo.stencil import STENCIL26, StencilOp

__all__ = ["CYCLES", "SmootherReport", "run_smoother", "smoother_cycle"]

#: the in-launch cycles by name (argparse choices on every driver)
CYCLES: Tuple[str, ...] = ("smooth", "predictor-corrector")


def smoother_cycle(name: str) -> Tuple[StencilOp, ...]:
    """The op cycle a ``--smoother-cycle`` name denotes."""
    if name == "smooth":
        return (STENCIL26,)
    if name == "predictor-corrector":
        return (StencilOp((2, 1, 1), weight=0.5), StencilOp((1, 1, 1), weight=0.25))
    raise ValueError(f"unknown smoother cycle {name!r}; expected one of {CYCLES}")


@dataclass(frozen=True)
class SmootherReport:
    """What one smoother run did — the launch drivers print it and the
    CI step asserts on it."""

    program: HaloProgram
    iterations: int
    checksum: float      # interior sum after the run (reproducibility probe)
    decision_recorded: bool  # a program/s=N row exists in the decisions

    @property
    def summary(self) -> str:
        p = self.program
        return (
            f"smoother: cycle_len={p.cycle_len} steps={p.steps}"
            f"{' (pinned)' if p.pinned else ''} "
            f"applications={self.iterations * p.applications} "
            f"exchanges/cycle={p.exchanges_per_cycle:.2f} "
            f"wire={p.plan.wire.schedule}/{p.plan.wire.issued_bytes}B "
            f"checksum={self.checksum:.6e}"
        )


def run_smoother(
    comm,
    iters: int = 1,
    interior: Tuple[int, int, int] = (8, 8, 8),
    cycle: str = "predictor-corrector",
    halo_steps: Union[int, str, None] = None,
    axis_name: str = "data",
    seed: int = 0,
    devices=None,
    overlap: str = "off",
) -> SmootherReport:
    """Smooth a sharded 3D field with one fused deep-halo program.

    The field is sharded over ``len(devices)`` ranks along the leading
    (slow) dimension — the data axis — with a periodic domain; each
    iteration is ONE exchange plus ``steps`` repeats of the cycle.
    ``halo_steps=None`` resolves through the process default
    (``--halo-steps`` / ``production_communicator(halo_steps=...)``), so
    this is the end-to-end path for the fusion-depth seam: with
    ``"auto"`` the depth is priced on the communicator's calibrated
    tables and recorded/pinned in its decisions cache.

    ``overlap`` selects exchange/compute overlap for the compiled step:
    ``"off"`` (the plain exchange-then-cycle iteration) or an overlap
    mode — ``"monolithic"``, ``"region"`` (per-delta-class drains feed
    the core/face/edge/corner region scheduler), or ``"auto"`` (the
    model picks and pins an ``overlap/mode=...`` decision).  All modes
    are bit-identical; the checksum must not move.
    """
    comm = as_communicator(comm)
    if overlap not in ("off", "monolithic", "region", "auto"):
        raise ValueError(
            f"unknown overlap {overlap!r}; expected off, monolithic, "
            "region or auto"
        )
    devs = list(devices if devices is not None else jax.devices())
    R = len(devs)
    grid = (R, 1, 1)
    ops = smoother_cycle(cycle)
    program = build_halo_program(
        grid, interior, comm, ops=ops, steps=halo_steps
    )
    mesh = Mesh(np.array(devs), (axis_name,))
    step = make_program_step(
        program, comm, mesh, axis_name,
        overlap=False if overlap == "off" else overlap,
    )

    nz, ny, nx = interior
    rz, ry, rx = program.spec.radii
    az, ay, ax = program.spec.alloc
    rng = np.random.default_rng(seed)
    state = np.zeros((R, az, ay, ax), np.float32)
    state[:, rz:rz + nz, ry:ry + ny, rx:rx + nx] = rng.normal(
        size=(R, nz, ny, nx)
    ).astype(np.float32)
    x = jnp.asarray(state.reshape(R * az, ay, ax))
    telemetry = getattr(comm, "telemetry", None)
    tracer = getattr(comm, "tracer", None)
    if tracer is not None and not getattr(tracer, "enabled", False):
        tracer = None
    if telemetry is None and tracer is None:
        for _ in range(iters):
            x = step(x)
    else:
        # telemetry/tracing: the program runs jitted, so the
        # Communicator's eager probes never fire — time the compiled
        # step here instead.  AOT-compile first so compile time never
        # pollutes the samples, and block each iteration (async dispatch
        # would under-report).  The tracer gets the same observation as
        # an attributed span tree: the measured iteration wall time
        # split across phases in the model's predicted proportions.
        import time

        from repro.fleet.telemetry import predict_program_phases

        phases = predict_program_phases(program, comm.model)
        predicted = sum(phases.values())
        if telemetry is not None:
            telemetry.register(
                program.fingerprint, predicted, f"program/s={program.steps}"
            )
        # overlap runs care about per-direction completion: attribute
        # the wire span across the delta classes in the model's
        # predicted completion profile so a slow link is visible per
        # class, not just per exchange
        class_pred: Tuple[float, ...] = ()
        if overlap != "off" and tracer is not None:
            try:
                class_pred = tuple(
                    comm.model.price_class_completions(program.plan.wire)
                )
            except Exception:
                class_pred = ()
        try:
            run = step.lower(x).compile()
        except AttributeError:  # not a jit-wrapped callable
            run = step
        jax.block_until_ready(x)
        for i in range(iters):
            t0 = time.perf_counter()
            x = run(x)
            jax.block_until_ready(x)
            dt = time.perf_counter() - t0
            if telemetry is not None:
                telemetry.observe(program.fingerprint, dt)
            if tracer is not None:
                from repro.obs.trace import attribute_program_iteration

                attribute_program_iteration(
                    tracer, program, t0, dt, phases, iteration=i,
                    class_pred=class_pred,
                )
    out = np.asarray(x).reshape(R, az, ay, ax)
    checksum = float(
        out[:, rz:rz + nz, ry:ry + ny, rx:rx + nx].sum()
    )
    decisions = comm.model.decisions
    recorded = bool(
        decisions is not None
        and any(
            d.fingerprint == program.fingerprint
            for d in decisions.program_rows()
        )
    )
    return SmootherReport(
        program=program,
        iterations=iters,
        checksum=checksum,
        decision_recorded=recorded,
    )


def main() -> None:
    ap = argparse.ArgumentParser(prog="repro.launch.smoother",
                                 description=__doc__)
    ap.add_argument("--iters", type=int, default=1)
    ap.add_argument("--interior", type=int, default=8,
                    help="interior cube side per rank")
    ap.add_argument("--cycle", default="predictor-corrector", choices=CYCLES)
    ap.add_argument("--halo-steps", default="auto", metavar="auto|N")
    ap.add_argument("--overlap", default="off",
                    choices=("off", "monolithic", "region", "auto"),
                    help="exchange/compute overlap for the compiled "
                         "step: off, monolithic (one wait), region "
                         "(per-delta-class drains feed the core/rim "
                         "scheduler), or auto (model-priced, pinned "
                         "as an overlap/mode=... decision)")
    ap.add_argument("--comm-cache", default=None, metavar="DIR",
                    help="measure-store root for the production "
                         "communicator (calibrated params + decisions "
                         "file; decisions are saved back)")
    ap.add_argument("--assert-decision", action="store_true",
                    help="exit 1 unless a program/s=N decision row was "
                         "recorded (or pinned) for this program — the "
                         "CI gate on the end-to-end --halo-steps seam")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach the runtime exchange probe: per-"
                         "iteration wall time vs the model's prediction, "
                         "persisted to telemetry.json in the store "
                         "(render with `python -m repro.fleet report`)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record hierarchical spans (repro.obs) and "
                         "export a Chrome-trace JSON here — loadable in "
                         "Perfetto/chrome://tracing, rendered by "
                         "`python -m repro.obs summary`, validated by "
                         "`python -m repro.obs validate`")
    ap.add_argument("--drift-report", default=None, metavar="FILE",
                    help="write a DriftReport JSON after the run "
                         "(implies --telemetry)")
    ap.add_argument("--drift-reference", default=None, metavar="ENVELOPE",
                    help="reference params envelope for the drift audit "
                         "(default: self-audit on telemetry only)")
    ap.add_argument("--assert-no-drift", action="store_true",
                    help="exit 1 when the drift audit flags any decision "
                         "— the CI drift gate")
    args = ap.parse_args()

    from repro.halo.program import parse_halo_steps
    from repro.measure.production import production_communicator

    halo_steps = parse_halo_steps(args.halo_steps)
    want_telemetry = bool(
        args.telemetry or args.drift_report or args.assert_no_drift
    )
    comm, save_decisions = production_communicator(
        args.comm_cache, axis_name="data", halo_steps=halo_steps,
        telemetry=want_telemetry or None,
        tracer=bool(args.trace) or None,
    )
    n = args.interior
    report = run_smoother(comm, iters=args.iters, interior=(n, n, n),
                          cycle=args.cycle, overlap=args.overlap)
    print(report.summary)
    if args.trace:
        from repro.obs.export import save_chrome_trace

        path = save_chrome_trace(comm.tracer, args.trace)
        print(f"trace ({len(comm.tracer)} spans) -> {path}")
    rows = comm.model.decisions.program_rows()
    for d in rows:
        print(f"decision: {d.strategy} fp={d.fingerprint} {d.signature}")
    path = save_decisions()
    print(f"decisions -> {path}")
    if want_telemetry:
        print(comm.telemetry.report())
    if args.drift_report or args.assert_no_drift:
        from repro.fleet.drift import DriftDetector
        from repro.measure.store import ParamsStore

        reference = (
            ParamsStore.read_envelope(args.drift_reference)
            if args.drift_reference else None
        )
        if args.drift_reference and reference is None:
            raise SystemExit(
                f"unreadable reference envelope {args.drift_reference}"
            )
        trace_agg = (
            comm.tracer.phase_aggregates()
            if args.trace and getattr(comm, "tracer", None) is not None
            else None
        )
        drift = DriftDetector().audit(
            comm.model.decisions, comm.model.params,
            reference=reference, telemetry=comm.telemetry,
            system="smoother", trace=trace_agg,
        )
        print(drift.summary())
        if args.drift_report:
            print(f"drift report -> {drift.save(args.drift_report)}")
        if args.assert_no_drift and drift.drifted_count:
            raise SystemExit(
                f"DRIFT: {drift.drifted_count} decision(s) out of band"
            )
    if args.assert_decision:
        ok = report.decision_recorded or report.program.pinned
        if not ok:
            raise SystemExit(
                "no program/s=N decision row recorded for the smoother "
                "program — the --halo-steps auto seam is broken"
            )
        print("SMOOTHER_DECISION_OK")


if __name__ == "__main__":
    main()
