"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-0.5b --steps 100 --scale smoke   # CPU-sized run
    PYTHONPATH=src python -m repro.launch.train --arch repro-100m --steps 300

Wires together: config -> model -> sharded params/opt -> data pipeline ->
jit'd train step (in/out shardings from the rule set) -> checkpoint
manager (restore-on-start, periodic atomic saves) -> straggler monitor.

Datatype communication goes through a *production* Communicator
(``repro.measure.production``): the first run on a machine calibrates
the system tables once (reduced grid off-TPU) and records every
strategy selection to a decisions file in the measure store; later runs
load both and pin the selections — the model is never consulted again
(``--no-comm-cache`` skips all of it).
"""

from __future__ import annotations

import argparse
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.data.pipeline import DataConfig, synthetic_batch
from repro.distributed.sharding import (
    DEFAULT_RULES,
    tree_partition_specs,
    use_rules,
)
from repro.launch.mesh import make_test_mesh
from repro.models.model import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import StragglerMonitor
from repro.train.grad_wire import GRAD_WIRE_MODES, GradWire
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_grad_step, make_train_step

#: ~100M-parameter config for the end-to-end example (deliverable b)
REPRO_100M = ModelConfig(
    name="repro-100m", family="dense",
    num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
    d_ff=2048, vocab_size=32000, remat=False,
)


def resolve_config(arch: str, scale: str) -> ModelConfig:
    if arch == "repro-100m":
        cfg = REPRO_100M
    else:
        cfg = get_config(arch) if scale == "full" else smoke_config(arch)
    return cfg


def train(
    cfg: ModelConfig,
    steps: int,
    seq_len: int,
    global_batch: int,
    ckpt_dir: str,
    mesh=None,
    log_every: int = 10,
    ckpt_every: int = 100,
    comm=None,
    grad_wire: str = "off",
) -> dict:
    shape = ShapeConfig("train", seq_len, global_batch, "train")
    model = build_model(cfg)
    opt_cfg = AdamWConfig(
        moment_dtype=cfg.opt_moment_dtype, total_steps=max(steps, 10)
    )
    # "off" keeps the fused, donating train step; any other mode splits
    # it so the gradient exchange runs through the communicator's wire
    # stack between the jitted halves (model-priced, pinned, audited)
    wire = None
    if grad_wire != "off":
        if comm is None:
            raise ValueError(
                f"--grad-wire {grad_wire} needs a communicator "
                "(incompatible with --no-comm-cache)"
            )
        wire = GradWire(comm, mode=grad_wire)
        grad_fn, update_fn = make_grad_step(model, opt_cfg)
    else:
        step_fn = make_train_step(model, opt_cfg)
    mgr = CheckpointManager(ckpt_dir, every=ckpt_every)
    monitor = StragglerMonitor()

    if mesh is None and jax.device_count() >= 4:
        mesh = make_test_mesh(data=2, model=2)

    with use_rules(mesh, DEFAULT_RULES):
        if mesh is not None:
            p_specs = tree_partition_specs(
                jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))),
                DEFAULT_RULES, mesh,
            )
            p_shard = jax.tree.map(
                lambda s: NamedSharding(mesh, s), p_specs,
                is_leaf=lambda x: isinstance(x, P),
            )
            init = jax.jit(model.init, out_shardings=p_shard)
        else:
            init = jax.jit(model.init)

        def make_state():
            params = init(jax.random.PRNGKey(0))
            return {"params": params, "opt": init_opt_state(params, opt_cfg)}

        start, state = mgr.restore_or_init(make_state)
        if start:
            print(f"restored checkpoint at step {start}")
        params, opt_state = state["params"], state["opt"]

        if wire is not None:
            jit_grads = jax.jit(grad_fn)
            jit_update = jax.jit(update_fn, donate_argnums=(0, 1))
        else:
            jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        history = []
        for step in range(start, steps):
            t0 = time.perf_counter()
            batch = synthetic_batch(cfg, shape, step)
            if wire is not None:
                loss, metrics0, grads = jit_grads(params, batch)
                if not wire.planned:
                    # first concrete gradients are the calibration
                    # probe: the ratio is measured, never assumed
                    wire.plan_for(grads)
                    print(wire.describe())
                grads = wire.exchange(grads)
                params, opt_state, metrics = jit_update(
                    params, opt_state, grads, loss, metrics0
                )
            else:
                params, opt_state, metrics = jit_step(
                    params, opt_state, batch
                )
            metrics = jax.device_get(metrics)
            dt = time.perf_counter() - t0
            verdict = monitor.observe(step, dt)
            if verdict == "remesh":
                print(f"straggler policy escalation at step {step} "
                      f"(persistently slow steps) — checkpoint + remesh")
            history.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                print(
                    f"step {step:5d} loss {metrics['loss']:.4f} "
                    f"gnorm {metrics['grad_norm']:.3f} "
                    f"lr {metrics['lr']:.2e} {dt*1e3:.0f}ms [{verdict}]"
                )
            mgr.maybe_save(step, {"params": params, "opt": opt_state})

        mgr.maybe_save(steps, {"params": params, "opt": opt_state})
    out = {"losses": history, "params": params}
    if comm is not None:
        out["comm_stats"] = comm.stats()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="repro-100m",
                    choices=["repro-100m", *ARCHS])
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--comm-cache", default=None, metavar="DIR",
                    help="measure-store root for the production "
                         "communicator (default: $REPRO_MEASURE_DIR or "
                         "the user cache dir)")
    ap.add_argument("--no-comm-cache", action="store_true",
                    help="skip calibration/decision pinning entirely "
                         "(analytic model, nothing persisted)")
    ap.add_argument("--grad-wire", default="off", choices=GRAD_WIRE_MODES,
                    help="route the optimizer gradient exchange through "
                         "the production communicator as a committed "
                         "type: 'auto' is model-priced from a probe of "
                         "the first step's gradients (a compressible "
                         "payload rides the lossless varlen RLE wire), "
                         "'rle' forces it, 'int8' opts into the lossy "
                         "quantized wire (never auto-picked)")
    ap.add_argument("--halo-steps", default="auto", metavar="auto|N",
                    help="fusion depth for any deep-halo stencil program "
                         "the job builds (repro.halo.program); 'auto' is "
                         "model-priced and pinned through the decisions "
                         "file so reruns reuse the same depth")
    ap.add_argument("--smoother-iters", type=int, default=1,
                    help="iterations of the data-axis smoother workload "
                         "run before training (the in-launch HaloProgram "
                         "that exercises --halo-steps end to end; 0 "
                         "disables)")
    ap.add_argument("--smoother-cycle", default="predictor-corrector",
                    help="op cycle the smoother fuses (see "
                         "repro.launch.smoother.CYCLES)")
    ap.add_argument("--ranks-per-node", type=int, default=None,
                    metavar="N",
                    help="declare the two-level machine shape: ranks are "
                         "blocked N-per-node (repro.comm.topology), the "
                         "model prices intra- vs inter-node links "
                         "separately, and wire/program pins are keyed by "
                         "the topology fingerprint (default: flat)")
    ap.add_argument("--telemetry", action="store_true",
                    help="attach the runtime exchange probe "
                         "(repro.fleet): observed-vs-predicted wall time "
                         "per decision key, persisted to telemetry.json "
                         "in the measure store on save")
    ap.add_argument("--drift-report", default=None, metavar="FILE",
                    help="write a repro.fleet DriftReport JSON after the "
                         "run (implies --telemetry)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record hierarchical exchange spans (repro.obs) "
                         "and export a Chrome-trace JSON here (render "
                         "with `python -m repro.obs summary`)")
    args = ap.parse_args()

    from repro.halo.program import parse_halo_steps

    halo_steps = parse_halo_steps(args.halo_steps)

    cfg = resolve_config(args.arch, args.scale)
    n = cfg.param_count()
    print(f"training {cfg.name} ({n/1e6:.1f}M params, family={cfg.family}) "
          f"for {args.steps} steps @ seq={args.seq_len} batch={args.global_batch}")

    comm = save_decisions = None
    want_telemetry = bool(args.telemetry or args.drift_report)
    if not args.no_comm_cache:
        from repro.measure.production import production_communicator

        topology = None
        if args.ranks_per_node:
            from repro.comm.topology import Topology

            topology = Topology.blocked(
                jax.device_count(), args.ranks_per_node
            )
        comm, save_decisions = production_communicator(
            args.comm_cache, axis_name="data", halo_steps=halo_steps,
            telemetry=want_telemetry or None,
            tracer=bool(args.trace) or None,
            topology=topology,
        )
        dc = comm.model.decisions
        topo_note = (
            f" topo={topology.fingerprint}({topology.nnodes} nodes)"
            if topology is not None else ""
        )
        print(f"comm: params={comm.model.params.name} "
              f"pinned_decisions={len(dc)} halo_steps={halo_steps} "
              f"pinned_programs={len(dc.program_rows())}{topo_note}")
    else:
        from repro.halo.program import set_default_halo_steps

        set_default_halo_steps(halo_steps)

    if args.smoother_iters > 0 and comm is not None:
        # the in-launch deep-halo workload: smooth a data-axis field
        # before training so the fusion-depth seam (--halo-steps ->
        # production communicator -> build_halo_program -> decisions
        # file) runs end to end on every job
        from repro.launch.smoother import run_smoother

        report = run_smoother(comm, iters=args.smoother_iters,
                              cycle=args.smoother_cycle)
        print(report.summary)

    out = train(cfg, args.steps, args.seq_len, args.global_batch,
                args.ckpt_dir, comm=comm, grad_wire=args.grad_wire)
    losses = out["losses"]
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"(delta {losses[0]-losses[-1]:+.4f})")
    if save_decisions is not None:
        path = save_decisions()
        dc = comm.model.decisions
        print(f"comm: recorded {len(dc)} decisions "
              f"({dc.pinned_hits} pinned hits) -> {path}")
    if args.trace and comm is not None and comm.tracer is not None:
        from repro.obs.export import save_chrome_trace

        tpath = save_chrome_trace(comm.tracer, args.trace)
        print(f"trace ({len(comm.tracer)} spans) -> {tpath}")
    if comm is not None and want_telemetry:
        print(comm.telemetry.report())
        if args.drift_report:
            from repro.fleet.drift import DriftDetector

            drift = DriftDetector().audit(
                comm.model.decisions, comm.model.params,
                telemetry=comm.telemetry, system="train",
            )
            print(f"drift report -> {drift.save(args.drift_report)}")


if __name__ == "__main__":
    main()
