import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import: jax locks device count on first init.
"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) on the production meshes, print
memory_analysis / cost_analysis, and emit the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --out dryrun.json

Skip rules (recorded as SKIP rows, DESIGN.md §Arch-applicability):
  * long_500k on pure full-attention archs (quadratic; no sub-quadratic
    path) — runs for SSM/hybrid/SWA archs with rolling/state caches.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, shape_for
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import input_specs_train
from repro.distributed.sharding import (
    DEFAULT_RULES,
    ShardingRules,
    tree_partition_specs,
    use_rules,
)
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models.model import Model, build_model
from repro.roofline.analysis import HW_V5E, analyze
from repro.roofline.hlo_cost import cost_analysis_dict
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step

ENC_LEN = 4096  # cross-attention context for encdec decode shapes


# ---------------------------------------------------------------------------
# cell applicability
# ---------------------------------------------------------------------------

def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.kind == "long-decode" and not cfg.sub_quadratic:
        return "full-attention arch: 500k decode needs sub-quadratic path"
    return None


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def _tree_shardings(shapes_tree, rules, mesh):
    specs = tree_partition_specs(shapes_tree, rules, mesh)
    return jax.tree.map(lambda s: _ns(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_sharding(specs: Dict[str, jax.ShapeDtypeStruct], rules, mesh):
    b_ax = batch_axes(mesh)
    out = {}
    for k, v in specs.items():
        if k == "positions":  # (3, B, S)
            spec = P(None, rules.resolve("batch", mesh, v.shape[1]), None)
        else:
            spec = P(
                rules.resolve("batch", mesh, v.shape[0]),
                *([None] * (len(v.shape) - 1)),
            )
        out[k] = _ns(mesh, spec)
    return out


_CACHE_AXES = {
    "k": (None, "batch", "kv_seq", None, None),
    "v": (None, "batch", "kv_seq", None, None),
    "xk": (None, "batch", "kv_seq", None, None),
    "xv": (None, "batch", "kv_seq", None, None),
    "shared_k": (None, "batch", "kv_seq", None, None),
    "shared_v": (None, "batch", "kv_seq", None, None),
    "kpos": (None,),
    "conv": (None, "batch", None, "heads"),
    "ssm": (None, "batch", "state", None, None),
    "wkv": (None, "batch", "state", None, None),
    "shift_t": (None, "batch", None),
    "shift_c": (None, "batch", None),
}


def _cache_shardings(cache_shapes, rules, mesh):
    out = {}
    for k, v in cache_shapes.items():
        axes = _CACHE_AXES[k]
        spec = P(*(rules.resolve(a, mesh, d) for a, d in zip(axes, v.shape)))
        out[k] = _ns(mesh, spec)
    return out


# ---------------------------------------------------------------------------
# cell construction: (fn, arg_shapes, in_shardings)
# ---------------------------------------------------------------------------

def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    model = build_model(cfg)
    params_s = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_shard = _tree_shardings(params_s, rules, mesh)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.opt_moment_dtype)
        opt_s = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), params_s)
        o_shard = {
            "mu": _tree_shardings(opt_s["mu"], rules, mesh),
            "nu": _tree_shardings(opt_s["nu"], rules, mesh),
            "step": _ns(mesh, P()),
        }
        batch_s = input_specs_train(cfg, shape)
        b_shard = _batch_sharding(batch_s, rules, mesh)
        fn = make_train_step(model, opt_cfg)
        args = (params_s, opt_s, batch_s)
        shardings = (p_shard, o_shard, b_shard)
        donate = (0, 1)
        return fn, args, shardings, donate

    if shape.kind == "prefill":
        batch_s = {
            "tokens": jax.ShapeDtypeStruct(
                (shape.global_batch, shape.seq_len), jnp.int32
            )
        }
        if cfg.frontend == "vision":
            batch_s["patch_embeds"] = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.num_patches, cfg.d_model), jnp.bfloat16
            )
        b_shard = _batch_sharding(batch_s, rules, mesh)
        if cfg.family in ("ssm", "rwkv", "hybrid", "encdec"):
            # recurrent/encdec prefill == forward pass producing last
            # logits (their decode caches are built stepwise)
            def fn(params, batch):
                logits, _ = model.forward(params, batch)
                return logits[:, -1]

            if cfg.family == "encdec":
                batch_s["enc_embeds"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, shape.seq_len, cfg.d_model),
                    jnp.bfloat16,
                )
                b_shard = _batch_sharding(batch_s, rules, mesh)
        else:
            fn = lambda params, batch: model.prefill(params, batch)
        return fn, (params_s, batch_s), (p_shard, b_shard), ()

    # decode / long-decode
    B = shape.global_batch
    cache_s = jax.eval_shape(
        lambda: model.init_cache(B, max_len=shape.seq_len, enc_len=ENC_LEN)
    )
    c_shard = _cache_shardings(cache_s, rules, mesh)
    tok_s = jax.ShapeDtypeStruct((B,), jnp.int32)
    t_s = jax.ShapeDtypeStruct((), jnp.int32)
    tok_shard = _ns(mesh, P(rules.resolve("batch", mesh, B)))
    t_shard = _ns(mesh, P())

    def fn(params, cache, tokens, t):
        return model.decode_step(params, cache, tokens, t)

    return (
        fn,
        (params_s, cache_s, tok_s, t_s),
        (p_shard, c_shard, tok_shard, t_shard),
        (1,),  # donate the cache
    )


# ---------------------------------------------------------------------------
# lower + compile + analyze one cell
# ---------------------------------------------------------------------------

def run_cell(
    arch: str,
    shape_name: str,
    mesh,
    rules: ShardingRules = DEFAULT_RULES,
    verbose: bool = True,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = shape_for(shape_name)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": mesh.devices.size,
    }
    reason = cell_skip_reason(cfg, shape)
    if reason:
        rec["status"] = "SKIP"
        rec["reason"] = reason
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] SKIP: {reason}")
        return rec

    t0 = time.time()
    with use_rules(mesh, rules):
        fn, args, shardings, donate = build_cell(cfg, shape, mesh, rules)
        jfn = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    report = analyze(
        arch, shape_name, mesh_name, mesh.devices.size, cost, hlo, cfg, shape
    )

    rec.update(
        status="OK",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops_per_device=report.hlo_flops,
        bytes_per_device=report.hlo_bytes,
        coll_bytes_per_device=report.coll_bytes,
        coll_by_kind={k: v for k, v in report.coll_by_kind.items() if v},
        model_flops=report.model_flops,
        t_compute_ms=report.t_compute * 1e3,
        t_memory_ms=report.t_memory * 1e3,
        t_collective_ms=report.t_collective * 1e3,
        bottleneck=report.bottleneck,
        useful_flops_ratio=report.useful_flops_ratio,
        roofline_fraction=report.roofline_fraction,
    )
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "generated_code_size_in_bytes"):
        try:
            rec[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s")
        print(f"  memory_analysis: args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"out={rec.get('output_size_in_bytes', 0)/2**30:.2f}GiB (per device)")
        print(f"  cost_analysis: flops/dev={report.hlo_flops:.3e} "
              f"bytes/dev={report.hlo_bytes:.3e} coll/dev={report.coll_bytes:.3e}")
        print(f"  roofline: compute={report.t_compute*1e3:.2f}ms "
              f"memory={report.t_memory*1e3:.2f}ms "
              f"collective={report.t_collective*1e3:.2f}ms "
              f"-> {report.bottleneck}-bound; useful={report.useful_flops_ratio:.2f} "
              f"roofline_frac={report.roofline_fraction:.2f}")
        sys.stdout.flush()
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 mesh (default: 16x16 single pod)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    records = []
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    rec = run_cell(arch, shape, mesh)
                except Exception as e:  # a cell failure is a bug; record it
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "x".join(map(str, mesh.devices.shape)),
                        "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"[{arch} x {shape} ] FAIL: {e}")
                    traceback.print_exc()
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")

    ok = sum(r["status"] == "OK" for r in records)
    skip = sum(r["status"] == "SKIP" for r in records)
    fail = sum(r["status"] == "FAIL" for r in records)
    print(f"\ndry-run complete: {ok} OK, {skip} SKIP, {fail} FAIL "
          f"of {len(records)} cells")
    if fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
