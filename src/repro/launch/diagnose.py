import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Hillclimb diagnosis: compile one cell and print the per-op-kind byte
breakdown + collective split from the loop-aware HLO walk.

    PYTHONPATH=src python -m repro.launch.diagnose --arch mixtral-8x22b \
        --shape train_4k [--multi-pod] [--set microbatches=4 fsdp=False]
"""

import argparse
import sys

import jax

from repro.configs.base import shape_for
from repro.configs.registry import get_config
from repro.distributed.sharding import DEFAULT_RULES, use_rules
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import HW_V5E, analyze
from repro.roofline.hlo_cost import parse_hlo_cost


def parse_overrides(pairs):
    out = {}
    for p in pairs or ():
        k, v = p.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = int(v)
            except ValueError:
                try:
                    v = float(v)
                except ValueError:
                    pass
        out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--dump", default=None, help="write HLO text here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    over = parse_overrides(args.set)
    if over:
        cfg = cfg.replace(**over)
        print(f"overrides: {over}")
    shape = shape_for(args.shape)
    mesh = make_production_mesh(multi_pod=args.multi_pod)

    with use_rules(mesh, DEFAULT_RULES):
        fn, a, sh, don = build_cell(cfg, shape, mesh, DEFAULT_RULES)
        compiled = jax.jit(fn, in_shardings=sh, donate_argnums=don).lower(*a).compile()
    hlo = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(hlo)
    cost = parse_hlo_cost(hlo)
    rep = analyze(args.arch, args.shape, "x".join(map(str, mesh.devices.shape)),
                  mesh.devices.size, {}, hlo, cfg, shape)
    print(f"\nroofline: compute={rep.t_compute*1e3:.1f}ms "
          f"memory={rep.t_memory*1e3:.1f}ms "
          f"collective={rep.t_collective*1e3:.1f}ms -> {rep.bottleneck}")
    print(f"flops/dev={cost.flops:.3e}  bytes/dev={cost.bytes:.3e}  "
          f"coll/dev={cost.coll_bytes:.3e}")
    print("\ntop byte contributors (per device, per step):")
    for op, b in cost.top_ops(20):
        print(f"  {op:24s} {b:.3e} B  ({b/cost.bytes*100:5.1f}% of memory)")
    print("\ncollectives:")
    for k, v in sorted(cost.coll.items(), key=lambda kv: -kv[1]):
        if v:
            print(f"  {k:24s} {v:.3e} B/dev")
    print("\ntop collective shapes (bytes/dev incl. loop trips):")
    for k, v in sorted(cost.coll_shapes.items(), key=lambda kv: -kv[1])[:10]:
        print(f"  {v:.3e}  {k}")
    try:
        mem = compiled.memory_analysis()
        print(f"\nmemory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
    except Exception:
        pass


if __name__ == "__main__":
    main()
