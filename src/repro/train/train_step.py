"""Train / serve step factories: loss, microbatched gradient
accumulation, optimizer update — the functions the launcher jits with
in/out shardings.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, adamw_update

__all__ = ["make_loss_fn", "make_train_step", "make_grad_step",
           "make_prefill_step", "make_decode_step"]

AUX_WEIGHT = 1e-2  # MoE load-balance loss weight


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy, fp32, computed shard-locally over a
    (possibly vocab-sharded) logits tensor."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        labels = batch["labels"]
        loss = cross_entropy(logits, labels) + AUX_WEIGHT * aux
        return loss, {"xent": loss, "moe_aux": aux}

    return loss_fn


def _make_compute_grads(model: Model):
    """The shared gradient half of the step factories: returns
    compute_grads(params, batch) -> (loss, metrics, grads), with
    ``cfg.microbatches`` gradient-accumulation steps (fp32
    accumulators) — the activation-memory knob for the big archs."""
    cfg = model.cfg
    loss_fn = make_loss_fn(model)
    n_micro = max(cfg.microbatches, 1)

    def compute_grads(params, batch):
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        def split(x):
            return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            gacc, lacc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb
            )
            gacc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / n_micro, gacc, grads
            )
            return (gacc, lacc + loss / n_micro), None

        gacc0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        (grads, loss), _ = lax.scan(body, (gacc0, jnp.float32(0.0)), micro)
        return loss, {"xent": loss, "moe_aux": jnp.float32(0.0)}, grads

    return compute_grads


def make_train_step(model: Model, opt_cfg: AdamWConfig):
    """Returns the fused train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    compute_grads = _make_compute_grads(model)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute_grads(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return train_step


def make_grad_step(model: Model, opt_cfg: AdamWConfig):
    """The split factories the wire-routed gradient path needs
    (:class:`repro.train.grad_wire.GradWire` runs *between* them):
    ``grad_fn(params, batch) -> (loss, metrics, grads)`` and
    ``update_fn(params, opt_state, grads, loss, metrics) ->
    (params, opt_state, metrics)``.  Composing them is numerically the
    fused :func:`make_train_step`."""
    compute_grads = _make_compute_grads(model)

    def grad_fn(params, batch):
        return compute_grads(params, batch)

    def update_fn(params, opt_state, grads, loss, metrics):
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        return params, opt_state, {**metrics, **opt_metrics, "loss": loss}

    return grad_fn, update_fn


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, t):
        return model.decode_step(params, cache, tokens, t)

    return decode_step
