"""AdamW in pure JAX with the distributed-optimization features the
scale deliverable asks for:

* **ZeRO-style sharded state** — moments inherit the parameter sharding
  (already model/data sharded for the big archs) and can additionally be
  sharded over the data axis via the state partitioner in
  ``repro.launch``.
* **moment dtype control** — bf16 moments for the 100B+ archs
  (``cfg.opt_moment_dtype``), halving optimizer HBM.
* **global-norm clipping** and decoupled weight decay.
* optional **int8 gradient compression** for the cross-pod (DCN)
  all-reduce: error-feedback quantization applied before the pod-axis
  reduction (``compress_pod_grads``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update", "global_norm",
           "quantize_grad_int8", "dequantize_grad_int8"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


def _mdt(cfg: AdamWConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]


def init_opt_state(params, cfg: AdamWConfig):
    mdt = _mdt(cfg)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(step, cfg: AdamWConfig):
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(step, cfg)
    mdt = _mdt(cfg)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics


# ---------------------------------------------------------------------------
# int8 gradient compression (cross-pod DCN traffic reduction)
# ---------------------------------------------------------------------------

def quantize_grad_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: g ~ q * scale."""
    amax = jnp.max(jnp.abs(g.astype(jnp.float32))) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_grad_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)
