"""Elastic scaling + straggler mitigation (scale deliverable).

At 1000+ nodes the failure model is: a pod/slice drops out (hardware,
preemption), or a host straggles (thermal throttling, ECC retries).  The
policies here are deliberately *mechanism-level* so they run on this
container and on a real cluster:

* **Elastic re-mesh** (`plan_remesh`): given surviving device count,
  pick the largest valid (data, model) mesh <= survivors that preserves
  the model-parallel degree (weights reshard cheaply along data/pod
  only), rescale the per-host batch, and return the new mesh spec.
  `repro.train.checkpoint.restore_checkpoint(shardings=...)` already
  re-shards the state onto the new mesh — together they implement
  checkpoint/restart elasticity.

* **Straggler mitigation** (`StragglerMonitor`): EWMA of per-step wall
  time; a step slower than `threshold` x EWMA flags a straggler event.
  The recommended action at scale is within-step: XLA's collective
  scheduling already overlaps; across steps the monitor recommends
  checkpoint-and-remesh when a host is persistently slow (the same
  elastic path as failures — slow node == failed node policy, standard
  at pod scale).

* **Failure detection** (`heartbeat_check`): in multi-controller JAX the
  runtime surfaces device loss as errors on collectives; the driver
  wraps steps in `try` and escalates to the elastic path.  Here the hook
  is a callable so tests can inject failures.

* **Decision re-planning** (`replan_on_remesh`): a mesh reshape changes
  the machine the performance model priced — wire-schedule, fusion-depth
  and overlap-mode pins recorded under the old rank->node map are stale
  opinions about a machine that no longer exists.  Rather than silently
  replaying them, the replan rebinds the communicator's topology, clears
  the model's selection cache, and *prunes* every topology-sensitive
  decision row recorded under a different (or no) topology tag — the
  next planning pass re-prices on the new shape and re-records.  The
  topology fingerprint inside wire/program decision keys already makes
  stale pins unreachable; pruning keeps the persisted audit log from
  accumulating rows no lookup can ever hit again.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

__all__ = [
    "plan_remesh",
    "StragglerMonitor",
    "ElasticPolicy",
    "ReplanReport",
    "replan_on_remesh",
]

#: decision strategy prefixes whose rows encode topology-dependent
#: choices (wire schedules, fusion depth, overlap mode) — the rows an
#: elastic remesh must never replay across a reshape
TOPOLOGY_SENSITIVE_PREFIXES = ("wire/", "program/s=", "overlap/mode=")


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    global_batch: int


def plan_remesh(
    survivors: int,
    model_parallel: int,
    global_batch: int,
    multi_pod: bool = False,
    pod_size: int = 256,
) -> MeshPlan:
    """Largest usable mesh after losing devices.

    Keeps the model axis fixed (weight shards survive in-place) and
    shrinks the data (and pod) axes; the global batch is scaled down
    proportionally in whole microbatch units so per-device batch stays
    constant (loss scale unchanged).
    """
    if survivors < model_parallel:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with "
            f"{survivors} devices"
        )
    if multi_pod and survivors >= pod_size * 2:
        pods = survivors // pod_size
        data = pod_size // model_parallel
        frac = (pods * pod_size) / (2 * pod_size)
        return MeshPlan(
            (pods, data, model_parallel),
            ("pod", "data", "model"),
            max(int(global_batch * frac), 1),
        )
    data = survivors // model_parallel
    # data axis must divide the batch; round down to a power of two
    data = 2 ** int(math.log2(data)) if data > 0 else 1
    orig_data = survivors // model_parallel
    frac = data / max(orig_data, 1)
    return MeshPlan(
        (data, model_parallel),
        ("data", "model"),
        max(global_batch * data // max(orig_data, 1), 1),
    )


class StragglerMonitor:
    """EWMA step-time monitor with a slow-step escalation policy."""

    def __init__(self, alpha: float = 0.1, threshold: float = 1.5,
                 patience: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ewma: Optional[float] = None
        self.slow_streak = 0
        self.events: List[Tuple[int, float, float]] = []

    def observe(self, step: int, seconds: float) -> str:
        """Returns "ok" | "slow" | "remesh"."""
        if self.ewma is None:
            self.ewma = seconds
            return "ok"
        verdict = "ok"
        if seconds > self.threshold * self.ewma:
            self.slow_streak += 1
            self.events.append((step, seconds, self.ewma))
            verdict = "slow"
            if self.slow_streak >= self.patience:
                verdict = "remesh"
        else:
            self.slow_streak = 0
        # slow steps do not pollute the baseline
        if verdict == "ok":
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return verdict


@dataclass(frozen=True)
class ReplanReport:
    """What an elastic re-plan did to the decision state."""

    old_topology: str           # previous topology fingerprint ("" = flat)
    new_topology: str           # fingerprint now bound to the model
    pruned: Tuple[str, ...]     # "strategy@fingerprint" of demoted rows
    cache_cleared: bool         # model selection cache was dropped

    @property
    def npruned(self) -> int:
        return len(self.pruned)


def replan_on_remesh(comm, topology) -> ReplanReport:
    """Rebind ``comm`` (a :class:`repro.comm.api.Communicator`) to the
    post-reshape ``topology`` and demote every stale topology-sensitive
    pin (see the module docstring).

    A decision row is stale when its strategy is topology-dependent
    (:data:`TOPOLOGY_SENSITIVE_PREFIXES`) and its signature's ``topo=``
    tag names a different topology than the new one — including rows
    recorded with *no* tag (planned flat): the reshape invalidates those
    too, because the flat plan's pricing assumed every hop equal.  Rows
    pinned under the incoming topology's own fingerprint survive (a
    replay onto the same shape is exactly what pins are for).
    """
    model = comm.model
    old = model.topology
    old_fp = old.fingerprint if old is not None else ""
    new_fp = topology.fingerprint if topology is not None else ""
    model.topology = topology
    model._cache.clear()
    pruned: Tuple[str, ...] = ()
    if model.decisions is not None and old_fp != new_fp:
        tag = f"topo={new_fp}" if new_fp else None

        def stale(d) -> bool:
            if not d.strategy.startswith(TOPOLOGY_SENSITIVE_PREFIXES):
                return False
            return tag is None or tag not in (d.signature or "")

        pruned = tuple(
            f"{d.strategy}@{d.fingerprint}"
            for d in model.decisions.prune(stale)
        )
    return ReplanReport(
        old_topology=old_fp,
        new_topology=new_fp,
        pruned=pruned,
        cache_cleared=True,
    )


@dataclass
class ElasticPolicy:
    """Driver-facing bundle: detect -> checkpoint -> remesh -> resume."""

    model_parallel: int
    global_batch: int
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)

    def on_failure(self, survivors: int, multi_pod: bool = False) -> MeshPlan:
        return plan_remesh(
            survivors, self.model_parallel, self.global_batch, multi_pod
        )

    def remesh_and_replan(
        self,
        survivors: int,
        comm,
        ranks_per_node: Optional[int] = None,
        multi_pod: bool = False,
    ) -> Tuple[MeshPlan, ReplanReport]:
        """The failure path with decision hygiene: pick the new mesh,
        rebind the communicator's topology to it (``ranks_per_node``
        blocks the surviving ranks onto nodes; None keeps a single-node
        map), and demote every pin the reshape invalidated.  The next
        ``build_halo_program`` / ``plan_neighbor`` on ``comm`` re-prices
        from scratch on the new shape."""
        from repro.comm.topology import Topology

        mesh = self.on_failure(survivors, multi_pod)
        nranks = math.prod(mesh.shape)
        topo = (
            Topology.blocked(nranks, ranks_per_node)
            if ranks_per_node
            else Topology.flat(nranks)
        )
        return mesh, replan_on_remesh(comm, topo)
