"""Elastic scaling + straggler mitigation (scale deliverable).

At 1000+ nodes the failure model is: a pod/slice drops out (hardware,
preemption), or a host straggles (thermal throttling, ECC retries).  The
policies here are deliberately *mechanism-level* so they run on this
container and on a real cluster:

* **Elastic re-mesh** (`plan_remesh`): given surviving device count,
  pick the largest valid (data, model) mesh <= survivors that preserves
  the model-parallel degree (weights reshard cheaply along data/pod
  only), rescale the per-host batch, and return the new mesh spec.
  `repro.train.checkpoint.restore_checkpoint(shardings=...)` already
  re-shards the state onto the new mesh — together they implement
  checkpoint/restart elasticity.

* **Straggler mitigation** (`StragglerMonitor`): EWMA of per-step wall
  time; a step slower than `threshold` x EWMA flags a straggler event.
  The recommended action at scale is within-step: XLA's collective
  scheduling already overlaps; across steps the monitor recommends
  checkpoint-and-remesh when a host is persistently slow (the same
  elastic path as failures — slow node == failed node policy, standard
  at pod scale).

* **Failure detection** (`heartbeat_check`): in multi-controller JAX the
  runtime surfaces device loss as errors on collectives; the driver
  wraps steps in `try` and escalates to the elastic path.  Here the hook
  is a callable so tests can inject failures.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

__all__ = ["plan_remesh", "StragglerMonitor", "ElasticPolicy"]


@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    global_batch: int


def plan_remesh(
    survivors: int,
    model_parallel: int,
    global_batch: int,
    multi_pod: bool = False,
    pod_size: int = 256,
) -> MeshPlan:
    """Largest usable mesh after losing devices.

    Keeps the model axis fixed (weight shards survive in-place) and
    shrinks the data (and pod) axes; the global batch is scaled down
    proportionally in whole microbatch units so per-device batch stays
    constant (loss scale unchanged).
    """
    if survivors < model_parallel:
        raise RuntimeError(
            f"cannot keep model_parallel={model_parallel} with "
            f"{survivors} devices"
        )
    if multi_pod and survivors >= pod_size * 2:
        pods = survivors // pod_size
        data = pod_size // model_parallel
        frac = (pods * pod_size) / (2 * pod_size)
        return MeshPlan(
            (pods, data, model_parallel),
            ("pod", "data", "model"),
            max(int(global_batch * frac), 1),
        )
    data = survivors // model_parallel
    # data axis must divide the batch; round down to a power of two
    data = 2 ** int(math.log2(data)) if data > 0 else 1
    orig_data = survivors // model_parallel
    frac = data / max(orig_data, 1)
    return MeshPlan(
        (data, model_parallel),
        ("data", "model"),
        max(global_batch * data // max(orig_data, 1), 1),
    )


class StragglerMonitor:
    """EWMA step-time monitor with a slow-step escalation policy."""

    def __init__(self, alpha: float = 0.1, threshold: float = 1.5,
                 patience: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ewma: Optional[float] = None
        self.slow_streak = 0
        self.events: List[Tuple[int, float, float]] = []

    def observe(self, step: int, seconds: float) -> str:
        """Returns "ok" | "slow" | "remesh"."""
        if self.ewma is None:
            self.ewma = seconds
            return "ok"
        verdict = "ok"
        if seconds > self.threshold * self.ewma:
            self.slow_streak += 1
            self.events.append((step, seconds, self.ewma))
            verdict = "slow"
            if self.slow_streak >= self.patience:
                verdict = "remesh"
        else:
            self.slow_streak = 0
        # slow steps do not pollute the baseline
        if verdict == "ok":
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return verdict


@dataclass
class ElasticPolicy:
    """Driver-facing bundle: detect -> checkpoint -> remesh -> resume."""

    model_parallel: int
    global_batch: int
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)

    def on_failure(self, survivors: int, multi_pod: bool = False) -> MeshPlan:
        return plan_remesh(
            survivors, self.model_parallel, self.global_batch, multi_pod
        )
