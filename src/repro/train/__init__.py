"""repro.train — optimizer, train/serve steps, checkpointing, data."""

from repro.train.grad_wire import GRAD_WIRE_MODES, GradWire
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state
from repro.train.train_step import (
    make_grad_step,
    make_loss_fn,
    make_train_step,
)
