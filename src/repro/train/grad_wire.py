"""Model-priced gradient wire: the optimizer gradient exchange routed
through a :class:`~repro.comm.api.Communicator` as a committed datatype.

The training driver's gradients are a pytree the launcher jits end to
end; this module pulls the *exchange* half out of that jit and runs it
through the same wire stack every halo exchange uses.  The gradients
are flattened to one contiguous byte vector, committed once as a
``Vector(1, n, n, BYTE)`` :class:`~repro.core.commit.CommittedType`,
and planned with :meth:`Communicator.plan_neighbor` using a **probe**
of the concrete first-step gradient bytes — so a compressible payload
(e.g. a sparsely-updated embedding's zero-heavy gradient) can select
the lossless RLE wire and the ``varlen`` length-aware transport, priced
at the probed stream length, while a dense payload honestly stays on
the plain wire.  The decision rows this records (``wire/varlen`` with
``stream_bytes=``/``ratio=`` and the topology tag in the signature) are
pinned through the decisions file and drift-audited like any other.

The exchange pattern is a **there-and-back ring rotation** along the
communicator's axis: every rank ships its gradient bytes to the next
rank and receives them back on the return hop.  For lossless wire
formats the composition is the identity on the gradients (bit-exact),
while the bytes still traverse the planned — possibly compressed —
schedule twice, so the wire is load-bearing: a decode bug or a wrong
truncation length corrupts training, not just a counter.  On a 1-rank
axis (CI) both hops are self-permutes through the same code path.

Modes (:data:`GRAD_WIRE_MODES`):

``off``    no wire; the caller keeps the fused train step.
``auto``   model-priced selection with the gradient probe — the varlen
           RLE transport wins only when the probed ratio beats the
           plain wire end to end.
``rle``    force the lossless RLE wire (still probe-annotated, so the
           varlen schedule applies when the payload compresses).
``int8``   opt-in lossy quantized wire (never auto-picked): the DCN
           bandwidth trade, explicit because it changes numerics.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import BYTE, Vector
from repro.kernels.ops import byte_view, unbyte_view

__all__ = ["GRAD_WIRE_MODES", "GradWire"]

GRAD_WIRE_MODES: Tuple[str, ...] = ("off", "auto", "rle", "int8")

#: mode -> forced strategy name (None = model-priced selection)
_MODE_STRATEGY = {"auto": None, "rle": "rlewire", "int8": "int8wire"}


class GradWire:
    """Plan once from a concrete gradient sample, exchange every step.

    ``nranks`` is the ring size along the communicator's axis; the
    instance builds its own mesh over the first ``nranks`` visible
    devices (1 on CI — a self-permute ring, same code path).
    """

    def __init__(self, comm, mode: str = "auto", nranks: int = 1):
        if mode not in GRAD_WIRE_MODES:
            raise ValueError(
                f"unknown grad-wire mode {mode!r}; expected one of "
                f"{GRAD_WIRE_MODES}"
            )
        self.comm = comm
        self.mode = mode
        self.nranks = int(nranks)
        self._ct = None
        self._strats = None
        self._plan_fwd = None
        self._plan_back = None
        self._exchange_fn = None
        n = self.nranks
        self._fwd_perm = [[(i, (i + 1) % n) for i in range(n)]]
        self._back_perm = [[((i + 1) % n, i) for i in range(n)]]

    # -- planning --------------------------------------------------------
    @property
    def planned(self) -> bool:
        return self._plan_fwd is not None

    def plan_for(self, grads) -> None:
        """Host-side planning from a *concrete* gradient pytree (the
        first step's output): commit the flat byte type, probe the
        actual payload, and record/pin both hops' wire decisions."""
        if self.mode == "off":
            return
        leaves = jax.tree.leaves(grads)
        probe = np.concatenate(
            [np.asarray(jax.device_get(l)).reshape(-1).view(np.uint8)
             for l in leaves]
        )
        n = int(probe.size)
        self._ct = self.comm.commit(Vector(1, n, n, BYTE))
        name = _MODE_STRATEGY[self.mode]
        strategies = (
            None if name is None else [self.comm.strategies.get(name)]
        )
        # the int8 wire is lossy: never annotate it with a stream probe
        # (it has none), and never let "auto" reach it — only the
        # explicit mode opts in
        use_probe = jnp.asarray(probe) if self.mode != "int8" else None
        self._strats, self._plan_fwd = self.comm.plan_neighbor(
            [self._ct], self._fwd_perm,
            strategies=strategies, probe=use_probe,
        )
        _, self._plan_back = self.comm.plan_neighbor(
            [self._ct], self._back_perm,
            strategies=list(self._strats), probe=use_probe,
        )
        self._exchange_fn = None  # re-trace against the fresh plans

    # -- the per-step exchange ------------------------------------------
    def _roundtrip(self, flat):
        ct = self._ct
        out = self.comm.neighbor_alltoallv(
            flat, [ct], [ct], self._fwd_perm,
            plan=self._plan_fwd, strategies=self._strats,
        )
        return self.comm.neighbor_alltoallv(
            out, [ct], [ct], self._back_perm,
            plan=self._plan_back, strategies=self._strats,
        )

    def _build(self, grads):
        from jax.sharding import Mesh, PartitionSpec as P

        from repro.compat import shard_map

        axis = self.comm.axis_name or "data"
        devs = jax.devices()
        if self.nranks > len(devs):
            raise ValueError(
                f"grad wire ring needs {self.nranks} devices, "
                f"have {len(devs)}"
            )
        mesh = Mesh(np.array(devs[: self.nranks]), (axis,))
        leaves = jax.tree.leaves(grads)
        treedef = jax.tree.structure(grads)
        metas = [(l.dtype, l.shape, l.size * l.dtype.itemsize)
                 for l in leaves]

        def body(*flat_leaves):
            flat = jnp.concatenate([byte_view(l) for l in flat_leaves])
            out = self._roundtrip(flat)
            parts, off = [], 0
            for dtype, shape, nb in metas:
                part = lax.dynamic_slice(out, (off,), (nb,))
                parts.append(unbyte_view(part, dtype, shape))
                off += nb
            return tuple(parts)

        fn = jax.jit(
            shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)
        )

        def exchange(g):
            return jax.tree.unflatten(treedef, fn(*jax.tree.leaves(g)))

        return exchange

    def exchange(self, grads):
        """Round-trip the gradient bytes through the planned wire;
        lossless modes return the pytree bit-exact, ``int8`` returns the
        quantize/dequantize round trip (twice — once per hop)."""
        if self.mode == "off":
            return grads
        if not self.planned:
            self.plan_for(grads)
        if self._exchange_fn is None:
            self._exchange_fn = self._build(grads)
        return self._exchange_fn(grads)

    # -- reporting -------------------------------------------------------
    def describe(self) -> str:
        if not self.planned:
            return f"grad-wire mode={self.mode} (unplanned)"
        p = self._plan_fwd
        return (
            f"grad-wire mode={self.mode} strategy={self._strats[0].name} "
            f"schedule={p.schedule} wire_bytes={p.wire_bytes} "
            f"issued={p.issued_bytes} ratio={p.stream_ratio:.4f} "
            f"ring={self.nranks}"
        )
