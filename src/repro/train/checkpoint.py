"""Fault-tolerant checkpointing (scale deliverable).

Design (works at 1000+ nodes):

* **Shard-parallel writes** — each host writes only the param/optimizer
  shards it owns (``jax.experimental.multihost_utils`` handles the
  single-controller case transparently; on this container everything is
  one host).  Files are one ``.npz`` per pytree leaf-group plus a JSON
  manifest, so restore can re-shard to a *different* mesh (elastic
  restart after node loss).
* **Atomicity** — writes go to ``step_XXXX.tmp/`` then ``os.rename``;
  a crashed write never corrupts the latest checkpoint.
* **Retention** — ``keep`` newest checkpoints are retained; restore
  picks the newest *complete* manifest, so a torn checkpoint at crash
  time falls back to the previous one (checkpoint/restart fault model).
* **Async-friendly** — ``save`` takes host numpy copies first, so the
  device buffers are free immediately (overlaps the next step's compute
  with the filesystem write).
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional, Tuple

import numpy as np
import ml_dtypes

import jax

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}.{k}" if prefix else k, node[k])
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def _unflatten(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save_checkpoint(directory: str, step: int, state: Dict[str, Any],
                    keep: int = 3) -> str:
    """Atomically write ``state`` (arbitrary pytree of arrays) for
    ``step``.  Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(state)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype == ml_dtypes.bfloat16:
            # npz cannot store bfloat16; persist the bit pattern
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(os.path.join(tmp, "shards.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {
            k: {"shape": list(a.shape), "dtype": dtypes[k]}
            for k, a in arrays.items()
        },
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    done = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, _MANIFEST))
    )
    for d in done[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> Optional[int]:
    """Newest step with a COMPLETE manifest (torn writes are skipped)."""
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, _MANIFEST)):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    step: Optional[int] = None,
    shardings=None,
) -> Tuple[int, Dict[str, Any]]:
    """Restore newest (or ``step``) checkpoint.  If ``shardings`` (a
    matching pytree of jax.sharding.Sharding) is given, leaves are placed
    sharded — this is the elastic-restart path: the target mesh may
    differ from the mesh that wrote the checkpoint."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "shards.npz")) as z:
        flat = {}
        for k in z.files:
            a = z[k]
            if manifest["leaves"].get(k, {}).get("dtype") == "bfloat16":
                a = a.view(ml_dtypes.bfloat16)
            flat[k] = a
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings
        )
    return step, tree


class CheckpointManager:
    """save-every-N + restore-on-start convenience wrapper used by the
    train driver."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, state) -> Optional[str]:
        if step % self.every == 0 and step > 0:
            return save_checkpoint(self.directory, step, state, self.keep)
        return None

    def restore_or_init(self, init_fn, shardings=None):
        try:
            return restore_checkpoint(self.directory, shardings=shardings)
        except FileNotFoundError:
            return 0, init_fn()
