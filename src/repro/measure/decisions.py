"""Persistent strategy-selection cache + audit log.

The paper's selection is a pure function of (datatype, system
parameters), memoized per committed type (§6.3, 277 ns cached).  This
module makes those decisions *durable*: every selection the
:class:`~repro.comm.perfmodel.PerfModel` makes is recorded as a
:class:`Decision` keyed by the datatype's content fingerprint, can be
saved to JSON, reloaded in a fresh process, and handed back to a model
(``PerfModel(params, decisions=...)``) — which then *pins* the recorded
strategy instead of re-deriving it.  Pinning is what lets CI assert the
same choices on any runner, and what lets a production job skip the
model entirely after its first run.

``report()`` dumps the audit log: datatype signature -> chosen strategy
-> estimated terms, one line per decision.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Dict, List, Optional, Tuple, Union

from repro.comm.perfmodel import StrategyEstimate

__all__ = ["Decision", "DecisionCache"]

#: bump when Decision's schema changes incompatibly
DECISIONS_FORMAT = 1

Key = Tuple[str, int, int, bool]


@dataclass(frozen=True)
class Decision:
    """One audited strategy selection."""

    fingerprint: str        # CommittedType content hash
    incount: int
    hops: int
    allow_bounding: bool
    strategy: str           # the winner
    t_pack: float           # estimated terms at decision time (seconds)
    t_link: float
    t_unpack: float
    signature: str = ""     # human-readable datatype description
    wire_bytes: int = 0     # exact bytes the choice puts on the wire

    @property
    def total(self) -> float:
        return self.t_pack + self.t_link + self.t_unpack

    @property
    def key(self) -> Key:
        return (self.fingerprint, self.incount, self.hops, self.allow_bounding)


def _describe(ct) -> str:
    """Short human-readable signature for the audit log."""
    if ct is None:
        return ""
    b = ct.block
    if b is None:
        return f"{ct.kernel.value} size={ct.size} extent={ct.extent}"
    return (
        f"{ct.kernel.value} counts={list(b.counts)} strides={list(b.strides)}"
        f" size={ct.size}"
    )


def describe_type(ct) -> str:
    """Public audit-log type signature — callers that extend a decision
    signature (e.g. a probed compressed selection appending its stream
    bytes + ratio) build on this so the base text stays uniform."""
    return _describe(ct)


class DecisionCache:
    """Fingerprint-keyed decision store: lookup/record for the model,
    load/save for persistence, report() for the audit dump."""

    def __init__(self, decisions: Optional[List[Decision]] = None):
        self._by_key: Dict[Key, Decision] = {}
        self.log: List[Decision] = []      # insertion-ordered audit trail
        self._log_index: Dict[Key, int] = {}  # key -> position in the log
        self.pinned_hits = 0               # lookups served from the cache
        for d in decisions or ():
            self._insert(d)

    def _insert(self, d: Decision) -> None:
        # last-wins per key, stable order: re-recording an existing key
        # replaces its row in place instead of appending a duplicate —
        # otherwise every record -> save -> load -> record cycle would
        # compound duplicate rows in the persisted audit log
        k = d.key
        at = self._log_index.get(k)
        if at is None:
            self._log_index[k] = len(self.log)
            self.log.append(d)
        else:
            self.log[at] = d
        self._by_key[k] = d

    # -- model-facing ----------------------------------------------------
    def lookup(
        self, fingerprint: str, incount: int, hops: int, allow_bounding: bool
    ) -> Optional[Decision]:
        d = self._by_key.get((fingerprint, incount, hops, allow_bounding))
        if d is not None:
            self.pinned_hits += 1
        return d

    def record(
        self,
        fingerprint: str,
        incount: int,
        hops: int,
        allow_bounding: bool,
        estimate: StrategyEstimate,
        ct=None,
        signature: Optional[str] = None,
    ) -> Decision:
        d = Decision(
            fingerprint=fingerprint,
            incount=incount,
            hops=hops,
            allow_bounding=allow_bounding,
            strategy=estimate.strategy,
            t_pack=estimate.t_pack,
            t_link=estimate.t_link,
            t_unpack=estimate.t_unpack,
            signature=signature if signature is not None else _describe(ct),
            wire_bytes=getattr(estimate, "wire_bytes", 0),
        )
        self._insert(d)
        return d

    # -- persistence -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "format": DECISIONS_FORMAT,
                "decisions": [asdict(d) for d in self.log],
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "DecisionCache":
        d = json.loads(s)
        if d.get("format") != DECISIONS_FORMAT:
            # refusing loudly beats silently un-pinning every selection
            # (and letting the next save() overwrite the old audit log)
            raise ValueError(
                f"decision file format {d.get('format')!r} != "
                f"{DECISIONS_FORMAT}; re-record or migrate it"
            )
        return DecisionCache([Decision(**row) for row in d["decisions"]])

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(self.to_json())
        tmp.replace(p)  # atomic: concurrent readers never see a torn file
        return p

    @staticmethod
    def load(path: Union[str, Path]) -> "DecisionCache":
        """Load a saved cache; an absent file yields an empty cache (the
        first run of a job starts cold and records)."""
        p = Path(path)
        if not p.exists():
            return DecisionCache()
        return DecisionCache.from_json(p.read_text())

    # -- maintenance -----------------------------------------------------
    def prune(self, predicate) -> List[Decision]:
        """Remove every row for which ``predicate(decision)`` is true;
        returns the removed rows.  This is the demotion primitive: a pin
        whose premise no longer holds (drifted overlap mode, a topology
        that reshaped away) is *deleted* so the next planning pass
        re-prices and re-records instead of replaying it."""
        dropped, kept = [], []
        for d in self.log:
            (dropped if predicate(d) else kept).append(d)
        if dropped:
            self._by_key.clear()
            self._log_index.clear()
            self.log = []
            for d in kept:
                self._insert(d)
        return dropped

    # -- queries ---------------------------------------------------------
    def program_rows(self) -> List[Decision]:
        """The deep-halo fusion-depth decisions (``program/s=N`` rows,
        keyed by program fingerprint — one per distinct
        grid/interior/cycle geometry).  The launch drivers report these
        and the CI smoother step asserts one was recorded."""
        return [d for d in self.log if d.strategy.startswith("program/s=")]

    # -- audit -----------------------------------------------------------
    def report(self) -> str:
        """The audit log as aligned text: one selection per line."""
        lines = [
            f"{'fingerprint':16s}  {'n':>3s} {'hop':>3s} {'strategy':12s}"
            f" {'t_pack_us':>10s} {'t_link_us':>10s} {'t_unpack_us':>11s}"
            f" {'total_us':>10s} {'wire_B':>10s}  signature"
        ]
        for d in self.log:
            lines.append(
                f"{d.fingerprint:16s}  {d.incount:3d} {d.hops:3d}"
                f" {d.strategy:12s} {d.t_pack * 1e6:10.3f}"
                f" {d.t_link * 1e6:10.3f} {d.t_unpack * 1e6:11.3f}"
                f" {d.total * 1e6:10.3f} {d.wire_bytes:10d}  {d.signature}"
            )
        return "\n".join(lines)

    def __len__(self) -> int:
        return len(self._by_key)

    def __contains__(self, key: object) -> bool:
        return key in self._by_key
