"""CLI: run the full-term calibration and write a store envelope.

    PYTHONPATH=src python -m repro.measure [--reduced] [--name NAME] [out.json]

Without an output path the envelope lands in the default store
(``$REPRO_MEASURE_DIR`` or ``~/.cache/repro/measure``) under the running
system's fingerprint, where ``load_or_calibrate()`` finds it.
"""

from __future__ import annotations

import argparse

import jax

from repro.measure.bench import calibrate_params
from repro.measure.fingerprint import system_description, system_fingerprint
from repro.measure.store import ParamsStore


def main() -> None:
    ap = argparse.ArgumentParser(prog="repro.measure")
    ap.add_argument("out", nargs="?", default=None,
                    help="output JSON path (default: the params store)")
    ap.add_argument("--reduced", action="store_true",
                    help="small CI grid instead of the full sweep")
    ap.add_argument("--name", default=None, help="params table name")
    ap.add_argument("--mesh-axes", default=None,
                    help="per-axis wire sweep spec, e.g. ici=4,dcn=2 "
                         "(axis sizes must multiply to <= device count)")
    args = ap.parse_args()

    mesh_axes = None
    if args.mesh_axes:
        mesh_axes = {}
        for part in args.mesh_axes.split(","):
            k, v = part.split("=")
            mesh_axes[k.strip()] = int(v)

    params = calibrate_params(
        name=args.name, reduced=args.reduced, mesh_axes=mesh_axes
    )
    store = ParamsStore()
    path = store.save(params, path=args.out)
    strategies = sorted((params.pack_table or {}).keys())
    print(f"backend: {jax.default_backend()}  "
          f"system: {system_fingerprint()} {system_description()}")
    print(f"measured strategies: {strategies}")
    print(f"wire fit: latency={params.wire_latency} bw={params.wire_bw}")
    if params.wire_tables:
        for ax, fit in sorted((params.wire_fits or {}).items()):
            print(f"wire[{ax}]: latency={fit[0]} bw={fit[1]}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
