"""Stable content fingerprints for measurement & selection caches.

TEMPI's measured system parameters are recorded "to the file system"
once and reused across runs (paper §6.3) — so every key in the measured
database must survive the process that created it.  Two kinds of key:

* **datatype fingerprint** — :func:`type_fingerprint` hashes the
  *canonical* structure of a committed type (StridedBlock / IR tree +
  kernel kind + word width + size/extent, see
  ``CommittedType.structure_key``).  Re-committing the same description
  in a different registry — or a different process — yields the same
  fingerprint; ``id(ct)`` does not.  Fig.-2-equivalent constructions
  (different build, same canonical object) also share a fingerprint,
  which is exactly the paper's canonicalization argument.

* **system fingerprint** — :func:`system_fingerprint` hashes the
  backend/topology a calibration was taken on (platform, device kind,
  device count, jax version), so a params database never serves numbers
  measured on different hardware.
"""

from __future__ import annotations

import hashlib
from typing import Optional, Tuple

from repro.core.commit import CommittedType

__all__ = [
    "type_fingerprint",
    "system_fingerprint",
    "system_description",
    "FINGERPRINT_BYTES",
]

#: hex digits kept from the sha256 (64-bit keys: ample for cache keying,
#: short enough to read in audit reports and filenames)
FINGERPRINT_BYTES = 16


def type_fingerprint(ct: CommittedType) -> str:
    """Content hash of a committed type's canonical structure.

    Delegates to the core hook so the runtime and the measurement layer
    can never disagree about a type's identity.
    """
    return ct.fingerprint


def system_description(ndev: Optional[int] = None) -> Tuple[str, ...]:
    """Human-readable (platform, device_kind, device_count, jax_version)
    tuple describing the running system."""
    import jax

    devs = jax.devices()
    kind = devs[0].device_kind if devs else "none"
    return (
        jax.default_backend(),
        str(kind),
        str(ndev if ndev is not None else len(devs)),
        jax.__version__,
    )


def system_fingerprint(ndev: Optional[int] = None) -> str:
    """Stable hash of :func:`system_description` — the key a stored
    :class:`~repro.comm.perfmodel.SystemParams` lives under."""
    desc = "/".join(system_description(ndev))
    return hashlib.sha256(desc.encode()).hexdigest()[:FINGERPRINT_BYTES]
