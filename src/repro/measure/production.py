"""Production entry-point wiring: measured params + pinned decisions.

The §6.3 lifecycle for a long-running job in one call: the first run
calibrates (or loads a prior calibration for this system fingerprint)
and records every strategy selection it makes; the decisions file is
saved next to the params store, so every later run of the same job
**pins** those selections and never consults the model again.  The
``launch.train`` / ``launch.serve`` drivers construct their communicator
through this module.

    comm, save = production_communicator(axis_name="data")
    ... run the job; every datatype exchange goes through `comm` ...
    save()          # persist the (possibly grown) decision file

With ``telemetry=True`` the communicator also carries an
:class:`~repro.fleet.telemetry.ExchangeTelemetry` probe whose
aggregates persist to ``telemetry.json`` next to the decisions file on
``save()`` — the observation side of the fleet feedback loop
(``python -m repro.fleet report`` renders it; ``repro.fleet.drift``
audits it).
"""

from __future__ import annotations

from pathlib import Path
from typing import Callable, Optional, Tuple, Union

from repro.comm.api import Communicator
from repro.comm.perfmodel import SystemParams, TPU_V5E
from repro.measure.decisions import DecisionCache
from repro.measure.store import ParamsStore

__all__ = ["DECISIONS_FILENAME", "production_communicator"]

#: the decisions file lives next to the params envelopes in the store
DECISIONS_FILENAME = "decisions.json"


def production_communicator(
    cache_dir: Optional[Union[str, Path]] = None,
    axis_name: Optional[str] = None,
    *,
    calibrate: bool = True,
    reduced: Optional[bool] = None,
    params: Optional[SystemParams] = None,
    halo_steps: Optional[Union[int, str]] = None,
    telemetry: Union[bool, "object", None] = None,
    tracer: Union[bool, "object", None] = None,
    topology: Optional["object"] = None,
) -> Tuple[Communicator, Callable[[], Path]]:
    """A :class:`Communicator` wired for production reuse.

    Parameters
    ----------
    cache_dir: params-store root (default: ``$REPRO_MEASURE_DIR`` or the
        user cache dir — the same store ``load_or_calibrate`` uses).
    axis_name: mesh axis the communicator (and its per-axis wire
        pricing) binds to.
    calibrate: when True (default), a missing calibration for this
        system fingerprint is measured once and persisted
        (``load_or_calibrate``); when False, a missing calibration falls
        back to the analytic table — nothing slow happens.
    reduced: grid size for a fresh calibration; defaults to reduced
        everywhere but on a real TPU backend.
    params: explicit SystemParams override (skips the store entirely).
    halo_steps: when given (``"auto"`` or an int), installs the
        process-wide deep-halo fusion-depth default
        (:func:`repro.halo.program.set_default_halo_steps`) alongside
        the decisions cache that pins ``"auto"`` — so any
        :func:`~repro.halo.program.build_halo_program` the job runs
        resolves its depth through this seam and the choice lands in
        the same persisted decisions file.
    telemetry: ``True`` loads (or starts) the store's runtime telemetry
        (``telemetry.json``, persisted by ``save()`` alongside the
        decisions); an explicit
        :class:`~repro.fleet.telemetry.ExchangeTelemetry` instance is
        attached as-is (the caller owns persistence); ``None``/``False``
        attaches no probe.
    tracer: ``True`` attaches a fresh :class:`repro.obs.Tracer`
        (hierarchical exchange spans — export with
        :func:`repro.obs.export.save_chrome_trace`, the launch drivers'
        ``--trace PATH``); an explicit Tracer instance is attached
        as-is; ``None``/``False`` attaches none.
    topology: a :class:`repro.comm.topology.Topology` rank->node map
        (the launch drivers build one from ``--ranks-per-node``).  The
        model then prices per link class, may pick the tier-coalesced
        wire schedule, and stamps every wire/program decision with the
        topology fingerprint so pins never replay across a reshape.
        ``None`` plans flat (every hop priced equal).

    Returns ``(comm, save)``: call ``save()`` after the job to persist
    the decision cache — the file that lets the next run skip the model
    — plus the telemetry (when store-owned) and a ``metrics.json``
    snapshot of the communicator's counters
    (:func:`repro.obs.metrics.publish_comm_stats`; inspect with
    ``python -m repro.fleet stats``).
    """
    if halo_steps is not None:
        from repro.halo.program import set_default_halo_steps

        set_default_halo_steps(halo_steps)
    store = ParamsStore(cache_dir)
    if params is None:
        if calibrate:
            if reduced is None:
                import jax

                reduced = jax.default_backend() != "tpu"
            params = store.load_or_calibrate(reduced=reduced)
        else:
            params = store.load() or TPU_V5E
    decisions_path = store.root / DECISIONS_FILENAME
    decisions = DecisionCache.load(decisions_path)
    tel = None
    tel_path = None
    if telemetry is True:
        from repro.fleet.telemetry import TELEMETRY_FILENAME, ExchangeTelemetry

        tel_path = store.root / TELEMETRY_FILENAME
        tel = ExchangeTelemetry.load(tel_path)
    elif telemetry:  # an ExchangeTelemetry (or compatible) instance
        tel = telemetry
    tr = None
    if tracer is True:
        from repro.obs.trace import Tracer

        tr = Tracer()
    elif tracer:  # a Tracer (or compatible) instance
        tr = tracer
    comm = Communicator(
        axis_name=axis_name, params=params, decisions=decisions,
        telemetry=tel, tracer=tr, topology=topology,
    )

    def save() -> Path:
        if tel_path is not None:
            tel.save(tel_path)
        from repro.obs.metrics import METRICS_FILENAME, default_metrics

        comm.stats()  # publish the latest counters into the registry
        default_metrics().save(store.root / METRICS_FILENAME)
        return decisions.save(decisions_path)

    return comm, save
