"""Versioned on-disk SystemParams database (paper §6.3: measurements are
recorded once to the filesystem and reused by every later run).

Layout: one JSON file per system fingerprint under a root directory
(``$REPRO_MEASURE_DIR`` or ``~/.cache/repro/measure``).  Each file is an
envelope::

    {
      "format": 3,                       # store format version
      "system": "<system fingerprint>",  # backend/topology key
      "system_description": [...],       # human-readable provenance
      "params": { ... SystemParams ... }
    }

``load()`` refuses mismatched format versions and foreign system
fingerprints, so a database can never silently serve numbers measured
on different hardware or in an old schema.  ``load_or_calibrate()`` is
the one-call entry point: read the stored table for *this* system, or
run the calibration sweep once and persist it.

A reduced-grid calibration taken on the CI runner is checked in as
``ci_params.json`` next to this module; loading it (``load_ci_params``)
pins strategy-selection decisions deterministically in CI regardless of
the runner's actual speed that day.
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Optional, Union

from repro.comm.perfmodel import SystemParams
from repro.measure.bench import calibrate_params
from repro.measure.fingerprint import system_description, system_fingerprint

__all__ = [
    "STORE_FORMAT",
    "COMPATIBLE_FORMATS",
    "ParamsStore",
    "default_store",
    "load_or_calibrate",
    "ci_params_path",
    "load_ci_params",
]

#: bump when the envelope or SystemParams schema changes incompatibly
STORE_FORMAT = 6

#: formats this reader still understands: format 2 predates the
#: per-axis wire tables (``wire_tables`` / ``wire_fits``), format 3 the
#: stencil-application sweep (``stencil_table``), format 4 the
#: per-link-class sweeps (``link_tables`` / ``link_fits``), format 5
#: the compress/decompress sweep (``compress_table``, rows
#: ``(log2_total, compress_sec, decompress_sec, ratio_sample)`` per
#: wire compressor) — all optional fields, so older envelopes load
#: unchanged with those fields absent (the model then falls back: copy
#: proxy for the redundant-compute term, the flat wire table priced as
#: ``intra`` for every link class, and an analytic read+write sweep for
#: the compress term).  The checked-in ``ci_params.json`` stays valid
#: at any compatible format; format-2/3/4/5 loading is covered by
#: synthetic envelopes in ``tests/test_measure.py`` /
#: ``tests/test_hierarchy.py``
COMPATIBLE_FORMATS = (2, 3, 4, 5, STORE_FORMAT)

_ENV_ROOT = "REPRO_MEASURE_DIR"


class ParamsStore:
    """A directory of system-fingerprint-keyed SystemParams envelopes."""

    def __init__(self, root: Optional[Union[str, Path]] = None):
        if root is None:
            root = os.environ.get(_ENV_ROOT) or (
                Path.home() / ".cache" / "repro" / "measure"
            )
        self.root = Path(root)

    def path_for(self, system: Optional[str] = None) -> Path:
        return self.root / f"{system or system_fingerprint()}.json"

    # -- write ----------------------------------------------------------
    def save(
        self,
        params: SystemParams,
        system: Optional[str] = None,
        path: Optional[Union[str, Path]] = None,
    ) -> Path:
        system = system or system_fingerprint()
        envelope = {
            "format": STORE_FORMAT,
            "system": system,
            "system_description": list(system_description()),
            "params": json.loads(params.to_json()),
        }
        out = Path(path) if path is not None else self.path_for(system)
        out.parent.mkdir(parents=True, exist_ok=True)
        tmp = out.with_suffix(".tmp")
        tmp.write_text(json.dumps(envelope, indent=2))
        tmp.replace(out)  # atomic: concurrent readers never see a torn file
        return out

    # -- read -----------------------------------------------------------
    @staticmethod
    def _parse(path: Union[str, Path]):
        """One envelope file -> (SystemParams, system fingerprint) or
        (None, None) when missing/foreign-format.  Bare SystemParams
        JSON (the ``repro.comm.calibrate`` output) is accepted too, for
        hand-written files (its system field is None)."""
        p = Path(path)
        if not p.exists():
            return None, None
        d = json.loads(p.read_text())
        system = None
        if "params" in d:
            if d.get("format") not in COMPATIBLE_FORMATS:
                return None, None
            system = d.get("system")
            d = d["params"]
        if "name" not in d:
            return None, None
        return SystemParams.from_json(json.dumps(d)), system

    @staticmethod
    def read_envelope(path: Union[str, Path]) -> Optional[SystemParams]:
        """Parse one envelope file regardless of which system recorded
        it; None when missing or foreign-format."""
        return ParamsStore._parse(path)[0]

    def load(self, system: Optional[str] = None) -> Optional[SystemParams]:
        """Stored params for ``system`` (default: the running system),
        or None when absent, incompatibly versioned, or recorded for a
        different system fingerprint."""
        system = system or system_fingerprint()
        params, recorded = self._parse(self.path_for(system))
        if params is None or recorded != system:
            return None
        return params

    def load_or_calibrate(
        self,
        name: Optional[str] = None,
        reduced: bool = False,
        force: bool = False,
    ) -> SystemParams:
        """The §6.3 lifecycle in one call: reuse the stored measurement
        for this system fingerprint, or calibrate once and persist."""
        if not force:
            got = self.load()
            if got is not None:
                return got
        params = calibrate_params(name=name, reduced=reduced)
        self.save(params)
        return params


def default_store() -> ParamsStore:
    """Store rooted at ``$REPRO_MEASURE_DIR`` (or the user cache dir)."""
    return ParamsStore()


def load_or_calibrate(
    name: Optional[str] = None, reduced: bool = False, force: bool = False
) -> SystemParams:
    """Module-level shorthand over :meth:`ParamsStore.load_or_calibrate`."""
    return default_store().load_or_calibrate(name, reduced, force)


def ci_params_path() -> Path:
    """The checked-in reduced-grid CPU calibration used to pin CI
    selection decisions."""
    return Path(__file__).parent / "ci_params.json"


def load_ci_params() -> SystemParams:
    params = ParamsStore.read_envelope(ci_params_path())
    if params is None:
        raise FileNotFoundError(
            f"checked-in CI params missing or unreadable: {ci_params_path()}"
        )
    return params
