"""Timed-sweep measurement harness (paper §6.3: "TEMPI provides a binary
that records system performance parameters to the file system.  This
binary should be run once before TEMPI is used in an application.").

The paper's model needs *every* term of T = T_pack + T_link + T_unpack
from empirical measurement, not analytic constants — strategy rankings
flip with block size and total size per system.  This module measures
all of them on the *running* backend:

* :func:`measure_pack_table` / :func:`measure_unpack_table` — per
  registered strategy, over a sparse (contiguous-block-size x
  total-object-size) grid, interpolated at query time;
* :func:`measure_wire_table` — one-hop collective (``ppermute`` ring
  over however many devices exist; 1-device self-permutes still price
  the dispatch overhead) over message sizes, with a least-squares
  (latency, bandwidth) fit;
* :func:`measure_wire_tables` — the same sweep run **per mesh axis**: a
  multi-axis mesh (fast ICI axis x slow DCN axis) has genuinely
  different link terms per axis, so each axis gets its own ring, table,
  and fit, and ``PerfModel.t_link(axis=...)`` prices the axis it is
  actually crossing;
* :func:`measure_copy_table` — contiguous device copy over sizes (the
  memcpy analogue every strategy's staging bottoms out in);
* :func:`measure_compress_table` — per wire compressor, the
  encode/decode transform cost over sizes plus an achieved-ratio
  sample (STORE_FORMAT 6) — what
  :meth:`~repro.comm.perfmodel.PerfModel.measured_compress`
  interpolates to price a compressed schedule's pack-side cost against
  its wire-byte savings;
* :func:`measure_stencil_table` — one stencil application
  (:func:`repro.kernels.ops.stencil_window_update`) over (neighbor
  count x window bytes): the redundant ghost-shell term of
  :meth:`~repro.comm.perfmodel.PerfModel.price_program` priced from a
  real sweep instead of the contiguous-copy proxy.

:func:`calibrate_params` assembles everything into a
:class:`~repro.comm.perfmodel.SystemParams`.  On a real TPU the
measurements are wall-clock; on CPU containers they still provide a
useful relative ordering.  ``reduced=True`` shrinks the grid for CI.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BYTE, TypeRegistry, Vector
from repro.kernels import ops
from repro.comm.perfmodel import SystemParams, TPU_V5E

__all__ = [
    "BLOCK_BYTES",
    "TOTAL_BYTES",
    "REDUCED_BLOCK_BYTES",
    "REDUCED_TOTAL_BYTES",
    "PITCH",
    "time_fn",
    "measure_pack_table",
    "measure_unpack_table",
    "measure_wire_table",
    "measure_wire_tables",
    "measure_link_class_tables",
    "measure_copy_table",
    "measure_compress_table",
    "measure_stencil_table",
    "STENCIL_RADII",
    "REDUCED_STENCIL_RADII",
    "fit_latency_bandwidth",
    "calibrate_params",
]

# paper Fig. 10 sweeps 64 B - 4 MiB objects over block sizes; we use a
# coarser grid (interpolated at query time)
BLOCK_BYTES: Tuple[int, ...] = (8, 32, 128, 512)
TOTAL_BYTES: Tuple[int, ...] = (1 << 10, 1 << 14, 1 << 18, 1 << 22)
#: CI / smoke grid — small enough for interpret-mode kernels on CPU
REDUCED_BLOCK_BYTES: Tuple[int, ...] = (8, 128)
REDUCED_TOTAL_BYTES: Tuple[int, ...] = (1 << 10, 1 << 14)
PITCH = 512  # paper Fig. 7 uses 512 B pitch

#: stencil-sweep op shapes: per-dimension radii -> neighbor counts 26,
#: 44, and 124 — spanning the paper's 26-point op up to deep boxes
STENCIL_RADII: Tuple[Tuple[int, int, int], ...] = (
    (1, 1, 1), (2, 1, 1), (2, 2, 2),
)
REDUCED_STENCIL_RADII: Tuple[Tuple[int, int, int], ...] = ((1, 1, 1), (2, 1, 1))


def time_fn(fn, *args, iters: int = 5) -> float:
    """Mean wall-clock seconds per call of an async-dispatch ``fn``.

    The warm-up call (compile + caches) MUST be block_until_ready'd
    before ``t0`` is taken: JAX dispatch is asynchronous, so an
    unsynchronized warm-up would still be executing inside the timed
    region and bleed into every sample.
    """
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _resolve_strategies(strategies):
    from repro.comm.api import default_registry, resolve_strategy

    if strategies is None:
        return default_registry().measurable()
    return tuple(resolve_strategy(s) for s in strategies)


def _sweep(
    block_bytes: Sequence[int], total_bytes: Sequence[int]
) -> Iterable[Tuple[int, int, object, jax.Array]]:
    """Yield (blk, nblocks, committed vector type, source buffer) over
    the measurement grid — the same shapes for pack and unpack so their
    tables are directly comparable."""
    reg = TypeRegistry()
    for blk in block_bytes:
        pitch = max(PITCH, 2 * blk)
        for total in total_bytes:
            nblocks = max(total // blk, 1)
            ct = reg.commit(Vector(nblocks, blk, pitch, BYTE))
            buf = jnp.zeros((ct.extent + 64,), jnp.uint8)
            yield blk, nblocks, ct, buf


def _measure_table(
    make_timed, strategies, block_bytes, total_bytes, iters
) -> Dict[str, List[Tuple[float, float, float]]]:
    """Shared sweep scaffolding for the 2D kernel tables: ``make_timed``
    maps (strategy, ct, buf) -> (jitted fn, args).  One implementation
    so cap handling / grid shape / row format can never drift between
    the pack and unpack tables."""
    strats = _resolve_strategies(strategies)
    table: Dict[str, List[Tuple[float, float, float]]] = {
        s.name: [] for s in strats
    }
    for blk, nblocks, ct, buf in _sweep(block_bytes, total_bytes):
        for s in strats:
            cap = s.calibration_cap
            if cap is not None and nblocks > cap:
                continue  # per-block unrolled HLO blows up past the cap
            jfn, args = make_timed(s, ct, buf)
            sec = time_fn(jfn, *args, iters=iters)
            table[s.name].append(
                (math.log2(blk), math.log2(nblocks * blk), sec)
            )
    return table


def measure_pack_table(
    strategies=None,
    block_bytes: Sequence[int] = BLOCK_BYTES,
    total_bytes: Sequence[int] = TOTAL_BYTES,
    iters: int = 5,
) -> Dict[str, List[Tuple[float, float, float]]]:
    """Measure pack time for every calibratable registered strategy (or
    an explicit iterable of strategies/names) over the grid."""

    def timed(s, ct, buf):
        return jax.jit(
            lambda b, _ct=ct, _s=s: ops.pack(b, _ct, strategy=_s)
        ), (buf,)

    return _measure_table(timed, strategies, block_bytes, total_bytes, iters)


def measure_unpack_table(
    strategies=None,
    block_bytes: Sequence[int] = BLOCK_BYTES,
    total_bytes: Sequence[int] = TOTAL_BYTES,
    iters: int = 5,
) -> Dict[str, List[Tuple[float, float, float]]]:
    """Measure unpack (packed bytes -> strided destination) over the same
    grid as :func:`measure_pack_table` — the paper observes pack/unpack
    asymmetry, so the model must not derive one from the other."""

    def timed(s, ct, buf):
        packed = jnp.zeros((ct.size,), jnp.uint8)
        return jax.jit(
            lambda b, p, _ct=ct, _s=s: ops.unpack(b, p, _ct, strategy=_s)
        ), (buf, packed)

    return _measure_table(timed, strategies, block_bytes, total_bytes, iters)


def measure_copy_table(
    total_bytes: Sequence[int] = TOTAL_BYTES, iters: int = 5
) -> List[Tuple[float, float]]:
    """Contiguous device copy time over sizes (read + write of ``n``
    bytes — the staging floor every pack strategy competes with)."""
    rows = []
    for total in total_bytes:
        x = jnp.zeros((total,), jnp.uint8)
        jfn = jax.jit(lambda a: a + jnp.uint8(1))  # forced read+write
        rows.append((math.log2(total), time_fn(jfn, x, iters=iters)))
    return rows


def measure_compress_table(
    strategies=None,
    total_bytes: Sequence[int] = TOTAL_BYTES,
    iters: int = 5,
) -> Dict[str, List[Tuple[float, float, float, float]]]:
    """Compress / decompress throughput sweep per wire compressor
    (STORE_FORMAT 6): rows ``(log2_total, compress_sec, decompress_sec,
    achieved_ratio_sample)``.

    Times each compressor's ``encode_wire`` (packed member bytes ->
    wire) and ``decode_wire`` (wire -> member bytes) transforms in
    isolation — the *extra* cost a compressed wire adds on top of the
    base pack/unpack, which is exactly the term
    :meth:`~repro.comm.perfmodel.PerfModel.measured_compress`
    interpolates for ``model_pack`` / ``model_unpack``.  The sweep
    payload is zero-heavy (one nonzero byte per 256) so the RLE
    encoder's run machinery is exercised on its intended regime.

    The fourth column is an *informational* achieved-ratio sample for
    that payload: bytes the format would actually move (the probed
    stream length for varlen-capable formats, the capacity wire
    otherwise) per member byte.  Per-payload ratios always come from a
    live calibration probe of the actual payload
    (:meth:`~repro.comm.api.Strategy.probe_stream_bytes`), never from
    this table — the column only documents what the sweep saw.

    Default strategies: the registered wire compressors
    (``RLE_WIRE``, ``INT8_WIRE``).
    """
    from repro.comm.compress import INT8_WIRE, RLE_WIRE

    strats = (
        (RLE_WIRE, INT8_WIRE)
        if strategies is None
        else tuple(strategies)
    )
    reg = TypeRegistry()
    table: Dict[str, List[Tuple[float, float, float, float]]] = {}
    for s in strats:
        rows: List[Tuple[float, float, float, float]] = []
        for total in total_bytes:
            n = max(total - total % 4, 4)  # int8 views member bytes as f32
            member = np.zeros((n,), np.uint8)
            member[::256] = 1  # zero-heavy: short runs every 256 B
            buf = jnp.asarray(member)
            enc = jax.jit(s.encode_wire)
            wire = jax.block_until_ready(enc(buf))
            csec = time_fn(enc, buf, iters=iters)
            dec = jax.jit(lambda w, _n=n, _s=s: _s.decode_wire(w, _n))
            dsec = time_fn(dec, wire, iters=iters)
            ct = reg.commit(Vector(1, n, n, BYTE))  # contiguous: pack = id
            moved = min(s.probe_stream_bytes(ct, 1, buf), wire.shape[0])
            rows.append(
                (math.log2(n), csec, dsec, moved / float(n))
            )
        table[s.name] = rows
    return table


def measure_stencil_table(
    radii_set: Sequence[Tuple[int, int, int]] = STENCIL_RADII,
    total_bytes: Sequence[int] = TOTAL_BYTES,
    iters: int = 5,
) -> List[Tuple[float, float, float]]:
    """One weighted box-stencil application over (neighbor count x
    window bytes): rows ``(log2_neighbors, log2_window_bytes, sec)``.

    Times :func:`repro.kernels.ops.stencil_window_update` — the exact
    primitive every deep-halo application runs — on a float32 cube whose
    window holds ~``total`` bytes, for each op shape in ``radii_set``.
    ``PerfModel.price_program`` interpolates this table to price the
    redundant ghost-shell compute a fused program buys, instead of
    approximating a sweep with ``n_neighbors + 2`` contiguous-copy
    touches.
    """
    import itertools as _it

    from repro.kernels.ops import stencil_window_update

    rows: List[Tuple[float, float, float]] = []
    for radii in radii_set:
        rz, ry, rx = radii
        offsets = tuple(
            d
            for d in _it.product(
                range(-rz, rz + 1), range(-ry, ry + 1), range(-rx, rx + 1)
            )
            if d != (0, 0, 0)
        )
        for total in total_bytes:
            m = max(int(round((total / 4) ** (1.0 / 3.0))), 1)
            shape = (m, m, m)
            arr = jnp.zeros(
                tuple(s + 2 * r for s, r in zip(shape, radii)), jnp.float32
            )
            jfn = jax.jit(
                lambda a, _o=offsets, _r=radii, _s=shape: stencil_window_update(
                    a, _o, 0.4, _r, _s
                )
            )
            sec = time_fn(jfn, arr, iters=iters)
            rows.append(
                (math.log2(len(offsets)), math.log2(4 * m ** 3), sec)
            )
    return rows


def measure_wire_table(
    total_bytes: Sequence[int] = TOTAL_BYTES,
    iters: int = 5,
    axis_name: str = "wire",
) -> List[Tuple[float, float]]:
    """One-hop collective time over message sizes: a ``ppermute`` ring
    across every visible device (a 1-device mesh self-permutes, which
    still prices collective dispatch).  Rows are (log2_bytes, sec)."""
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    devs = jax.devices()
    n = len(devs)
    mesh = Mesh(np.array(devs), (axis_name,))
    perm = [(i, (i + 1) % n) for i in range(n)]
    rows = []
    for total in total_bytes:
        def body(x):
            return jax.lax.ppermute(x, axis_name, perm)

        fn = jax.jit(
            shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                      check_vma=False)
        )
        x = jnp.zeros((total,), jnp.uint8)
        rows.append((math.log2(total), time_fn(fn, x, iters=iters)))
    return rows


def measure_wire_tables(
    axes: Optional[Dict[str, int]] = None,
    total_bytes: Sequence[int] = TOTAL_BYTES,
    iters: int = 5,
) -> Dict[str, List[Tuple[float, float]]]:
    """One-hop collective sweep per mesh axis.

    ``axes`` maps axis name -> size (ordered; the product must not
    exceed the visible device count — the first ``prod(sizes)`` devices
    are folded into the mesh).  Each axis is measured with a ``ppermute``
    ring *along that axis only*, inside a shard_map over the full mesh,
    so the timing reflects that axis's links.  Default: one flat
    ``wire`` axis over every device (the legacy single-table sweep).
    """
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    devs = jax.devices()
    if axes is None:
        axes = {"wire": len(devs)}
    names = tuple(axes)
    shape = tuple(axes[n] for n in names)
    ndev = int(np.prod(shape))
    if ndev > len(devs):
        raise ValueError(
            f"mesh {dict(axes)} needs {ndev} devices, have {len(devs)}"
        )
    mesh = Mesh(np.array(devs[:ndev]).reshape(shape), names)
    tables: Dict[str, List[Tuple[float, float]]] = {}
    for ai, name in enumerate(names):
        n = shape[ai]
        perm = [(i, (i + 1) % n) for i in range(n)]
        rows = []
        for total in total_bytes:
            def body(x, _name=name, _perm=perm):
                return jax.lax.ppermute(x, _name, _perm)

            fn = jax.jit(
                shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False)
            )
            x = jnp.zeros((total,), jnp.uint8)
            rows.append((math.log2(total), time_fn(fn, x, iters=iters)))
        tables[name] = rows
    return tables


def measure_link_class_tables(
    topology,
    total_bytes: Sequence[int] = TOTAL_BYTES,
    iters: int = 5,
    axis_name: str = "wire",
) -> Dict[str, List[Tuple[float, float]]]:
    """Per-LINK-CLASS one-hop collective sweep (STORE_FORMAT 5).

    ``topology`` is a :class:`repro.comm.topology.Topology` whose rank
    count must not exceed the visible device count; rank ``r`` runs on
    device ``r``.  Two permutations isolate the two tiers of the
    hierarchy:

    * ``intra`` — a ring within each node's rank block (every edge
      stays on one node, so the timing is pure fast-tier);
    * ``inter`` — rank ``j`` of node ``i`` sends to rank ``j`` of node
      ``i + 1`` (mod nodes): every edge crosses nodes, and the
      bulk-synchronous collective completes at the slow tier.

    Rows are (log2_bytes, sec) per class; a single-node topology yields
    ``intra`` only.  On a single-host container both permutations ride
    the same physical links — the sweep is then a smoke-path (the two
    tables come out nearly equal), while a real multi-node mesh prices
    its DCN tier honestly.
    """
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.compat import shard_map

    devs = jax.devices()
    n = topology.nranks
    if n > len(devs):
        raise ValueError(
            f"topology has {n} ranks, only {len(devs)} devices visible"
        )
    nodes = topology.nodes
    by_node: Dict[int, List[int]] = {}
    for r, nd in enumerate(nodes):
        by_node.setdefault(nd, []).append(r)

    # intra: ring within each node block (self-permute for 1-rank nodes)
    intra_perm: List[Tuple[int, int]] = []
    for members in by_node.values():
        k = len(members)
        intra_perm.extend(
            (members[i], members[(i + 1) % k]) for i in range(k)
        )
    perms = {"intra": intra_perm}
    node_ids = sorted(by_node)
    if len(node_ids) > 1:
        # inter: j-th rank of node i -> j-th rank of node i+1; ragged
        # node sizes wrap j modulo the destination block
        inter_perm: List[Tuple[int, int]] = []
        for i, nd in enumerate(node_ids):
            nxt = by_node[node_ids[(i + 1) % len(node_ids)]]
            for j, r in enumerate(by_node[nd]):
                inter_perm.append((r, nxt[j % len(nxt)]))
        if sorted(d for _, d in inter_perm) == list(range(n)):
            perms["inter"] = inter_perm

    mesh = Mesh(np.array(devs[:n]), (axis_name,))
    tables: Dict[str, List[Tuple[float, float]]] = {}
    for cls, perm in perms.items():
        rows = []
        for total in total_bytes:
            def body(x, _perm=tuple(perm)):
                return jax.lax.ppermute(x, axis_name, list(_perm))

            fn = jax.jit(
                shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False)
            )
            x = jnp.zeros((total,), jnp.uint8)
            rows.append((math.log2(total), time_fn(fn, x, iters=iters)))
        tables[cls] = rows
    return tables


def fit_latency_bandwidth(
    rows: Sequence[Tuple[float, float]]
) -> Tuple[Optional[float], Optional[float]]:
    """Least-squares fit of t(n) = latency + n / bandwidth over
    (log2_bytes, sec) rows.  Either term is None when the sweep is too
    small or noisy to resolve it (a non-positive intercept or slope) —
    consumers treat None as "no fit" and fall back to analytic
    constants; a clamped 0.0 would instead price extra hops as free."""
    if len(rows) < 2:
        return None, None
    nbytes = np.asarray([2.0 ** r[0] for r in rows])
    secs = np.asarray([r[1] for r in rows])
    design = np.stack([np.ones_like(nbytes), nbytes], axis=1)
    (lat, inv_bw), *_ = np.linalg.lstsq(design, secs, rcond=None)
    return (
        float(lat) if lat > 0 else None,
        float(1.0 / inv_bw) if inv_bw > 0 else None,
    )


def calibrate_params(
    name: Optional[str] = None,
    reduced: bool = False,
    strategies=None,
    iters: Optional[int] = None,
    mesh_axes: Optional[Dict[str, int]] = None,
    topology=None,
) -> SystemParams:
    """Full-term calibration: pack + unpack + wire + contiguous copy +
    compress/decompress + stencil application.

    ``mesh_axes`` (axis name -> size, e.g. ``{"ici": 4, "dcn": 2}``)
    sweeps the wire term once per mesh axis and stores one table + fit
    per axis (``wire_tables`` / ``wire_fits``) so ``t_link`` can price
    multi-axis meshes honestly; the flat full-device ring remains the
    axis-agnostic ``wire_table`` fallback either way.

    ``topology`` (a :class:`repro.comm.topology.Topology`) additionally
    runs the per-link-class sweep (:func:`measure_link_class_tables`)
    and stores its tables + fits (``link_tables`` / ``link_fits``,
    STORE_FORMAT 5) so tier-aware pricing — and the simulated-scale mode
    built on it — reads measured numbers for both tiers.

    Returns a :class:`SystemParams` whose measured tables drive every
    term of the model's T = T_pack + T_link + T_unpack; the analytic
    constants remain as fallbacks for uncovered strategies.
    """
    blocks = REDUCED_BLOCK_BYTES if reduced else BLOCK_BYTES
    totals = REDUCED_TOTAL_BYTES if reduced else TOTAL_BYTES
    radii_set = REDUCED_STENCIL_RADII if reduced else STENCIL_RADII
    it = iters if iters is not None else (2 if reduced else 5)

    pack = measure_pack_table(strategies, blocks, totals, iters=it)
    unpack = measure_unpack_table(strategies, blocks, totals, iters=it)
    copy = measure_copy_table(totals, iters=it)
    compress = measure_compress_table(total_bytes=totals, iters=it)
    stencil = measure_stencil_table(radii_set, totals, iters=it)
    wire = measure_wire_table(totals, iters=it)
    wire_lat, wire_bw = fit_latency_bandwidth(wire)
    wire_tables = wire_fits = None
    if mesh_axes is not None:
        wire_tables = measure_wire_tables(mesh_axes, totals, iters=it)
        wire_fits = {
            ax: fit_latency_bandwidth(rows) for ax, rows in wire_tables.items()
        }
    link_tables = link_fits = None
    if topology is not None:
        link_tables = measure_link_class_tables(topology, totals, iters=it)
        link_fits = {
            cls: fit_latency_bandwidth(rows)
            for cls, rows in link_tables.items()
        }

    backend = jax.default_backend()
    base = TPU_V5E if backend == "tpu" else dataclasses.replace(
        TPU_V5E, name=f"{backend}_measured"
    )
    # the largest contiguous copy moves 2*total bytes (read + write):
    # use it as the measured memory-bandwidth fallback term
    hbm_bw = base.hbm_bw
    if copy and copy[-1][1] > 0:
        hbm_bw = 2.0 * (2.0 ** copy[-1][0]) / copy[-1][1]
    return dataclasses.replace(
        base,
        name=name or f"{backend}_calibrated",
        hbm_bw=hbm_bw,
        pack_table={k: tuple(v) for k, v in pack.items() if v},
        unpack_table={k: tuple(v) for k, v in unpack.items() if v},
        compress_table={k: tuple(v) for k, v in compress.items() if v},
        wire_table=tuple(wire),
        copy_table=tuple(copy),
        stencil_table=tuple(stencil),
        wire_tables=(
            {k: tuple(v) for k, v in wire_tables.items()} if wire_tables else None
        ),
        wire_fits=wire_fits,
        link_tables=(
            {k: tuple(v) for k, v in link_tables.items()} if link_tables else None
        ),
        link_fits=link_fits,
        wire_latency=wire_lat,
        wire_bw=wire_bw,
        ici_bw=wire_bw if wire_bw else base.ici_bw,
        ici_latency=wire_lat if wire_lat else base.ici_latency,
    )
