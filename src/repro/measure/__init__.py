"""repro.measure — empirical measurement & autotuning (paper §5/§6.3).

TEMPI's claim is that non-contiguous transfer performance "can be
modeled with empirical system measurements" recorded once to the
filesystem and used to transparently pick the cheapest strategy.  This
package owns that data end-to-end:

* :mod:`repro.measure.bench`       — timed sweeps for pack, unpack,
  wire, and contiguous-copy terms (``calibrate_params``);
* :mod:`repro.measure.fingerprint` — content hashes for committed
  datatypes and for the backend/topology, the keys everything below
  persists under;
* :mod:`repro.measure.store`       — the versioned on-disk SystemParams
  database (``load_or_calibrate``) plus the checked-in ``ci_params.json``
  that pins CI decisions;
* :mod:`repro.measure.decisions`   — the persistent selection cache and
  audit log a :class:`~repro.comm.perfmodel.PerfModel` records into and
  pins from.

Lifecycle:  calibrate once -> store -> load in any process -> select
(fingerprint-keyed, reproducible) -> audit.  See ``docs/measure.md``.
"""

from repro.measure.bench import (
    calibrate_params,
    fit_latency_bandwidth,
    measure_copy_table,
    measure_link_class_tables,
    measure_pack_table,
    measure_stencil_table,
    measure_unpack_table,
    measure_wire_table,
    measure_wire_tables,
    time_fn,
)
from repro.measure.decisions import Decision, DecisionCache
from repro.measure.fingerprint import (
    system_description,
    system_fingerprint,
    type_fingerprint,
)
from repro.measure.production import (
    DECISIONS_FILENAME,
    production_communicator,
)
from repro.measure.store import (
    COMPATIBLE_FORMATS,
    ParamsStore,
    STORE_FORMAT,
    ci_params_path,
    default_store,
    load_ci_params,
    load_or_calibrate,
)

__all__ = [
    "COMPATIBLE_FORMATS",
    "DECISIONS_FILENAME",
    "Decision",
    "DecisionCache",
    "ParamsStore",
    "STORE_FORMAT",
    "calibrate_params",
    "ci_params_path",
    "default_store",
    "fit_latency_bandwidth",
    "load_ci_params",
    "load_or_calibrate",
    "measure_copy_table",
    "measure_link_class_tables",
    "measure_pack_table",
    "measure_stencil_table",
    "measure_unpack_table",
    "measure_wire_table",
    "measure_wire_tables",
    "production_communicator",
    "system_description",
    "system_fingerprint",
    "time_fn",
    "type_fingerprint",
]
