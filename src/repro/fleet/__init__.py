"""Fleet layer: the engine's runtime feedback loop.

PRs 1-5 built a *predictive* pipeline — canonical types are priced on
once-measured tables and the winning strategies are pinned.  This
package closes the loop for production fleets:

* :mod:`repro.fleet.telemetry` — per-exchange observed wall time,
  aggregated per decision key (observed vs predicted, always one
  division away);
* :mod:`repro.fleet.drift` — flag stale decisions, attribute the drift
  to a model term, re-measure *only* that term's table;
* :mod:`repro.fleet.bundle` — generation-numbered decision envelopes
  with deterministic merge, diff, promote and rollback.

``python -m repro.fleet {report,diff,merge,promote}`` is the operator
surface; ``docs/measure.md`` ("fleet lifecycle") walks the whole
telemetry -> drift -> re-measure -> promote cycle.
"""

from repro.fleet.bundle import (
    BUNDLE_FORMAT,
    CONFLICT_POLICIES,
    DecisionBundle,
    diff_bundles,
    load_bundle,
    merge_bundles,
    promote,
    rollback,
)
from repro.fleet.drift import (
    DEFAULT_COMPRESS_MARGIN,
    DEFAULT_MIN_SAMPLES,
    DEFAULT_OVERLAP_MARGIN,
    DEFAULT_THRESHOLD,
    TERMS,
    DriftDetector,
    DriftFinding,
    DriftReport,
    demote_stale_compress,
    demote_stale_modes,
    remeasure_term,
)
from repro.fleet.telemetry import (
    DEFAULT_WINDOW,
    TELEMETRY_FILENAME,
    TELEMETRY_FORMAT,
    ExchangeTelemetry,
    RingAggregate,
    predict_class_completions,
    predict_program_iteration,
    predict_program_phases,
)

__all__ = [
    "BUNDLE_FORMAT",
    "CONFLICT_POLICIES",
    "DEFAULT_MIN_SAMPLES",
    "DEFAULT_COMPRESS_MARGIN",
    "DEFAULT_OVERLAP_MARGIN",
    "DEFAULT_THRESHOLD",
    "DEFAULT_WINDOW",
    "TELEMETRY_FILENAME",
    "TELEMETRY_FORMAT",
    "TERMS",
    "DecisionBundle",
    "DriftDetector",
    "DriftFinding",
    "DriftReport",
    "ExchangeTelemetry",
    "RingAggregate",
    "demote_stale_compress",
    "demote_stale_modes",
    "diff_bundles",
    "load_bundle",
    "merge_bundles",
    "predict_class_completions",
    "predict_program_iteration",
    "predict_program_phases",
    "promote",
    "remeasure_term",
    "rollback",
]
