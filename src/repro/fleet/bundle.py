"""Versioned decision bundles: rollout semantics for ``decisions.json``.

A raw :class:`~repro.measure.decisions.DecisionCache` file is what one
process recorded — fine for one host, but a fleet needs to move
decisions around: merge what N hosts learned, inspect what changed
between two generations, stage a re-measured set next to the live one
and promote (or roll back) deliberately.  A :class:`DecisionBundle` is
the unit of that motion: a generation-numbered envelope wrapping a
``DecisionCache`` plus provenance (which system fingerprint recorded
it, which params store format priced it, which host), so a bundle can
never silently masquerade as measurements it is not.

Merge is **deterministic and commutative**: the same input bundles in
any order produce byte-identical output.  Conflicts (two bundles
pinning the same decision key to different rows) are resolved by an
*explicit* policy —

``newest-generation``
    the row from the highest-generation bundle wins (a re-measured
    rollout supersedes the old pin);
``lowest-price``
    the row with the lowest recorded total price wins (optimistic
    best-of-fleet; safe only across same-hardware hosts).

Both policies break remaining ties identically (lower price, then the
lexicographically smaller serialized row), so no input ordering can
leak into the result.  ``diff`` output is canonical JSON (sorted keys,
sorted rows) and round-trips byte-identically.  ``promote`` installs a
bundle's decisions as the live engine file with a ``.prev`` backup;
``rollback`` swaps the backup straight back.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.measure.decisions import (
    DECISIONS_FORMAT,
    Decision,
    DecisionCache,
    Key,
)
from repro.measure.store import STORE_FORMAT

__all__ = [
    "BUNDLE_FORMAT",
    "CONFLICT_POLICIES",
    "DecisionBundle",
    "load_bundle",
    "merge_bundles",
    "diff_bundles",
    "promote",
    "rollback",
]

#: bump when the bundle envelope schema changes incompatibly
BUNDLE_FORMAT = 1

#: explicit conflict policies for :func:`merge_bundles`
CONFLICT_POLICIES = ("newest-generation", "lowest-price")


def _row_sort_key(d: Decision) -> tuple:
    return (d.fingerprint, d.incount, d.hops, d.allow_bounding, d.strategy)


def _canonical_row(d: Decision) -> str:
    """Canonical serialized form of one decision row — the final merge
    tie-break, so two rows compare identically on every host."""
    return json.dumps(dataclasses.asdict(d), sort_keys=True)


@dataclass
class DecisionBundle:
    """Generation-numbered, provenance-stamped ``DecisionCache``."""

    decisions: DecisionCache
    generation: int = 0
    system: str = ""         # system fingerprint that recorded the rows
    params_format: int = STORE_FORMAT
    host: str = ""           # free-form origin label (hostname, CI run id)
    #: topology fingerprint the rows were planned under ("" = flat /
    #: unknown) — wire-schedule and fusion-depth rows recorded on a
    #: 2-level machine must not be promoted onto a different shape, so
    #: bundles carry the rank->node map's identity alongside the
    #: system's (optional envelope key; format stays 1)
    topology: str = ""

    # -- persistence -----------------------------------------------------
    def to_json(self) -> str:
        """Canonical form: sorted envelope keys, key-sorted rows — two
        bundles with the same content serialize byte-identically
        regardless of recording order."""
        return json.dumps(
            {
                "bundle_format": BUNDLE_FORMAT,
                "decisions_format": DECISIONS_FORMAT,
                "generation": self.generation,
                "host": self.host,
                "params_format": self.params_format,
                "system": self.system,
                "topology": self.topology,
                "rows": [
                    dataclasses.asdict(d)
                    for d in sorted(self.decisions.log, key=_row_sort_key)
                ],
            },
            sort_keys=True,
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "DecisionBundle":
        d = json.loads(s)
        if d.get("bundle_format") != BUNDLE_FORMAT:
            raise ValueError(
                f"bundle format {d.get('bundle_format')!r} != {BUNDLE_FORMAT}"
            )
        if d.get("decisions_format") != DECISIONS_FORMAT:
            raise ValueError(
                f"bundled decisions format {d.get('decisions_format')!r} != "
                f"{DECISIONS_FORMAT}; re-record or migrate"
            )
        return DecisionBundle(
            decisions=DecisionCache(
                [Decision(**row) for row in d.get("rows", ())]
            ),
            generation=int(d.get("generation", 0)),
            system=d.get("system", ""),
            params_format=int(d.get("params_format", STORE_FORMAT)),
            host=d.get("host", ""),
            topology=d.get("topology", ""),
        )

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(self.to_json())
        tmp.replace(p)
        return p

    def summary(self) -> str:
        return (
            f"bundle gen={self.generation} system={self.system or '-'}"
            f" host={self.host or '-'} params_format={self.params_format}"
            f" topo={self.topology or '-'} rows={len(self.decisions)}"
        )


def load_bundle(path: Union[str, Path]) -> DecisionBundle:
    """Load a bundle file — or a raw engine ``decisions.json``, which is
    auto-wrapped as a generation-0 bundle (so ``merge``/``diff`` accept
    what :func:`~repro.measure.production.production_communicator`
    writes without a separate conversion step)."""
    p = Path(path)
    d = json.loads(p.read_text())
    if "bundle_format" in d:
        return DecisionBundle.from_json(p.read_text())
    # raw DecisionCache file (validates its own format field)
    return DecisionBundle(decisions=DecisionCache.from_json(p.read_text()))


def _pick(
    a: Tuple[int, Decision], b: Tuple[int, Decision], policy: str
) -> Tuple[int, Decision]:
    """Resolve one key conflict between (generation, row) pairs.  Total
    order: policy criterion, then lower price, then canonical-JSON — so
    the pick is independent of argument order."""
    (ga, da), (gb, db) = a, b
    if policy == "newest-generation":
        if ga != gb:
            return a if ga > gb else b
    elif policy == "lowest-price":
        if da.total != db.total:
            return a if da.total < db.total else b
        if ga != gb:            # same price: prefer the newer provenance
            return a if ga > gb else b
    else:
        raise ValueError(
            f"unknown conflict policy {policy!r}; expected one of "
            f"{CONFLICT_POLICIES}"
        )
    if da.total != db.total:    # newest-generation tie: cheaper row
        return a if da.total < db.total else b
    return a if _canonical_row(da) <= _canonical_row(db) else b


def merge_bundles(
    bundles: Sequence[DecisionBundle],
    policy: str = "newest-generation",
    generation: Optional[int] = None,
    host: str = "",
) -> DecisionBundle:
    """Deterministic union of N bundles under ``policy``.

    The output generation defaults to ``max(input generations) + 1`` —
    a merge is a new rollout, not a re-label.  Output rows are
    key-sorted; merging the same bundles in any order yields
    byte-identical JSON.  System/params provenance carries through only
    when unanimous (a cross-system merge stamps neither fingerprint —
    the bundle says so rather than lying about where its numbers came
    from).
    """
    if not bundles:
        raise ValueError("merge_bundles needs at least one bundle")
    if policy not in CONFLICT_POLICIES:
        raise ValueError(
            f"unknown conflict policy {policy!r}; expected one of "
            f"{CONFLICT_POLICIES}"
        )
    chosen: Dict[Key, Tuple[int, Decision]] = {}
    for b in bundles:
        for d in b.decisions.log:
            cur = chosen.get(d.key)
            cand = (b.generation, d)
            chosen[d.key] = cand if cur is None else _pick(cur, cand, policy)
    rows = sorted((d for _, d in chosen.values()), key=_row_sort_key)
    systems = {b.system for b in bundles}
    formats = {b.params_format for b in bundles}
    topologies = {b.topology for b in bundles}
    return DecisionBundle(
        decisions=DecisionCache(rows),
        generation=(
            generation if generation is not None
            else max(b.generation for b in bundles) + 1
        ),
        system=systems.pop() if len(systems) == 1 else "",
        params_format=formats.pop() if len(formats) == 1 else 0,
        host=host,
        # same unanimity rule as system: a cross-topology merge stamps
        # no fingerprint rather than claiming a shape it wasn't on
        topology=topologies.pop() if len(topologies) == 1 else "",
    )


def diff_bundles(a: DecisionBundle, b: DecisionBundle) -> dict:
    """Canonical diff ``a -> b``: added / removed / changed rows, every
    list key-sorted.  ``json.dumps(diff, sort_keys=True, indent=2)``
    round-trips byte-identically (the CI gate serializes it twice and
    compares bytes)."""
    rows_a = {d.key: d for d in a.decisions.log}
    rows_b = {d.key: d for d in b.decisions.log}
    added = [rows_b[k] for k in rows_b.keys() - rows_a.keys()]
    removed = [rows_a[k] for k in rows_a.keys() - rows_b.keys()]
    changed = [
        {
            "before": dataclasses.asdict(rows_a[k]),
            "after": dataclasses.asdict(rows_b[k]),
        }
        for k in sorted(
            rows_a.keys() & rows_b.keys(),
            key=lambda k: _row_sort_key(rows_a[k]),
        )
        if rows_a[k] != rows_b[k]
    ]
    return {
        "generation_from": a.generation,
        "generation_to": b.generation,
        "added": [
            dataclasses.asdict(d) for d in sorted(added, key=_row_sort_key)
        ],
        "removed": [
            dataclasses.asdict(d) for d in sorted(removed, key=_row_sort_key)
        ],
        "changed": changed,
    }


def _prev_path(live: Path) -> Path:
    return live.with_name(live.name + ".prev")


def promote(
    bundle: DecisionBundle, live_path: Union[str, Path]
) -> Tuple[Path, Optional[Path]]:
    """Install ``bundle``'s decisions as the live engine file.

    Writes the raw ``DecisionCache`` JSON (exactly what
    ``production_communicator`` loads) to ``live_path`` after backing up
    any existing live file to ``<live_path>.prev``; the full bundle
    envelope is kept alongside as ``<live_path>.bundle`` so provenance
    survives promotion.  Returns ``(live, backup-or-None)``.
    """
    live = Path(live_path)
    live.parent.mkdir(parents=True, exist_ok=True)
    backup = None
    if live.exists():
        backup = _prev_path(live)
        backup.write_text(live.read_text())
    bundle.decisions.save(live)
    live.with_name(live.name + ".bundle").write_text(bundle.to_json())
    return live, backup


def rollback(live_path: Union[str, Path]) -> Path:
    """Undo the last :func:`promote`: restore ``<live_path>.prev``."""
    live = Path(live_path)
    backup = _prev_path(live)
    if not backup.exists():
        raise FileNotFoundError(
            f"no {backup} to roll back to — nothing was promoted here"
        )
    live.write_text(backup.read_text())
    return live
