"""Runtime exchange telemetry: the engine's first feedback loop.

PRs 1-5 made every selection *predictive*: the model prices a transfer
on once-measured tables and the ``DecisionCache`` pins the winner.
Nothing ever checked the prediction.  Hunold et al. ("MPI Derived
Datatypes: Performance Expectations and Status Quo") show why that is
dangerous — datatype performance shifts across implementations and
versions, and the same holds across a fleet's JAX/driver/hardware mix:
a pinned decision that was optimal at calibration time goes stale
silently.  This module is the observation side of that loop:

* :class:`RingAggregate` — a bounded ring buffer of observed wall times
  for ONE decision key (count / mean / p95 over the window, lifetime
  count), plus the predicted seconds the model recorded for that key,
  so ``observed / predicted`` is always one division away;
* :class:`ExchangeTelemetry` — the per-process registry of aggregates.
  ``observe()`` is the hot-path probe: one dict lookup and one ring
  write (its cost is itself measured and gated by
  ``benchmarks/bench_measure.py --assert-telemetry-overhead``);
  ``register()`` is the trace-time half, called by
  :meth:`repro.comm.api.Communicator.plan_neighbor` so every priced
  exchange has its prediction on file before the first observation.

Keys are the same content fingerprints the
:class:`~repro.measure.decisions.DecisionCache` uses — a committed
type's fingerprint for point-to-point sends, a
:class:`~repro.comm.wireplan.WirePlan` fingerprint for fused exchanges,
a program fingerprint for deep-halo iterations — so telemetry rows join
decision rows by key and :mod:`repro.fleet.drift` can compare what the
model promised against what the wire delivered.

Wall time is only meaningful where execution actually happens: inside a
``jit``/``shard_map`` trace a ``perf_counter`` pair measures tracing,
not transfer.  The Communicator therefore probes only its *eager*
blocking paths (skipping tracers), and jitted workloads time their
compiled step from the launch layer (``run_smoother`` does).
"""

from __future__ import annotations

import json
import math
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Dict, List, Optional, Tuple, Union

__all__ = [
    "TELEMETRY_FORMAT",
    "TELEMETRY_FILENAME",
    "DEFAULT_WINDOW",
    "RingAggregate",
    "ExchangeTelemetry",
    "predict_class_completions",
    "predict_program_iteration",
    "predict_program_phases",
]

#: bump when the persisted telemetry schema changes incompatibly
TELEMETRY_FORMAT = 1

#: the telemetry file lives next to ``decisions.json`` in the store
TELEMETRY_FILENAME = "telemetry.json"

#: ring-buffer window per decision key — enough samples for a stable
#: p95, small enough that a million-exchange job stays bounded
DEFAULT_WINDOW = 256


class RingAggregate:
    """Bounded ring of observed seconds for one decision key.

    The window keeps the newest ``capacity`` samples; ``total_count``
    keeps the lifetime tally so a long job's report still shows how
    much traffic the window summarizes.  Statistics are computed on
    demand (the probe itself never sorts).
    """

    __slots__ = (
        "key", "strategy", "predicted", "capacity",
        "_ring", "_next", "total_count",
    )

    def __init__(
        self,
        key: str,
        predicted: float = 0.0,
        strategy: str = "",
        capacity: int = DEFAULT_WINDOW,
    ):
        self.key = key
        self.strategy = strategy
        self.predicted = float(predicted)
        self.capacity = int(capacity)
        self._ring: List[float] = []
        self._next = 0
        self.total_count = 0

    # -- hot path --------------------------------------------------------
    def observe(self, seconds: float) -> None:
        if len(self._ring) < self.capacity:
            self._ring.append(seconds)
        else:
            self._ring[self._next] = seconds
            self._next = (self._next + 1) % self.capacity
        self.total_count += 1

    # -- statistics ------------------------------------------------------
    @property
    def count(self) -> int:
        """Samples currently in the window."""
        return len(self._ring)

    @property
    def mean(self) -> float:
        if not self._ring:
            return 0.0
        return sum(self._ring) / len(self._ring)

    @property
    def p95(self) -> float:
        if not self._ring:
            return 0.0
        s = sorted(self._ring)
        return s[min(int(math.ceil(0.95 * len(s))) - 1, len(s) - 1)]

    @property
    def ratio(self) -> Optional[float]:
        """observed mean / predicted seconds (None without both)."""
        if not self._ring or self.predicted <= 0.0:
            return None
        return self.mean / self.predicted

    # -- persistence -----------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "key": self.key,
            "strategy": self.strategy,
            "predicted": self.predicted,
            "capacity": self.capacity,
            "samples": list(self._ring),
            "total_count": self.total_count,
        }

    @staticmethod
    def from_dict(d: dict) -> "RingAggregate":
        agg = RingAggregate(
            d["key"], d.get("predicted", 0.0), d.get("strategy", ""),
            d.get("capacity", DEFAULT_WINDOW),
        )
        for s in d.get("samples", ()):
            agg.observe(float(s))
        agg.total_count = int(d.get("total_count", agg.total_count))
        return agg


class ExchangeTelemetry:
    """Per-process registry of :class:`RingAggregate` rows, keyed like
    the decision cache.  Attach to a
    :class:`~repro.comm.api.Communicator` (``telemetry=...``) or request
    one from :func:`repro.measure.production.production_communicator`
    (``telemetry=True``); ``repro.fleet.drift`` consumes the result.
    """

    def __init__(self, capacity: int = DEFAULT_WINDOW):
        self.capacity = int(capacity)
        self._by_key: Dict[str, RingAggregate] = {}

    # -- registration (trace-time half of the probe) ---------------------
    def register(
        self, key: str, predicted: float, strategy: str = ""
    ) -> RingAggregate:
        """Record the model's prediction for a decision key (idempotent;
        a re-plan updates the prediction without dropping samples)."""
        agg = self._by_key.get(key)
        if agg is None:
            agg = RingAggregate(key, predicted, strategy, self.capacity)
            self._by_key[key] = agg
        else:
            agg.predicted = float(predicted)
            if strategy:
                agg.strategy = strategy
        return agg

    # -- observation (hot path) ------------------------------------------
    def observe(
        self,
        key: str,
        seconds: float,
        predicted: Optional[float] = None,
        strategy: str = "",
    ) -> None:
        """One observed exchange: dict lookup + ring write."""
        agg = self._by_key.get(key)
        if agg is None:
            agg = RingAggregate(
                key, predicted or 0.0, strategy, self.capacity
            )
            self._by_key[key] = agg
        elif predicted is not None:
            agg.predicted = float(predicted)
        agg.observe(seconds)

    @contextmanager
    def timed(self, key: str, predicted: Optional[float] = None,
              strategy: str = ""):
        """Time a block of *blocking* work against a decision key.  The
        caller is responsible for synchronization (``block_until_ready``)
        — an async dispatch timed here would under-report."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.observe(key, time.perf_counter() - t0, predicted, strategy)

    # -- queries ---------------------------------------------------------
    def get(self, key: str) -> Optional[RingAggregate]:
        return self._by_key.get(key)

    def aggregates(self) -> List[RingAggregate]:
        """All rows, key-sorted (deterministic report order)."""
        return [self._by_key[k] for k in sorted(self._by_key)]

    def __len__(self) -> int:
        return len(self._by_key)

    def __contains__(self, key: object) -> bool:
        return key in self._by_key

    # -- report ----------------------------------------------------------
    def report(self) -> str:
        """Aligned observed-vs-predicted table, one decision key per
        line (the runtime counterpart of ``DecisionCache.report()``)."""
        lines = [
            f"{'key':16s} {'strategy':14s} {'n':>5s} {'total':>7s}"
            f" {'mean_us':>10s} {'p95_us':>10s} {'pred_us':>10s}"
            f" {'obs/pred':>9s}"
        ]
        for agg in self.aggregates():
            ratio = agg.ratio
            shown = f"{ratio:9.3f}" if ratio is not None else f"{'-':>9s}"
            lines.append(
                f"{agg.key:16s} {agg.strategy:14s} {agg.count:5d}"
                f" {agg.total_count:7d} {agg.mean * 1e6:10.3f}"
                f" {agg.p95 * 1e6:10.3f} {agg.predicted * 1e6:10.3f}"
                f" {shown}"
            )
        return "\n".join(lines)

    # -- persistence -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "format": TELEMETRY_FORMAT,
                "capacity": self.capacity,
                "aggregates": [
                    self._by_key[k].to_dict() for k in sorted(self._by_key)
                ],
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "ExchangeTelemetry":
        d = json.loads(s)
        if d.get("format") != TELEMETRY_FORMAT:
            raise ValueError(
                f"telemetry file format {d.get('format')!r} != "
                f"{TELEMETRY_FORMAT}; re-run with telemetry on"
            )
        tel = ExchangeTelemetry(d.get("capacity", DEFAULT_WINDOW))
        for row in d.get("aggregates", ()):
            tel._by_key[row["key"]] = RingAggregate.from_dict(row)
        return tel

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(self.to_json())
        tmp.replace(p)  # atomic: concurrent readers never see a torn file
        return p

    @staticmethod
    def load(path: Union[str, Path]) -> "ExchangeTelemetry":
        """Load saved telemetry; an absent file yields an empty registry
        (a cold job starts observing from zero)."""
        p = Path(path)
        if not p.exists():
            return ExchangeTelemetry()
        return ExchangeTelemetry.from_json(p.read_text())


def predict_program_phases(program, model) -> Dict[str, float]:
    """The model's per-phase prediction of ONE deep-halo iteration:
    ``{"pack", "wire", "unpack", "stencil"}`` seconds, summing to
    :func:`predict_program_iteration`.

    The member pack/unpack terms are re-priced per committed type
    through the plan's strategies; the wire phase is what remains of the
    estimate's exchange half (so the decomposition is exactly
    consistent with the recorded decision price).  The stencil phase is
    the redundant ghost-shell compute the estimate prices *plus* the
    interior compute it deliberately excludes (every candidate depth
    pays the interior equally — but a wall-clock observer sees it).
    Feeds the per-phase ``pred`` attributes on
    :func:`repro.obs.trace.attribute_program_iteration` span trees and,
    through them, trace-sourced drift attribution.
    """
    est = program.estimate
    t_pack = t_unpack = 0.0
    for ct, strat in zip(program.plan.send_cts, program.plan.strategies):
        e = model.estimate(ct, 1, strat)
        t_pack += e.t_pack
        t_unpack += e.t_unpack
    t_wire = max(est.t_exchange - t_pack - t_unpack, 0.0)
    t_stencil = est.t_redundant
    interior_bytes = (
        math.prod(program.spec.interior) * program.spec.element.size
    )
    for op in program.ops:
        t_app = model.measured_stencil(op.nneighbors, interior_bytes)
        if t_app is None:
            t_app = (op.nneighbors + 2) * (
                interior_bytes / model.params.hbm_bw
            )
        t_stencil += t_app * program.steps
    return {
        "pack": t_pack, "wire": t_wire, "unpack": t_unpack,
        "stencil": t_stencil,
    }


def predict_class_completions(program, model) -> Dict[str, float]:
    """The model's per-delta-class wire-completion predictions for a
    deep-halo program, keyed exactly like the Communicator's per-class
    telemetry rows (``{wire_fingerprint}/c{g}``, the keys
    :meth:`repro.comm.api.Communicator.plan_neighbor` registers when
    the plan has more than one class).  Joining these against the
    observed per-class drain latencies attributes drift to the slow
    *direction* rather than the whole exchange — the region-split
    overlap scheduler's feedback loop."""
    wire = program.plan.wire
    completions = model.price_class_completions(wire)
    return {
        f"{wire.fingerprint}/c{g}": float(t)
        for g, t in enumerate(completions)
    }


def predict_program_iteration(program, model) -> float:
    """Predicted wall seconds of ONE deep-halo program iteration as the
    launch layer observes it: the model's exchange + redundant-shell
    estimate plus the interior stencil compute the estimate deliberately
    excludes (every candidate depth pays the interior equally, so
    ``price_program`` never prices it — but the step timer sees it).
    Priced from the measured stencil sweep when calibrated, else the
    same contiguous-copy proxy ``PerfModel._redundant_time`` falls back
    to.  The per-phase split is :func:`predict_program_phases`."""
    return sum(predict_program_phases(program, model).values())
