"""Drift detection + targeted re-measurement.

A pinned :class:`~repro.measure.decisions.Decision` carries the terms
the model believed at decision time (``t_pack`` / ``t_link`` /
``t_unpack``).  Two things can invalidate it:

* the **system moved** — a JAX upgrade, a driver change, thermal
  throttling: the stored :class:`~repro.comm.perfmodel.SystemParams`
  tables no longer describe the machine.  Detected by comparing the
  stored tables against a *reference* calibration (freshly measured, or
  the CI artifact recorded minutes ago) term by term;
* the **traffic moved** — runtime observations
  (:class:`~repro.fleet.telemetry.ExchangeTelemetry`) diverge from the
  recorded price beyond a threshold over a minimum sample count.

Either way the response is the same and *targeted*: re-measure only the
drifted term's table (:func:`remeasure_term` re-runs just that
``measure.bench`` sweep), not the full calibration — the paper's
"record once" economy survives contact with a fleet.

Term attribution maps the model's cost decomposition onto the sweep
that produced each term:

====================  =======================================  ==========
term                  decision rows it prices                   sweep
====================  =======================================  ==========
``wire``              ``wire/<schedule>`` exchange rows; the    ``measure_wire_table``
                      ``t_link`` of every strategy row; the
                      exchange half of ``program/s=N`` rows
``pack_unpack``       ``t_pack``/``t_unpack`` of strategy rows  ``measure_pack_table`` +
                                                                ``measure_unpack_table``
``stencil``           the redundant-compute half of             ``measure_stencil_table``
                      ``program/s=N`` rows
``copy``              the contiguous-copy proxy terms           ``measure_copy_table``
``compress``          the encode/decode cost of compressed      ``measure_compress_table``
                      strategy rows; the achieved-ratio check
                      of ``wire/varlen`` pins (telemetry ring)
====================  =======================================  ==========

The whole audit is machine-readable: :class:`DriftReport` serializes to
JSON (CI asserts well-formedness and gates on ``drifted_count == 0``),
and ``python -m repro.fleet report`` renders it next to the telemetry
table.
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.comm.perfmodel import PerfModel, SystemParams
from repro.fleet.telemetry import ExchangeTelemetry

__all__ = [
    "DRIFT_FORMAT",
    "TERMS",
    "DEFAULT_THRESHOLD",
    "DEFAULT_MIN_SAMPLES",
    "DEFAULT_OVERLAP_MARGIN",
    "DEFAULT_COMPRESS_MARGIN",
    "DriftFinding",
    "DriftReport",
    "DriftDetector",
    "remeasure_term",
    "demote_stale_modes",
    "demote_stale_compress",
]

#: bump when the persisted DriftReport schema changes incompatibly.
#: Format 2 (PR 7): finding ``source`` distinguishes ``"trace"`` (direct
#: per-phase span observation), ``"telemetry"`` (whole-exchange runtime
#: ratio) and ``"interpolated"`` (table-interpolation inference, the
#: format-1 ``"params"``); findings gain ``phase_ratios``.  Format-1
#: files still load (``from_json`` normalizes old source labels).
DRIFT_FORMAT = 2

#: older report formats ``from_json`` accepts (normalized on load)
_COMPAT_FORMATS = (1, DRIFT_FORMAT)

#: which model term each trace phase span is evidence for
_PHASE_TERM = {
    "wire": "wire",
    "pack": "pack_unpack",
    "unpack": "pack_unpack",
    "stencil": "stencil",
}

#: the model terms a drift can be attributed to, each owning exactly one
#: calibration sweep (see module docstring table)
TERMS: Tuple[str, ...] = ("wire", "pack_unpack", "stencil", "copy", "compress")

#: flag when stored/reference (or observed/predicted) diverge beyond
#: this factor in either direction — generous because CPU-runner sweeps
#: are noisy; a fleet with stable hardware should tighten it
DEFAULT_THRESHOLD = 5.0

#: runtime findings need at least this many window samples: one slow
#: exchange is an outlier, a windowful is drift
DEFAULT_MIN_SAMPLES = 8

#: an ``overlap/mode=<m>`` pin is stale when the *measured* iteration
#: time of the chosen mode exceeds the best measured alternative by
#: this factor — much tighter than :data:`DEFAULT_THRESHOLD` because
#: the comparison is same-machine same-moment (both modes timed in one
#: smoother run), so table noise does not apply
DEFAULT_OVERLAP_MARGIN = 1.25

#: a ``wire/varlen`` pin is stale when the *achieved* compression ratio
#: (the per-exchange stream/capacity observations in the telemetry ring
#: keyed ``<fingerprint>/ratio``) decays past the probed ratio recorded
#: in the pin's signature by this factor — the schedule is then moving
#: more bytes than the price it was chosen on.  Tight like the overlap
#: margin: both sides are same-payload same-machine observations, no
#: table noise involved
DEFAULT_COMPRESS_MARGIN = 1.25

#: the probed stream ratio a compressed pin's signature records
#: (``... ratio=0.0514 ...``)
_RATIO_RE = re.compile(r"\bratio=([0-9.eE+-]+)")


def _pinned_ratio(signature: str) -> Optional[float]:
    m = _RATIO_RE.search(signature or "")
    if m is None:
        return None
    try:
        return float(m.group(1))
    except ValueError:
        return None


@dataclass(frozen=True)
class DriftFinding:
    """One decision row's drift verdict.

    ``source`` says where the term attribution came from, strongest
    evidence first: ``"trace"`` — direct per-phase span observations
    (``DriftDetector.audit(trace=...)``); ``"telemetry"`` — the
    whole-exchange runtime ratio flagged it; ``"interpolated"`` — the
    term was *inferred* by interpolating stored vs reference calibration
    tables (no runtime observation involved).  Consumers gating on
    ``--assert-no-drift`` can weigh a ``"trace"`` finding above an
    inferred one.
    """

    fingerprint: str
    strategy: str
    term: str            # attributed term ("" when nothing diverges)
    ratio: float         # observed/predicted (trace) or stored/reference
    drifted: bool
    source: str          # "trace" | "telemetry" | "interpolated"
    recorded_total: float = 0.0   # the Decision's recorded price (sec)
    repriced_total: float = 0.0   # same decision priced on the reference
    observed_mean: float = 0.0    # runtime mean (telemetry joins only)
    observed_ratio: float = 0.0   # observed/predicted (0 = no telemetry)
    samples: int = 0
    signature: str = ""
    #: per-term observed/predicted ratios from trace aggregates (empty
    #: without a trace join) — the direct attribution evidence
    phase_ratios: Dict[str, float] = field(default_factory=dict)


@dataclass
class DriftReport:
    """Machine-readable audit result: per-term table ratios + per-row
    findings.  ``drifted_count == 0`` is the CI gate."""

    system: str
    threshold: float
    min_samples: int
    term_ratios: Dict[str, float] = field(default_factory=dict)
    findings: Tuple[DriftFinding, ...] = ()

    @property
    def drifted(self) -> Tuple[DriftFinding, ...]:
        return tuple(f for f in self.findings if f.drifted)

    @property
    def drifted_count(self) -> int:
        return len(self.drifted)

    @property
    def drifted_terms(self) -> Tuple[str, ...]:
        """The distinct attributed terms, sorted — what
        :func:`remeasure_term` should be pointed at."""
        return tuple(sorted({f.term for f in self.drifted if f.term}))

    def to_json(self) -> str:
        return json.dumps(
            {
                "format": DRIFT_FORMAT,
                "system": self.system,
                "threshold": self.threshold,
                "min_samples": self.min_samples,
                "term_ratios": dict(sorted(self.term_ratios.items())),
                "findings": [dataclasses.asdict(f) for f in self.findings],
            },
            indent=2,
        )

    @staticmethod
    def from_json(s: str) -> "DriftReport":
        d = json.loads(s)
        if d.get("format") not in _COMPAT_FORMATS:
            raise ValueError(
                f"drift report format {d.get('format')!r} not in "
                f"{_COMPAT_FORMATS}"
            )
        findings = []
        for row in d.get("findings", ()):
            row = dict(row)
            # format 1 called table-interpolation findings "params"
            if row.get("source") == "params":
                row["source"] = "interpolated"
            findings.append(DriftFinding(**row))
        return DriftReport(
            system=d.get("system", ""),
            threshold=float(d["threshold"]),
            min_samples=int(d["min_samples"]),
            term_ratios=dict(d.get("term_ratios", {})),
            findings=tuple(findings),
        )

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(self.to_json())
        return p

    def summary(self) -> str:
        lines = [
            f"drift audit: {len(self.findings)} decisions, "
            f"{self.drifted_count} drifted "
            f"(threshold x{self.threshold:g}, min_samples "
            f"{self.min_samples})"
        ]
        for t in TERMS:
            if t in self.term_ratios:
                lines.append(
                    f"  term {t:12s} stored/reference = "
                    f"{self.term_ratios[t]:.3f}"
                )
        for f in self.findings:
            mark = "DRIFT" if f.drifted else "ok"
            obs = (
                f" observed/pred={f.observed_ratio:.2f} (n={f.samples})"
                if f.samples else ""
            )
            lines.append(
                f"  [{mark:5s}] {f.fingerprint:16s} {f.strategy:14s} "
                f"term={f.term or '-':11s} ratio={f.ratio:.3f} "
                f"source={f.source}{obs}"
            )
        return "\n".join(lines)


def _geomean_ratio(pairs: Sequence[Tuple[float, float]]) -> Optional[float]:
    """Geometric mean of a/b over positive pairs (None when empty) —
    robust to the odd noisy grid point in a way an arithmetic mean of
    ratios is not."""
    logs = [
        math.log(a / b) for a, b in pairs if a > 0.0 and b > 0.0
    ]
    if not logs:
        return None
    return math.exp(sum(logs) / len(logs))


def _table1d_ratio(stored, reference) -> Optional[float]:
    """stored/reference ratio of two (log2_x, sec) tables, compared by
    interpolating the stored table at the reference's grid points."""
    if not stored or not reference:
        return None
    from repro.comm.perfmodel import _Interp1D

    interp = _Interp1D(tuple(tuple(r) for r in stored))
    return _geomean_ratio([(interp(x), sec) for x, sec in reference])


def _table2d_ratio(stored, reference) -> Optional[float]:
    """Same, for (log2_a, log2_b, sec) tables."""
    if not stored or not reference:
        return None
    from repro.comm.perfmodel import _Interp2D

    interp = _Interp2D(tuple(tuple(r) for r in stored))
    return _geomean_ratio([(interp(x, y), sec) for x, y, sec in reference])


def _strategy_tables_ratio(stored, reference) -> Optional[float]:
    """stored/reference over the per-strategy 2D tables they share."""
    if not stored or not reference:
        return None
    ratios = []
    for name in sorted(set(stored) & set(reference)):
        r = _table2d_ratio(stored[name], reference[name])
        if r is not None:
            ratios.append((r, 1.0))
    return _geomean_ratio(ratios)


def _compress_tables_ratio(stored, reference) -> Optional[float]:
    """stored/reference over the per-compressor sweep tables
    (``(log2_total, compress_sec, decompress_sec, ratio_sample)`` rows):
    both timing columns compared as 1D tables, the informational ratio
    column ignored."""
    if not stored or not reference:
        return None
    ratios = []
    for name in sorted(set(stored) & set(reference)):
        for col in (1, 2):
            r = _table1d_ratio(
                [(row[0], row[col]) for row in stored[name]],
                [(row[0], row[col]) for row in reference[name]],
            )
            if r is not None:
                ratios.append((r, 1.0))
    return _geomean_ratio(ratios)


def _trace_term_ratios(
    rec: Dict[str, dict],
) -> Tuple[Dict[str, float], int]:
    """Observed/predicted ratio per model term from one decision key's
    trace phase aggregates (``{phase: {count, observed, predicted}}``,
    see :func:`repro.obs.export.aggregate_spans`).  The pack and unpack
    phases pool into the one ``pack_unpack`` term (they share a
    calibration sweep).  Returns ``(ratios, samples)`` where samples is
    the per-iteration observation count behind the ratios."""
    by_term: Dict[str, List[float]] = {}
    counts: List[int] = []
    for phase, r in rec.items():
        term = _PHASE_TERM.get(phase)
        if term is None:
            continue
        agg = by_term.setdefault(term, [0.0, 0.0])
        agg[0] += float(r.get("observed", 0.0))
        agg[1] += float(r.get("predicted", 0.0))
        counts.append(int(r.get("count", 0)))
    ratios = {
        t: o / p for t, (o, p) in by_term.items() if o > 0.0 and p > 0.0
    }
    return ratios, (max(counts) if counts else 0)


def _terms_of(strategy: str) -> Tuple[str, ...]:
    """Which model terms a decision row's price is built from, in
    attribution priority order."""
    if strategy.startswith("wire/"):
        return ("wire",)
    if strategy.startswith("program/s="):
        # t_link slot holds the exchange, t_pack slot the redundant
        # stencil compute (see build_halo_program's record call)
        return ("wire", "stencil", "copy")
    if strategy.startswith("overlap/mode="):
        # an overlap-mode row prices stencil compute against wire time
        # (the overlap trade); neither table alone re-measures it — the
        # authoritative check is the smoother's per-mode timings
        return ("stencil", "wire")
    if strategy in ("rlewire", "int8wire"):
        # a compressed-wire selection prices the encode/decode sweep on
        # top of the base pack/unpack terms
        return ("pack_unpack", "compress", "wire")
    return ("pack_unpack", "wire")


class DriftDetector:
    """Compare what the engine believes against a reference (and the
    runtime), flag divergent decisions, attribute each to a term."""

    def __init__(
        self,
        threshold: float = DEFAULT_THRESHOLD,
        min_samples: int = DEFAULT_MIN_SAMPLES,
    ):
        if threshold <= 1.0:
            raise ValueError(f"threshold must be > 1, got {threshold}")
        self.threshold = float(threshold)
        self.min_samples = int(min_samples)

    # -- table-level comparison ------------------------------------------
    def term_ratios(
        self, params: SystemParams, reference: SystemParams
    ) -> Dict[str, float]:
        """stored/reference price ratio per term, from the term's own
        calibration table (absent tables are skipped, not guessed)."""
        out: Dict[str, float] = {}
        r = _table1d_ratio(params.wire_table, reference.wire_table)
        if r is not None:
            out["wire"] = r
        pack = _strategy_tables_ratio(params.pack_table, reference.pack_table)
        unpack = _strategy_tables_ratio(
            params.unpack_table, reference.unpack_table
        )
        pu = _geomean_ratio(
            [(v, 1.0) for v in (pack, unpack) if v is not None]
        )
        if pu is not None:
            out["pack_unpack"] = pu
        r = _table2d_ratio(params.stencil_table, reference.stencil_table)
        if r is not None:
            out["stencil"] = r
        r = _table1d_ratio(params.copy_table, reference.copy_table)
        if r is not None:
            out["copy"] = r
        r = _compress_tables_ratio(
            params.compress_table, reference.compress_table
        )
        if r is not None:
            out["compress"] = r
        return out

    def _out_of_band(self, ratio: float) -> bool:
        return ratio > self.threshold or ratio < 1.0 / self.threshold

    # -- the audit -------------------------------------------------------
    def audit(
        self,
        decisions,
        params: SystemParams,
        reference: Optional[SystemParams] = None,
        telemetry: Optional[ExchangeTelemetry] = None,
        system: str = "",
        trace: Optional[Dict[str, Dict[str, dict]]] = None,
        overlap_timings: Optional[Dict[str, Dict[str, float]]] = None,
        overlap_margin: float = DEFAULT_OVERLAP_MARGIN,
        compress_margin: float = DEFAULT_COMPRESS_MARGIN,
    ) -> DriftReport:
        """One finding per decision row.

        With ``trace`` (per-decision phase aggregates from
        :meth:`repro.obs.Tracer.phase_aggregates` or
        :func:`repro.obs.export.aggregate_events`): a row whose
        fingerprint has trace coverage gets **direct** term attribution
        — each phase's observed/predicted ratio maps onto the term that
        phase is evidence for (pack+unpack pool into ``pack_unpack``),
        the worst out-of-band term wins, and the finding's ``source`` is
        ``"trace"``.  Rows without trace coverage fall back to the
        interpolated path below.

        With ``reference``: each row's terms are checked against the
        reference tables; a row drifts when a term it prices is out of
        band, attributed to the *worst* such term (``source``
        ``"interpolated"`` — the attribution is inferred, not
        observed).  The ``wire`` term is additionally re-priced
        point-wise at the row's exact ``wire_bytes`` (more honest than
        the table-mean for a row living at one message size).  With
        ``telemetry``: rows whose observed/predicted ratio is out of
        band over ``min_samples`` drift too — attributed through the
        reference when one is given, else left unattributed
        (``term=""``; re-measure everything or bring a reference).

        With ``overlap_timings`` (``{fingerprint: {mode: measured
        iteration seconds}}``, the per-mode timings a smoother sweep
        already collects): every ``overlap/mode=<m>`` row is checked
        against what was *measured*, not modeled — the observed ratio
        is the chosen mode's iteration time over the best measured
        alternative mode (``"off"`` excluded: it is the no-overlap
        baseline, not an alternative schedule).  A ratio above
        ``overlap_margin`` flags the pin (``term="overlap"``, source
        ``"telemetry"``); :func:`demote_stale_modes` then deletes it so
        the next smoother pass re-prices.

        ``wire/varlen`` rows carry their probed compression ratio in the
        pin signature (``ratio=<r>``), and every varlen exchange records
        its achieved ratio in the telemetry ring keyed
        ``<fingerprint>/ratio``.  When the ring mean decays past the
        pinned ratio by more than ``compress_margin`` over
        ``min_samples`` observations, the pin drifts (``term="compress"``,
        source ``"telemetry"``): the payload no longer compresses as
        promised, so the schedule is moving more bytes than the price it
        was chosen on.  :func:`demote_stale_compress` deletes flagged
        varlen pins (and probed compressed selections) so the next
        planning pass re-probes.
        """
        ratios = (
            self.term_ratios(params, reference) if reference is not None
            else {}
        )
        model = PerfModel(params)
        ref_model = PerfModel(reference) if reference is not None else None
        findings: List[DriftFinding] = []
        for d in decisions.log:
            terms = _terms_of(d.strategy)
            # per-row term ratios: start from the table-level numbers,
            # refine "wire" at the row's own byte count
            row_ratios: Dict[str, float] = {
                t: ratios[t] for t in terms if t in ratios
            }
            if (
                ref_model is not None
                and "wire" in terms
                and d.wire_bytes > 0
            ):
                hops = max(d.hops, 1)
                stored_link = model.t_link(d.wire_bytes, hops)
                ref_link = ref_model.t_link(d.wire_bytes, hops)
                if stored_link > 0 and ref_link > 0:
                    row_ratios["wire"] = stored_link / ref_link
            source = "interpolated"
            phase_ratios: Dict[str, float] = {}
            trace_samples = 0
            rec = (trace or {}).get(d.fingerprint)
            if rec:
                t_ratios, trace_samples = _trace_term_ratios(rec)
                phase_ratios = {
                    t: r for t, r in t_ratios.items() if t in terms
                }
                if phase_ratios:
                    # direct observation beats inference: the trace's
                    # per-phase ratios replace the interpolated ones
                    row_ratios = phase_ratios
                    source = "trace"
            # re-price the recorded total term by term: each recorded
            # slot divided by its stored/reference ratio (strategy class
            # determines which slot belongs to which term — program rows
            # keep redundant stencil compute in t_pack, see _terms_of)
            per_term = {
                "wire": d.t_link,
                "pack_unpack": d.t_pack + d.t_unpack,
                "stencil": d.t_pack if "stencil" in terms else 0.0,
                "copy": 0.0,
            }
            if "stencil" in terms:
                per_term["pack_unpack"] = 0.0
            repriced = sum(
                per_term.get(t, 0.0) / row_ratios.get(t, 1.0) for t in terms
            )
            worst_term, worst = "", 1.0
            for t, r in row_ratios.items():
                if abs(math.log(r)) > abs(math.log(worst)):
                    worst_term, worst = t, r
            drifted = bool(worst_term) and self._out_of_band(worst)
            if source == "trace":
                # runtime evidence: one slow iteration is an outlier, a
                # windowful is drift — same sample gate as telemetry
                drifted = drifted and trace_samples >= self.min_samples

            obs_mean = obs_ratio = 0.0
            samples = trace_samples if source == "trace" else 0
            agg = telemetry.get(d.fingerprint) if telemetry is not None else None
            if agg is not None:
                obs_mean = agg.mean
                samples = agg.count
                r = agg.ratio
                if r is not None:
                    obs_ratio = r
                    if samples >= self.min_samples and self._out_of_band(r):
                        if not drifted and source != "trace":
                            source = "telemetry"
                        drifted = True
            term = worst_term if self._out_of_band(worst) else ""
            ratio = worst
            # measured per-mode timings trump everything for overlap
            # pins: the chosen mode losing to a measured alternative by
            # more than the margin is drift, no table inference needed
            if overlap_timings is not None and d.strategy.startswith(
                "overlap/mode="
            ):
                modes = overlap_timings.get(d.fingerprint) or {}
                chosen = d.strategy.split("=", 1)[1]
                t_chosen = modes.get(chosen, 0.0)
                alternatives = [
                    t for m, t in modes.items()
                    if m not in (chosen, "off") and t > 0.0
                ]
                if t_chosen > 0.0 and alternatives:
                    r = t_chosen / min(alternatives)
                    obs_ratio = r
                    obs_mean = t_chosen
                    if r > overlap_margin:
                        drifted = True
                        source = "telemetry"
                        term, ratio = "overlap", r
            # a varlen pin's premise is its probed compression ratio:
            # the achieved-ratio ring decaying past the margin means the
            # compressed bytes on the wire grew past what was priced
            if telemetry is not None and d.strategy == "wire/varlen":
                pinned = _pinned_ratio(d.signature)
                ring = telemetry.get(f"{d.fingerprint}/ratio")
                if (
                    pinned
                    and ring is not None
                    and ring.count >= self.min_samples
                    and ring.mean > 0.0
                ):
                    r = ring.mean / pinned
                    obs_mean = ring.mean
                    obs_ratio = r
                    samples = ring.count
                    if r > compress_margin:
                        drifted = True
                        source = "telemetry"
                        term, ratio = "compress", r
            findings.append(
                DriftFinding(
                    fingerprint=d.fingerprint,
                    strategy=d.strategy,
                    term=term,
                    ratio=ratio,
                    drifted=drifted,
                    source=source,
                    recorded_total=d.total,
                    repriced_total=repriced,
                    observed_mean=obs_mean,
                    observed_ratio=obs_ratio,
                    samples=samples,
                    signature=d.signature,
                    phase_ratios=dict(sorted(phase_ratios.items())),
                )
            )
        report = DriftReport(
            system=system,
            threshold=self.threshold,
            min_samples=self.min_samples,
            term_ratios=ratios,
            findings=tuple(findings),
        )
        from repro.obs.metrics import default_metrics

        default_metrics().inc("drift.findings", len(report.findings))
        default_metrics().inc("drift.drifted", report.drifted_count)
        return report


def remeasure_term(
    params: SystemParams,
    term: str,
    reduced: bool = True,
    iters: Optional[int] = None,
    measured: Optional[dict] = None,
) -> SystemParams:
    """Targeted re-measurement: re-run ONLY the drifted term's sweep and
    splice the fresh table into ``params``, leaving every other measured
    term untouched — the surgical response a :class:`DriftReport`
    prescribes (a full ``calibrate_params`` re-run would throw away
    every still-valid table with it).

    ``measured`` injects pre-computed sweep output keyed by the
    SystemParams field names (tests and offline replays); by default the
    sweep runs on the live backend via ``repro.measure.bench``.
    """
    if term not in TERMS:
        raise ValueError(f"unknown term {term!r}; expected one of {TERMS}")
    from repro.measure import bench

    totals = bench.REDUCED_TOTAL_BYTES if reduced else bench.TOTAL_BYTES
    blocks = bench.REDUCED_BLOCK_BYTES if reduced else bench.BLOCK_BYTES
    radii = bench.REDUCED_STENCIL_RADII if reduced else bench.STENCIL_RADII
    it = iters if iters is not None else (2 if reduced else 5)

    updates: Dict[str, object] = {}
    if measured is not None:
        updates = dict(measured)
    elif term == "wire":
        rows = bench.measure_wire_table(totals, iters=it)
        lat, bw = bench.fit_latency_bandwidth(rows)
        updates = {
            "wire_table": tuple(rows), "wire_latency": lat, "wire_bw": bw,
        }
    elif term == "pack_unpack":
        pack = bench.measure_pack_table(None, blocks, totals, iters=it)
        unpack = bench.measure_unpack_table(None, blocks, totals, iters=it)
        updates = {
            "pack_table": {k: tuple(v) for k, v in pack.items() if v},
            "unpack_table": {k: tuple(v) for k, v in unpack.items() if v},
        }
    elif term == "stencil":
        rows = bench.measure_stencil_table(radii, totals, iters=it)
        updates = {"stencil_table": tuple(rows)}
    elif term == "copy":
        rows = bench.measure_copy_table(totals, iters=it)
        updates = {"copy_table": tuple(rows)}
    elif term == "compress":
        table = bench.measure_compress_table(total_bytes=totals, iters=it)
        updates = {
            "compress_table": {k: tuple(v) for k, v in table.items() if v}
        }
    return dataclasses.replace(params, **updates)


def demote_stale_modes(decisions, report: DriftReport) -> List[str]:
    """Delete every ``overlap/mode=`` decision row the ``report``
    flagged as drifted, so the next smoother pass re-measures and
    re-records instead of replaying a pin the measurements contradict.

    Returns the ``"strategy@fingerprint"`` labels of the demoted rows.
    The ``"overlap"`` term is *not* in :data:`TERMS` on purpose: no
    calibration sweep re-measures an overlap trade — demotion followed
    by a smoother re-run is the targeted response.
    """
    stale = {
        f.fingerprint
        for f in report.drifted
        if f.strategy.startswith("overlap/mode=")
    }
    dropped = decisions.prune(
        lambda d: d.strategy.startswith("overlap/mode=")
        and d.fingerprint in stale
    )
    return [f"{d.strategy}@{d.fingerprint}" for d in dropped]


def demote_stale_compress(decisions, report: DriftReport) -> List[str]:
    """Delete every ``wire/varlen`` schedule pin the ``report`` flagged
    for compression-ratio drift (``term="compress"``), plus every probed
    compressed *selection* row (a strategy row whose signature carries
    ``stream_bytes=``) — the selection pins share the drifted schedule's
    premise (the probed ratio) but live under the datatype fingerprint,
    not the plan fingerprint, so they cannot be joined row-for-row.  The
    next planning pass re-probes the actual payload and re-records both.

    Returns the ``"strategy@fingerprint"`` labels of the demoted rows.
    """
    stale = {
        f.fingerprint
        for f in report.drifted
        if f.strategy == "wire/varlen" and f.term == "compress"
    }
    if not stale:
        return []
    dropped = decisions.prune(
        lambda d: (d.strategy == "wire/varlen" and d.fingerprint in stale)
        or (
            not d.strategy.startswith(("wire/", "overlap/", "program/"))
            and " stream_bytes=" in f" {d.signature}"
        )
    )
    return [f"{d.strategy}@{d.fingerprint}" for d in dropped]
