"""Operator CLI for the fleet layer.

::

    python -m repro.fleet report  --store STORE [--reference ENV] ...
    python -m repro.fleet stats   --store STORE
    python -m repro.fleet diff    A B [--out FILE]
    python -m repro.fleet merge   IN [IN ...] --out FILE [--policy P]
    python -m repro.fleet promote BUNDLE --live PATH
    python -m repro.fleet promote --rollback --live PATH

``report`` renders a smoother/train run's observed-vs-predicted table
(and, given a reference calibration, the drift audit — exit 1 with
``--assert-no-drift`` when anything drifted).  ``stats`` renders the
``metrics.json`` counter snapshot a production run persisted on
``save()`` (exchange/wire-byte/decision-cache counters, telemetry ring
occupancy — :mod:`repro.obs.metrics`).  ``merge`` unifies N host
bundles (raw ``decisions.json`` files are auto-wrapped) under an
explicit conflict policy.  ``diff`` emits canonical JSON that
round-trips byte-identically.  ``promote`` stages a bundle as the live
engine file with a ``.prev`` backup for ``--rollback``.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.fleet.bundle import (
    CONFLICT_POLICIES,
    diff_bundles,
    load_bundle,
    merge_bundles,
    promote,
    rollback,
)
from repro.fleet.drift import (
    DEFAULT_MIN_SAMPLES,
    DEFAULT_THRESHOLD,
    DriftDetector,
)
from repro.fleet.telemetry import TELEMETRY_FILENAME, ExchangeTelemetry


def _cmd_report(args: argparse.Namespace) -> int:
    from repro.measure.decisions import DecisionCache
    from repro.measure.production import DECISIONS_FILENAME
    from repro.measure.store import ParamsStore

    store = Path(args.store)
    tel_path = Path(args.telemetry) if args.telemetry else (
        store / TELEMETRY_FILENAME
    )
    dec_path = Path(args.decisions) if args.decisions else (
        store / DECISIONS_FILENAME
    )
    telemetry = ExchangeTelemetry.load(tel_path)
    decisions = DecisionCache.load(dec_path)

    print(f"telemetry: {tel_path} ({len(telemetry)} keys)")
    print(telemetry.report())
    print()
    print(f"decisions: {dec_path} ({len(decisions)} rows)")
    print(decisions.report())

    if args.reference is None:
        if args.assert_no_drift:
            print(
                "error: --assert-no-drift needs --reference", file=sys.stderr
            )
            return 2
        return 0

    # drift audit: the live params this run priced with, vs the
    # reference calibration the operator trusts
    reference = ParamsStore.read_envelope(args.reference)
    if reference is None:
        print(
            f"error: unreadable reference envelope {args.reference}",
            file=sys.stderr,
        )
        return 2
    if args.params is not None:
        params = ParamsStore.read_envelope(args.params)
        if params is None:
            print(
                f"error: unreadable params envelope {args.params}",
                file=sys.stderr,
            )
            return 2
    else:
        params = reference  # self-audit: telemetry findings only
    detector = DriftDetector(args.threshold, args.min_samples)
    report = detector.audit(
        decisions, params, reference=reference, telemetry=telemetry,
        system=args.system,
    )
    print()
    print(report.summary())
    if args.drift_report:
        p = report.save(args.drift_report)
        print(f"drift report -> {p}")
    if args.assert_no_drift and report.drifted_count:
        print(
            f"DRIFT GATE FAILED: {report.drifted_count} drifted "
            f"decision(s): {', '.join(sorted(set(f.fingerprint for f in report.drifted)))}",
            file=sys.stderr,
        )
        return 1
    return 0


def _cmd_stats(args: argparse.Namespace) -> int:
    from repro.measure.production import DECISIONS_FILENAME
    from repro.obs.metrics import METRICS_FILENAME, MetricsRegistry

    store = Path(args.store)
    metrics_path = Path(args.metrics) if args.metrics else (
        store / METRICS_FILENAME
    )
    registry = MetricsRegistry.load(metrics_path)
    print(f"metrics: {metrics_path} ({len(registry)} series)")
    print(registry.report())

    dec_path = store / DECISIONS_FILENAME
    if dec_path.exists():
        try:
            bundle = load_bundle(dec_path)
        except Exception:
            bundle = None
        if bundle is not None:
            print()
            print(f"decisions: {bundle.summary()}")
    if args.json:
        print()
        print(json.dumps(registry.snapshot(), indent=2, sort_keys=True))
    return 0


def _cmd_diff(args: argparse.Namespace) -> int:
    d = diff_bundles(load_bundle(args.a), load_bundle(args.b))
    s = json.dumps(d, sort_keys=True, indent=2)
    if args.out:
        Path(args.out).write_text(s)
        print(f"diff -> {args.out}")
    else:
        print(s)
    n = len(d["added"]) + len(d["removed"]) + len(d["changed"])
    return 1 if (args.assert_same and n) else 0


def _cmd_merge(args: argparse.Namespace) -> int:
    bundles = [load_bundle(p) for p in args.inputs]
    merged = merge_bundles(
        bundles, policy=args.policy, generation=args.generation,
        host=args.host,
    )
    merged.save(args.out)
    print(f"{merged.summary()} -> {args.out}")
    return 0


def _cmd_promote(args: argparse.Namespace) -> int:
    if args.rollback:
        live = rollback(args.live)
        print(f"rolled back {live} from {live}.prev")
        return 0
    if args.bundle is None:
        print("error: promote needs a BUNDLE (or --rollback)",
              file=sys.stderr)
        return 2
    bundle = load_bundle(args.bundle)
    live, backup = promote(bundle, args.live)
    prev = f" (previous saved to {backup})" if backup else ""
    print(f"promoted {bundle.summary()} -> {live}{prev}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fleet",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser(
        "report", help="observed-vs-predicted table + drift audit"
    )
    rp.add_argument(
        "--store", default=".",
        help="run store dir holding telemetry.json/decisions.json",
    )
    rp.add_argument("--telemetry", help="explicit telemetry file")
    rp.add_argument("--decisions", help="explicit decisions file")
    rp.add_argument(
        "--params", help="live params envelope the run priced with"
    )
    rp.add_argument(
        "--reference",
        help="trusted reference params envelope (enables the drift audit)",
    )
    rp.add_argument("--drift-report", help="write DriftReport JSON here")
    rp.add_argument(
        "--assert-no-drift", action="store_true",
        help="exit 1 when any decision drifted (CI gate)",
    )
    rp.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD)
    rp.add_argument("--min-samples", type=int, default=DEFAULT_MIN_SAMPLES)
    rp.add_argument("--system", default="", help="system label for the report")
    rp.set_defaults(fn=_cmd_report)

    sp = sub.add_parser(
        "stats", help="render a run's metrics.json counter snapshot"
    )
    sp.add_argument(
        "--store", default=".",
        help="run store dir holding metrics.json (and decisions.json)",
    )
    sp.add_argument("--metrics", help="explicit metrics file")
    sp.add_argument(
        "--json", action="store_true",
        help="also print the raw snapshot as JSON (machine-readable)",
    )
    sp.set_defaults(fn=_cmd_stats)

    dp = sub.add_parser("diff", help="canonical JSON diff of two bundles")
    dp.add_argument("a")
    dp.add_argument("b")
    dp.add_argument("--out", help="write the diff JSON here")
    dp.add_argument(
        "--assert-same", action="store_true",
        help="exit 1 when the bundles differ",
    )
    dp.set_defaults(fn=_cmd_diff)

    mp = sub.add_parser(
        "merge", help="deterministic merge of N bundles/decision files"
    )
    mp.add_argument("inputs", nargs="+")
    mp.add_argument("--out", required=True)
    mp.add_argument(
        "--policy", choices=CONFLICT_POLICIES, default="newest-generation"
    )
    mp.add_argument(
        "--generation", type=int,
        help="explicit output generation (default: max(input)+1)",
    )
    mp.add_argument("--host", default="", help="origin label for the merge")
    mp.set_defaults(fn=_cmd_merge)

    pp = sub.add_parser(
        "promote", help="install a bundle as the live decisions file"
    )
    pp.add_argument("bundle", nargs="?")
    pp.add_argument("--live", required=True, help="live decisions.json path")
    pp.add_argument(
        "--rollback", action="store_true",
        help="restore the .prev backup instead of promoting",
    )
    pp.set_defaults(fn=_cmd_promote)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
