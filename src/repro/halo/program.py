"""HaloProgram: communication-avoiding deep-halo stencil schedules.

TEMPI's discipline is that an interposed layer with empirical system
measurements should restructure non-contiguous communication wherever
the model says it wins.  The one-exchange-per-step halo loop leaves the
biggest knob untouched: *how often* to exchange.  A
:class:`HaloProgram` compiles the alternative — exchange a halo of
depth ``s * r`` ONCE, then apply ``s`` stencil steps locally over a
shrinking valid region (:func:`repro.halo.stencil.stencil_steps`) — and
lets :meth:`repro.comm.perfmodel.PerfModel.price_program` choose ``s``
from the same measured wire/copy tables every other strategy selection
uses: deeper halos buy fewer collective launches and amortized wire
latency at the price of more wire bytes per exchange and redundant
ghost-shell compute.  Nothing is heuristic; the chosen depth is recorded
in the :class:`~repro.measure.decisions.DecisionCache` like any other
strategy selection, so ``--halo-steps auto`` is reproducible (pinned)
across runs and auditable in the decisions file.

Lifecycle (all host-side, paid once):

```
op + grid + interior ──▶ candidate depths s=1..max ──▶ price_program
       │                        (deep HaloSpec,            │
       │                         deep-halo WirePlan)       ▼
       └────────────── pinned? ◀── DecisionCache ◀── argmin per-step
                                                        cost
```

then per iteration: ONE fused exchange (the depth-``s*r`` region types
are just bigger canonical strided blocks — the ragged wire path at new
sizes) + ``s`` shrinking-region applications, bit-exact on the interior
against the step-per-exchange reference.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm.api import as_communicator
from repro.comm.perfmodel import ProgramEstimate
from repro.core.datatypes import FLOAT, Named
from repro.halo.exchange import HaloPlan, HaloSpec, halo_exchange, make_halo_plan
from repro.halo.stencil import (
    STENCIL26,
    StencilOp,
    overlapped_stencil_iteration,
    stencil_steps,
)

__all__ = [
    "HaloProgram",
    "build_halo_program",
    "make_program_step",
    "program_fingerprint",
    "parse_halo_steps",
    "get_default_halo_steps",
    "set_default_halo_steps",
    "MAX_AUTO_STEPS",
]

#: deepest fusion the auto chooser considers (bounded: past a few steps
#: the ghost shells dominate any realistic wire saving)
MAX_AUTO_STEPS = 3

#: process default for ``steps=None`` — what ``--halo-steps`` on the
#: launch drivers configures for every program the job builds
_DEFAULT_HALO_STEPS: Union[int, str] = "auto"


def parse_halo_steps(value: Union[str, int]) -> Union[int, str]:
    """CLI value of ``--halo-steps``: ``"auto"`` or a positive int."""
    if value == "auto":
        return "auto"
    steps = int(value)
    if steps < 1:
        raise ValueError(f"--halo-steps must be >= 1 or 'auto', got {value!r}")
    return steps


def get_default_halo_steps() -> Union[int, str]:
    return _DEFAULT_HALO_STEPS


def set_default_halo_steps(steps: Union[int, str]) -> Union[int, str]:
    """Set the process-wide default fusion depth (the launch drivers'
    ``--halo-steps`` lands here; programs built with ``steps=None`` use
    it)."""
    global _DEFAULT_HALO_STEPS
    _DEFAULT_HALO_STEPS = parse_halo_steps(steps)
    return _DEFAULT_HALO_STEPS


def program_fingerprint(
    grid: Tuple[int, int, int],
    interior: Tuple[int, int, int],
    op: StencilOp,
    element: Named,
) -> str:
    """Stable content hash of a program's geometry — the DecisionCache
    key that pins ``--halo-steps auto`` across processes (the analogue
    of ``CommittedType.fingerprint`` for per-type selections)."""
    key = (
        "haloprogram.v1",
        tuple(grid),
        tuple(interior),
        tuple(op.radii),
        float(op.weight),
        element.name,
        element.size,
    )
    return hashlib.sha256(repr(key).encode()).hexdigest()[:16]


@dataclass(frozen=True)
class HaloProgram:
    """A compiled deep-halo schedule: {exchange at depth ``steps * r``,
    apply steps ``1..steps`` over the shrinking valid region}.

    Build with :func:`build_halo_program`; every per-iteration cost after
    that is device compute plus the prebuilt :class:`HaloPlan`'s
    dictionary lookups.
    """

    spec: HaloSpec              # deep geometry: radius == steps * op.radii
    op: StencilOp
    steps: int
    plan: HaloPlan              # the one exchange, at the deep radius
    estimate: ProgramEstimate   # model price that selected (or priced) steps
    candidates: Tuple[ProgramEstimate, ...] = ()  # every depth priced
    pinned: bool = False        # steps came from a pinned Decision

    @property
    def exchanges_per_step(self) -> float:
        """Exchange collectives issued per stencil application — the
        communication-avoidance figure the CI gate asserts (``1/s``)."""
        return 1.0 / self.steps

    @property
    def fingerprint(self) -> str:
        return program_fingerprint(
            self.spec.grid, self.spec.interior, self.op, self.spec.element
        )

    def iteration(
        self,
        local: jax.Array,
        comm,
        axis_name: str = "ranks",
        overlap: bool = False,
        probe: Optional[dict] = None,
    ) -> jax.Array:
        """One program iteration: ONE fused exchange + ``steps``
        shrinking-region stencil applications.  With ``overlap`` the
        wire op hides behind the steps-deep interior chain."""
        if overlap:
            return overlapped_stencil_iteration(
                local, self.spec, comm, axis_name,
                steps=self.steps, probe=probe, plan=self.plan, op=self.op,
            )
        local = halo_exchange(local, self.spec, comm, axis_name, plan=self.plan)
        return stencil_steps(local, self.spec, self.steps, self.op)


def _feasible_steps(
    interior: Tuple[int, int, int], op: StencilOp, max_steps: int
) -> List[int]:
    """Depths whose halo (= send-slab depth ``s * r``) still fits inside
    the interior in every dimension."""
    return [
        s
        for s in range(1, max_steps + 1)
        if all(s * r <= n for n, r in zip(interior, op.radii))
    ]


def _price_candidate(
    comm,
    grid: Tuple[int, int, int],
    interior: Tuple[int, int, int],
    op: StencilOp,
    steps: int,
    element: Named,
    schedule_policy: str,
) -> Tuple[HaloSpec, HaloPlan, ProgramEstimate]:
    """Build the deep geometry + wire plan for one candidate depth and
    price the full iteration: member pack/unpack + wire per exchange,
    redundant ghost-shell compute per fused step."""
    spec = HaloSpec(
        grid=grid, interior=interior, radius=op.halo_radii(steps),
        element=element,
    )
    plan = make_halo_plan(spec, comm, schedule_policy=schedule_policy)
    model = comm.model
    t_members = 0.0
    for ct, strat in zip(plan.send_cts, plan.strategies):
        est = model.estimate(ct, 1, strat)
        t_members += est.t_pack + est.t_unpack
    estimate = model.price_program(
        plan.wire,
        interior,
        op.radii,
        op.nneighbors,
        steps,
        element_bytes=element.size,
        t_members=t_members,
        axis=model.axis,
    )
    return spec, plan, estimate


def build_halo_program(
    grid: Tuple[int, int, int],
    interior: Tuple[int, int, int],
    comm,
    op: StencilOp = STENCIL26,
    steps: Union[int, str, None] = None,
    element: Named = FLOAT,
    max_steps: int = MAX_AUTO_STEPS,
    schedule_policy: str = "exact",
) -> HaloProgram:
    """Compile a deep-halo program for one rank geometry.

    ``steps`` is a fixed depth, ``"auto"`` (the model prices every
    feasible depth and takes the cheapest per stencil application), or
    ``None`` (the process default — ``--halo-steps`` on the launch
    drivers).  With ``"auto"`` and a communicator that carries a
    :class:`~repro.measure.decisions.DecisionCache`, the choice is
    looked up first and recorded after — reruns pin it, the audit log
    shows it, CI can assert it.
    """
    comm = as_communicator(comm)
    if steps is None:
        steps = get_default_halo_steps()
    fp = program_fingerprint(grid, interior, op, element)
    decisions = comm.model.decisions
    candidates: Tuple[ProgramEstimate, ...] = ()
    pinned = False
    built: Optional[Tuple[HaloSpec, HaloPlan, ProgramEstimate]] = None

    if steps == "auto":
        feasible = _feasible_steps(interior, op, max_steps)
        if not feasible:
            raise ValueError(
                f"no feasible fusion depth: interior {interior} cannot host "
                f"a depth-{op.radii} halo"
            )
        pin = decisions.lookup(fp, 0, 1, True) if decisions is not None else None
        if (
            pin is not None
            and pin.strategy.startswith("program/s=")
            # a pin recorded under a looser cap (or different geometry
            # assumptions) must not smuggle in a depth this caller's
            # max_steps/feasibility would refuse
            and int(pin.strategy.split("=", 1)[1]) in feasible
        ):
            steps = int(pin.strategy.split("=", 1)[1])
            pinned = True
        else:
            priced: Dict[int, Tuple[HaloSpec, HaloPlan, ProgramEstimate]] = {
                s: _price_candidate(
                    comm, grid, interior, op, s, element, schedule_policy
                )
                for s in feasible
            }
            candidates = tuple(priced[s][2] for s in feasible)
            steps = min(priced, key=lambda s: priced[s][2].per_step)
            built = priced[steps]
            if decisions is not None:
                from repro.comm.perfmodel import StrategyEstimate

                best = priced[steps][2]
                decisions.record(
                    fp, 0, 1, True,
                    StrategyEstimate(
                        f"program/s={steps}",
                        t_pack=best.t_redundant,
                        t_link=best.t_exchange,
                        t_unpack=0.0,
                        wire_bytes=best.wire_bytes,
                    ),
                    signature=(
                        f"halo program grid={tuple(grid)} "
                        f"interior={tuple(interior)} op={op.radii} "
                        + " ".join(
                            f"s={e.steps}:{e.per_step:.3e}" for e in candidates
                        )
                    ),
                )
    else:
        steps = parse_halo_steps(steps)
        if steps not in _feasible_steps(interior, op, steps):
            raise ValueError(
                f"interior {interior} cannot host a depth-"
                f"{op.halo_radii(steps)} halo (send slabs exceed the interior)"
            )

    if built is None:
        built = _price_candidate(
            comm, grid, interior, op, steps, element, schedule_policy
        )
    spec, plan, estimate = built
    return HaloProgram(
        spec=spec, op=op, steps=steps, plan=plan, estimate=estimate,
        candidates=candidates, pinned=pinned,
    )


def make_program_step(
    program: HaloProgram,
    comm,
    mesh: Mesh,
    axis_name: str = "ranks",
    overlap: bool = False,
):
    """jit-compiled shard_map wrapper over one program iteration:
    (nranks*az, ay, ax) global array, sharded on the leading axis ->
    one exchange + ``program.steps`` stencil applications."""
    comm = as_communicator(comm)

    def step(local):
        return program.iteration(local, comm, axis_name, overlap=overlap)

    fn = shard_map(
        step,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
        check_vma=False,
    )
    return jax.jit(fn)
