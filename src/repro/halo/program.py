"""HaloProgram: communication-avoiding deep-halo stencil schedules.

TEMPI's discipline is that an interposed layer with empirical system
measurements should restructure non-contiguous communication wherever
the model says it wins.  The one-exchange-per-step halo loop leaves the
biggest knob untouched: *how often* to exchange.  A
:class:`HaloProgram` compiles the alternative — exchange a halo of
depth ``s * r`` ONCE, then apply ``s`` stencil steps locally over a
shrinking valid region (:func:`repro.halo.stencil.stencil_steps`) — and
lets :meth:`repro.comm.perfmodel.PerfModel.price_program` choose ``s``
from the same measured wire/copy tables every other strategy selection
uses: deeper halos buy fewer collective launches and amortized wire
latency at the price of more wire bytes per exchange and redundant
ghost-shell compute.  Nothing is heuristic; the chosen depth is recorded
in the :class:`~repro.measure.decisions.DecisionCache` like any other
strategy selection, so ``--halo-steps auto`` is reproducible (pinned)
across runs and auditable in the decisions file.

Lifecycle (all host-side, paid once):

```
op + grid + interior ──▶ candidate depths s=1..max ──▶ price_program
       │                        (deep HaloSpec,            │
       │                         deep-halo WirePlan)       ▼
       └────────────── pinned? ◀── DecisionCache ◀── argmin per-step
                                                        cost
```

then per iteration: ONE fused exchange (the depth-``s*r`` region types
are just bigger canonical strided blocks — the ragged wire path at new
sizes) + ``s`` shrinking-region applications, bit-exact on the interior
against the step-per-exchange reference.

Programs also fuse heterogeneous *cycles*: ``build_halo_program(ops=
[op_a, op_b], steps=s)`` exchanges ONE halo of depth
``s * cycle_radii([op_a, op_b])`` (the per-op radii summed, per
dimension) and applies the cycle ``s`` times over the per-application
shrinking valid region — the predictor/corrector and smoother patterns
that dominate real stencil codes ride the same mechanism, priced per
application by the same model.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from functools import cached_property
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm.api import as_communicator
from repro.comm.perfmodel import ProgramEstimate
from repro.core.datatypes import FLOAT, Named
from repro.halo.exchange import HaloPlan, HaloSpec, halo_exchange, make_halo_plan
from repro.halo.stencil import (
    STENCIL26,
    Ops,
    StencilOp,
    as_ops,
    cycle_halo_radii,
    cycle_radii,
    overlapped_stencil_iteration,
    stencil_cycle,
)

__all__ = [
    "HaloProgram",
    "build_halo_program",
    "make_program_step",
    "program_fingerprint",
    "parse_halo_steps",
    "get_default_halo_steps",
    "set_default_halo_steps",
    "MAX_AUTO_STEPS",
]

#: deepest fusion the auto chooser considers (bounded: past a few steps
#: the ghost shells dominate any realistic wire saving)
MAX_AUTO_STEPS = 3

#: process default for ``steps=None`` — what ``--halo-steps`` on the
#: launch drivers configures for every program the job builds
_DEFAULT_HALO_STEPS: Union[int, str] = "auto"


def parse_halo_steps(value: Union[str, int]) -> Union[int, str]:
    """CLI value of ``--halo-steps``: ``"auto"`` or a positive int."""
    if value == "auto":
        return "auto"
    steps = int(value)
    if steps < 1:
        raise ValueError(f"--halo-steps must be >= 1 or 'auto', got {value!r}")
    return steps


def get_default_halo_steps() -> Union[int, str]:
    return _DEFAULT_HALO_STEPS


def set_default_halo_steps(steps: Union[int, str]) -> Union[int, str]:
    """Set the process-wide default fusion depth (the launch drivers'
    ``--halo-steps`` lands here; programs built with ``steps=None`` use
    it)."""
    global _DEFAULT_HALO_STEPS
    _DEFAULT_HALO_STEPS = parse_halo_steps(steps)
    return _DEFAULT_HALO_STEPS


def program_fingerprint(
    grid: Tuple[int, int, int],
    interior: Tuple[int, int, int],
    op: Ops,
    element: Named,
    topology_fingerprint: str = "",
) -> str:
    """Stable content hash of a program's geometry — the DecisionCache
    key that pins ``--halo-steps auto`` across processes (the analogue
    of ``CommittedType.fingerprint`` for per-type selections).

    ``op`` is one :class:`StencilOp` or a cycle of them.  Single-op
    programs keep the original (v1) key so decision files recorded
    before cycles existed still pin; a cycle hashes every op in
    application order under a v2 key (``[a, b] != [b, a]`` — the
    shrinking-region schedule is order-sensitive).

    ``topology_fingerprint`` (a :attr:`repro.comm.topology.Topology.
    fingerprint`) is appended to the key only when non-empty, so pins
    recorded without a topology keep their keys — but a ``program/s=N``
    pinned on a 2x2x2 mesh can never be replayed on a reshaped mesh: a
    different topology is a different fingerprint, which is a decision
    cache *miss*.
    """
    ops = as_ops(op)
    if len(ops) == 1:
        key = (
            "haloprogram.v1",
            tuple(grid),
            tuple(interior),
            tuple(ops[0].radii),
            float(ops[0].weight),
            element.name,
            element.size,
        )
    else:
        key = (
            "haloprogram.v2",
            tuple(grid),
            tuple(interior),
            tuple((tuple(o.radii), float(o.weight)) for o in ops),
            element.name,
            element.size,
        )
    if topology_fingerprint:
        key = key + (topology_fingerprint,)
    return hashlib.sha256(repr(key).encode()).hexdigest()[:16]


def _describe_cycle(ops: Tuple[StencilOp, ...]) -> str:
    """Short human-readable cycle signature for the audit log."""
    return "[" + ",".join(
        f"{'x'.join(map(str, o.radii))}w{o.weight:g}" for o in ops
    ) + "]"


@dataclass(frozen=True)
class HaloProgram:
    """A compiled deep-halo schedule: {exchange at depth
    ``steps * cycle_radii(ops)``, apply the op cycle ``steps`` times
    over the shrinking valid region}.

    ``ops`` is the heterogeneous cycle applied in order each repeat —
    ``(STENCIL26,)`` is the classic single-op program, a
    predictor/corrector pair is ``(op_a, op_b)``.  Build with
    :func:`build_halo_program`; every per-iteration cost after that is
    device compute plus the prebuilt :class:`HaloPlan`'s dictionary
    lookups.
    """

    spec: HaloSpec              # deep geometry: radius == steps * cycle_radii
    ops: Tuple[StencilOp, ...]
    steps: int                  # cycle repeats per iteration
    plan: HaloPlan              # the one exchange, at the deep radius
    estimate: ProgramEstimate   # model price that selected (or priced) steps
    candidates: Tuple[ProgramEstimate, ...] = ()  # every depth priced
    pinned: bool = False        # steps came from a pinned Decision
    #: topology fingerprint the program was planned under ("" = flat);
    #: part of the decision key so mesh reshapes never replay this pin
    topology_fingerprint: str = ""

    @property
    def op(self) -> StencilOp:
        """The single op of a one-op cycle (raises on real cycles — a
        heterogeneous program has no 'the' op)."""
        if len(self.ops) != 1:
            raise ValueError(
                f"program fuses a {len(self.ops)}-op cycle; inspect .ops"
            )
        return self.ops[0]

    @property
    def cycle_len(self) -> int:
        return len(self.ops)

    @property
    def applications(self) -> int:
        """Stencil applications per iteration (``steps * cycle_len``)."""
        return self.steps * len(self.ops)

    @property
    def exchanges_per_step(self) -> float:
        """Exchange collectives issued per stencil application — the
        communication-avoidance figure the CI gate asserts."""
        return 1.0 / self.applications

    @property
    def exchanges_per_cycle(self) -> float:
        """Exchange collectives issued per cycle repeat (``1/steps``) —
        the cycle-mode CI gate asserts this is ``<= 1``."""
        return 1.0 / self.steps

    @cached_property
    def fingerprint(self) -> str:
        # content hash over frozen fields; cached because the tracer's
        # per-iteration hook reads it on the launch hot loop
        return program_fingerprint(
            self.spec.grid, self.spec.interior, self.ops, self.spec.element,
            self.topology_fingerprint,
        )

    def iteration(
        self,
        local: jax.Array,
        comm,
        axis_name: str = "ranks",
        overlap=False,
        probe: Optional[dict] = None,
    ) -> jax.Array:
        """One program iteration: ONE fused exchange + ``steps`` repeats
        of the shrinking-region op cycle.  With ``overlap`` the wire op
        hides behind the steps-deep interior chain: ``True`` (or
        ``"monolithic"``) waits for the whole fused collective,
        ``"region"`` drains per-delta-class requests and computes each
        core/face/edge/corner region as its classes land, ``"auto"``
        lets the model pick (pinned as ``overlap/mode=...`` — see
        :func:`repro.halo.stencil.overlapped_stencil_iteration`).

        When the communicator carries a :class:`repro.obs.Tracer` and
        the call is eager (no jax trace, no tracer operands), the
        iteration records the full span hierarchy: ``program_iteration``
        hosting the fused ``exchange`` (with its pack/wire/unpack
        phases, via :meth:`Communicator.neighbor_alltoallv`) and one
        ``stencil`` span per application — each phase blocked at its
        boundary.  Jitted runs skip this entirely (the launch layer
        attributes compiled iterations instead)."""
        if overlap:
            mode = "monolithic" if overlap is True else str(overlap)
            return overlapped_stencil_iteration(
                local, self.spec, comm, axis_name,
                steps=self.steps, probe=probe, plan=self.plan, op=self.ops,
                mode=mode,
            )
        comm = as_communicator(comm)
        tracer = getattr(comm, "tracer", None)
        if (
            tracer is not None
            and tracer.active
            and not isinstance(local, jax.core.Tracer)
        ):
            return self._traced_iteration(local, comm, axis_name, tracer)
        local = halo_exchange(local, self.spec, comm, axis_name, plan=self.plan)
        return stencil_cycle(local, self.spec, self.ops, self.steps)

    def _traced_iteration(
        self, local: jax.Array, comm, axis_name: str, tracer
    ) -> jax.Array:
        """Eager iteration under the tracer: spans per phase, blocking
        at each boundary (a debug/observation path — the hot path is the
        jitted ``make_program_step``)."""
        from repro.fleet.telemetry import predict_program_phases
        from repro.halo.stencil import op_sequence, stencil_apply

        try:
            phases = predict_program_phases(self, comm.model)
        except Exception:
            phases = {}
        napp = max(self.applications, 1)
        with tracer.span(
            "program_iteration",
            fingerprint=self.fingerprint,
            strategy=f"program/s={self.steps}",
            steps=self.steps, cycle_len=self.cycle_len,
            pinned=bool(self.pinned),
            pred=sum(phases.values()),
        ):
            # the fused exchange span (and its pack/wire/unpack
            # children) is recorded by the blocking Communicator path
            local = comm.neighbor_alltoallv(
                local, self.plan.send_cts, self.plan.recv_cts,
                self.plan.perms, axis_name, plan=self.plan.wire,
                strategies=self.plan.strategies,
            )
            valid = self.spec.radii
            pred_app = phases.get("stencil", 0.0) / napp
            for i, o in enumerate(op_sequence(self.ops, self.steps)):
                with tracer.span(
                    "stencil", application=i, op=i % self.cycle_len,
                    pred=pred_app,
                ):
                    local = stencil_apply(local, self.spec, valid, o)
                    jax.block_until_ready(local)
                valid = tuple(v - r for v, r in zip(valid, o.radii))
        return local


def _feasible_steps(
    interior: Tuple[int, int, int], ops: Tuple[StencilOp, ...], max_steps: int
) -> List[int]:
    """Repeat counts whose halo (= send-slab depth ``s * cycle_radii``)
    still fits inside the interior in every dimension."""
    cr = cycle_radii(ops)
    return [
        s
        for s in range(1, max_steps + 1)
        if all(s * r <= n for n, r in zip(interior, cr))
    ]


def _price_candidate(
    comm,
    grid: Tuple[int, int, int],
    interior: Tuple[int, int, int],
    ops: Tuple[StencilOp, ...],
    steps: int,
    element: Named,
    schedule_policy: Optional[str],
) -> Tuple[HaloSpec, HaloPlan, ProgramEstimate]:
    """Build the deep geometry + wire plan for one candidate repeat
    count and price the full iteration: member pack/unpack + wire per
    exchange, redundant ghost-shell compute per fused application."""
    spec = HaloSpec(
        grid=grid, interior=interior,
        radius=cycle_halo_radii(ops, steps),
        element=element,
    )
    plan = make_halo_plan(spec, comm, schedule_policy=schedule_policy)
    model = comm.model
    t_members = 0.0
    for ct, strat in zip(plan.send_cts, plan.strategies):
        est = model.estimate(ct, 1, strat)
        t_members += est.t_pack + est.t_unpack
    estimate = model.price_program(
        plan.wire,
        interior,
        [o.radii for o in ops],
        [o.nneighbors for o in ops],
        steps,
        element_bytes=element.size,
        t_members=t_members,
        axis=model.axis,
    )
    return spec, plan, estimate


def build_halo_program(
    grid: Tuple[int, int, int],
    interior: Tuple[int, int, int],
    comm,
    op: StencilOp = STENCIL26,
    steps: Union[int, str, None] = None,
    element: Named = FLOAT,
    max_steps: int = MAX_AUTO_STEPS,
    schedule_policy: Optional[str] = None,
    ops: Optional[Sequence[StencilOp]] = None,
) -> HaloProgram:
    """Compile a deep-halo program for one rank geometry.

    ``ops`` fuses a heterogeneous *cycle* ``[op_1..op_k]`` applied in
    order each repeat (``op`` is the single-op shorthand and is ignored
    when ``ops`` is given).  One exchange at halo depth
    ``steps * cycle_radii(ops)`` then hosts ``steps`` whole cycle
    passes.

    ``steps`` counts cycle repeats: a fixed count, ``"auto"`` (the model
    prices every feasible count and takes the cheapest per stencil
    application), or ``None`` (the process default — ``--halo-steps`` on
    the launch drivers).  With ``"auto"`` and a communicator that
    carries a :class:`~repro.measure.decisions.DecisionCache`, the
    choice is looked up first and recorded after — reruns pin it, the
    audit log shows it, CI can assert it.

    ``schedule_policy`` is forwarded to the wire planner (``None`` =
    the communicator's default — model-priced; pass ``"exact"`` for the
    byte-exact ladder the wire-bytes gates assert).
    """
    comm = as_communicator(comm)
    ops = as_ops(ops if ops is not None else op)
    if steps is None:
        steps = get_default_halo_steps()
    topo = getattr(comm.model, "topology", None)
    topo_fp = topo.fingerprint if topo is not None else ""
    fp = program_fingerprint(grid, interior, ops, element, topo_fp)
    decisions = comm.model.decisions
    candidates: Tuple[ProgramEstimate, ...] = ()
    pinned = False
    built: Optional[Tuple[HaloSpec, HaloPlan, ProgramEstimate]] = None

    if steps == "auto":
        feasible = _feasible_steps(interior, ops, max_steps)
        if not feasible:
            raise ValueError(
                f"no feasible fusion depth: interior {interior} cannot host "
                f"a depth-{cycle_radii(ops)} halo"
            )
        pin = decisions.lookup(fp, 0, 1, True) if decisions is not None else None
        if (
            pin is not None
            and pin.strategy.startswith("program/s=")
            # a pin recorded under a looser cap (or different geometry
            # assumptions) must not smuggle in a depth this caller's
            # max_steps/feasibility would refuse
            and int(pin.strategy.split("=", 1)[1]) in feasible
        ):
            steps = int(pin.strategy.split("=", 1)[1])
            pinned = True
        else:
            priced: Dict[int, Tuple[HaloSpec, HaloPlan, ProgramEstimate]] = {
                s: _price_candidate(
                    comm, grid, interior, ops, s, element, schedule_policy
                )
                for s in feasible
            }
            candidates = tuple(priced[s][2] for s in feasible)
            steps = min(priced, key=lambda s: priced[s][2].per_step)
            built = priced[steps]
            if decisions is not None:
                from repro.comm.perfmodel import StrategyEstimate

                best = priced[steps][2]
                decisions.record(
                    fp, 0, 1, True,
                    StrategyEstimate(
                        f"program/s={steps}",
                        t_pack=best.t_redundant,
                        t_link=best.t_exchange,
                        t_unpack=0.0,
                        wire_bytes=best.wire_bytes,
                    ),
                    signature=(
                        f"halo program grid={tuple(grid)} "
                        f"interior={tuple(interior)} "
                        f"cycle={_describe_cycle(ops)} "
                        + (f"topo={topo_fp} " if topo_fp else "")
                        + " ".join(
                            f"s={e.steps}:{e.per_step:.3e}" for e in candidates
                        )
                    ),
                )
    else:
        steps = parse_halo_steps(steps)
        if steps not in _feasible_steps(interior, ops, steps):
            raise ValueError(
                f"interior {interior} cannot host a depth-"
                f"{cycle_halo_radii(ops, steps)} halo "
                "(send slabs exceed the interior)"
            )

    if built is None:
        built = _price_candidate(
            comm, grid, interior, ops, steps, element, schedule_policy
        )
    spec, plan, estimate = built
    return HaloProgram(
        spec=spec, ops=ops, steps=steps, plan=plan, estimate=estimate,
        candidates=candidates, pinned=pinned, topology_fingerprint=topo_fp,
    )


def make_program_step(
    program: HaloProgram,
    comm,
    mesh: Mesh,
    axis_name: str = "ranks",
    overlap=False,
):
    """jit-compiled shard_map wrapper over one program iteration:
    (nranks*az, ay, ax) global array, sharded on the leading axis ->
    one exchange + ``program.steps`` stencil applications.  ``overlap``
    is a bool or an overlap-mode string (``"monolithic"``/``"region"``/
    ``"auto"``), forwarded to :meth:`HaloProgram.iteration`."""
    comm = as_communicator(comm)

    def step(local):
        return program.iteration(local, comm, axis_name, overlap=overlap)

    fn = shard_map(
        step,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
        check_vma=False,
    )
    return jax.jit(fn)
