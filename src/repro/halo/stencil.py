"""Per-dimension-radius stencil kernels with shrinking-region deep-halo
application (paper §6.4: "standard 26 point" stencil, radius-2 halos,
periodic boundaries, 4-byte gridpoints).

A :class:`StencilOp` describes one weighted box-neighborhood update with
*per-dimension* radii ``(rz, ry, rx)`` — the paper's 26-point stencil is
``StencilOp((1, 1, 1))``; a train-style workload that smooths deeper
along the slow axis is ``StencilOp((2, 1, 1))``.  Nothing here requires
a symmetric radius any more (the old ``HaloSpec.scalar_radius`` guard is
gone): the halo radii, the stencil radii, and the valid-region
bookkeeping are all per-dimension tuples.

Deep halos trade wire for redundant compute: after one exchange at halo
depth ``valid``, each application of a radius-``r`` op leaves a region
deeper by ``r`` invalid, so :func:`stencil_apply` computes exactly the
still-valid window — interior plus a shell of ``valid - r`` — and
:func:`stencil_steps` walks ``valid`` down step by step.  With halo
depth ``s * r`` that amortizes ONE exchange over ``s`` applications,
bit-exact against the step-per-exchange reference on the interior
(ghost-shell cells are recomputed redundantly; that redundancy is what
:meth:`repro.comm.perfmodel.PerfModel.price_program` prices against the
saved wire time).  :class:`repro.halo.program.HaloProgram` compiles the
whole schedule.

Ops also compose into *cycles*: a heterogeneous sequence
``[op_1..op_k]`` (a predictor/corrector pair, a smoother sweep) applied
in order and repeated.  One cycle pass consumes :func:`cycle_radii` of
valid halo per dimension — the per-op radii summed — so a halo of depth
``repeats * cycle_radii`` hosts ``repeats`` whole cycles on ONE
exchange (:func:`stencil_cycle`); every helper here accepts either a
single :class:`StencilOp` or a sequence of them.

All window arithmetic goes through the shared
:func:`repro.kernels.ops.stencil_window_update` /
:func:`~repro.kernels.ops.stencil_window_chain` primitives, so the
full-allocation path, the shrinking-region path, and the dense interior
chain of the overlap pipeline accumulate in the same order — which is
what makes their overlapping regions bit-identical and the overlap
splice legal.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.halo.exchange import HaloPlan, HaloSpec, ihalo_exchange
from repro.kernels.ops import stencil_window_chain, stencil_window_update

__all__ = [
    "StencilOp",
    "STENCIL26",
    "as_ops",
    "cycle_halo_radii",
    "cycle_radii",
    "op_sequence",
    "stencil_apply",
    "stencil_steps",
    "stencil_cycle",
    "stencil_interior_chain",
    "max_pipeline_depth",
    "stencil26",
    "stencil26_interior",
    "stencil_iterations",
    "overlapped_stencil_iteration",
]

#: one op or a heterogeneous cycle of them — every consumer normalizes
#: through :func:`as_ops`
Ops = Union["StencilOp", Sequence["StencilOp"]]


@dataclass(frozen=True)
class StencilOp:
    """One weighted box-neighborhood update with per-dimension radii.

    ``new[i] = (1-w) * u[i] + w/N * sum over the N offsets d of u[i+d]``
    where the offsets are every nonzero point of the
    ``[-rz..rz] x [-ry..ry] x [-rx..rx]`` box.
    """

    radii: Tuple[int, int, int] = (1, 1, 1)
    weight: float = 0.4

    def __post_init__(self):
        r = tuple(int(x) for x in self.radii)
        if len(r) != 3 or any(x < 1 for x in r):
            raise ValueError(f"stencil radii must be 3 positive ints, got {r}")
        object.__setattr__(self, "radii", r)

    @property
    def offsets(self) -> Tuple[Tuple[int, int, int], ...]:
        """All nonzero neighbor offsets, in a deterministic order (the
        accumulation order — part of the bit-exactness contract)."""
        rz, ry, rx = self.radii
        return tuple(
            d
            for d in itertools.product(
                range(-rz, rz + 1), range(-ry, ry + 1), range(-rx, rx + 1)
            )
            if d != (0, 0, 0)
        )

    @property
    def nneighbors(self) -> int:
        rz, ry, rx = self.radii
        return (2 * rz + 1) * (2 * ry + 1) * (2 * rx + 1) - 1

    def halo_radii(self, steps: int) -> Tuple[int, int, int]:
        """Per-dimension halo depth that lets ``steps`` applications run
        on one exchange."""
        return tuple(steps * r for r in self.radii)


#: the paper's 26-point stencil (radius 1 in every dimension)
STENCIL26 = StencilOp((1, 1, 1))


def as_ops(op: Ops) -> Tuple[StencilOp, ...]:
    """Normalize one op or an op sequence into a nonempty cycle tuple."""
    ops = (op,) if isinstance(op, StencilOp) else tuple(op)
    if not ops or not all(isinstance(o, StencilOp) for o in ops):
        raise ValueError(f"expected a StencilOp or a nonempty sequence, got {op!r}")
    return ops


def cycle_radii(op: Ops) -> Tuple[int, int, int]:
    """Per-dimension valid-halo depth ONE cycle pass consumes — the
    per-op radii summed in application order."""
    ops = as_ops(op)
    return tuple(sum(o.radii[d] for o in ops) for d in range(3))


def cycle_halo_radii(op: Ops, repeats: int) -> Tuple[int, int, int]:
    """Per-dimension halo depth that hosts ``repeats`` whole cycle
    passes on one exchange (the cycle analogue of
    :meth:`StencilOp.halo_radii`)."""
    return tuple(repeats * r for r in cycle_radii(op))


def op_sequence(op: Ops, repeats: int) -> Tuple[StencilOp, ...]:
    """The flattened application schedule: the cycle repeated
    ``repeats`` times (``repeats * len(ops)`` applications)."""
    if repeats < 1:
        raise ValueError(f"cycle repeats must be >= 1, got {repeats}")
    return as_ops(op) * repeats


def _as_radii(valid, spec: HaloSpec) -> Tuple[int, int, int]:
    if valid is None:
        return spec.radii
    if isinstance(valid, int):
        return (valid, valid, valid)
    return tuple(valid)


def stencil_apply(
    local: jax.Array,
    spec: HaloSpec,
    valid=None,
    op: StencilOp = STENCIL26,
) -> jax.Array:
    """One stencil application over the still-valid window.

    ``valid`` is the per-dimension halo depth whose cells currently hold
    correct values (defaults to the full ``spec.radii`` — i.e. "the
    exchange just ran").  The update writes interior plus a shell of
    ``valid - op.radii`` — exactly the cells whose whole neighborhood is
    valid — so after the call the valid depth has shrunk by ``op.radii``.
    """
    valid = _as_radii(valid, spec)
    radii = spec.radii
    for v, r, hr in zip(valid, op.radii, radii):
        if v < r:
            raise ValueError(
                f"valid halo depth {valid} is shallower than the stencil "
                f"radii {op.radii}; exchange first"
            )
        if v > hr:
            raise ValueError(f"valid depth {valid} exceeds halo radii {radii}")
    shell = tuple(v - r for v, r in zip(valid, op.radii))
    origin = tuple(hr - s for hr, s in zip(radii, shell))
    shape = tuple(n + 2 * s for n, s in zip(spec.interior, shell))
    updated = stencil_window_update(local, op.offsets, op.weight, origin, shape)
    return jax.lax.dynamic_update_slice(local, updated, origin)


def stencil_cycle(
    local: jax.Array,
    spec: HaloSpec,
    op: Ops,
    repeats: int = 1,
    valid=None,
) -> jax.Array:
    """``repeats`` passes of a (possibly heterogeneous) op cycle on one
    exchange, the valid region shrinking by each op's radii per
    application (valid until the halo depth is exhausted:
    ``repeats * cycle_radii(op) <= valid``)."""
    valid = _as_radii(valid, spec)
    need = cycle_halo_radii(op, repeats)
    if any(n > v for n, v in zip(need, valid)):
        raise ValueError(
            f"{repeats} repeats of cycle radii {cycle_radii(op)} exhaust "
            f"the valid halo depth {valid}"
        )
    for o in op_sequence(op, repeats):
        local = stencil_apply(local, spec, valid, o)
        valid = tuple(v - r for v, r in zip(valid, o.radii))
    return local


def stencil_steps(
    local: jax.Array,
    spec: HaloSpec,
    steps: int,
    op: StencilOp = STENCIL26,
    valid=None,
) -> jax.Array:
    """``steps`` applications of ONE op on one exchange (the single-op
    cycle — see :func:`stencil_cycle` for heterogeneous cycles)."""
    return stencil_cycle(local, spec, (op,), steps, valid)


def _cum_shrink(op: Ops, applications: int) -> List[Tuple[int, int, int]]:
    """Cumulative per-dimension shrink after each of the first
    ``applications`` applications of the repeating cycle."""
    cum = (0, 0, 0)
    out = []
    for o in itertools.islice(itertools.cycle(as_ops(op)), applications):
        cum = tuple(c + r for c, r in zip(cum, o.radii))
        out.append(cum)
    return out


def max_pipeline_depth(spec: HaloSpec, op: Ops, steps: int) -> int:
    """How many of the ``steps * len(ops)`` fused applications have a
    nonempty deep interior (every dim must keep >= 1 cell after the
    cumulative shrink from each side) — the depth
    :func:`stencil_interior_chain` can precompute while the exchange is
    on the wire.  ``steps`` counts cycle repeats."""
    ops = as_ops(op)
    depth = 0
    for k, cum in enumerate(_cum_shrink(ops, steps * len(ops)), 1):
        if any(n - 2 * c < 1 for n, c in zip(spec.interior, cum)):
            break
        depth = k
    return depth


def stencil_interior_chain(
    local: jax.Array,
    spec: HaloSpec,
    depth: int,
    op: Ops = STENCIL26,
) -> List[jax.Array]:
    """Steps-deep pipelining: applications ``1..depth`` of the repeating
    op cycle, restricted to the cells that need NO halo data at all.

    Block ``k`` (1-indexed) holds the application-``k`` values of the
    interior shrunk by the cycle's cumulative radii per side —
    computable from ``local``'s interior alone, before any exchange
    completes.  Because a halo exchange only *writes* halo shells, each
    block is bit-identical to the same region of the post-exchange
    application (same primitive, same accumulation order), which is what
    makes it legal to splice the chain into the real iteration while the
    wire op is still in flight.
    """
    x = jax.lax.dynamic_slice(local, spec.radii, spec.interior)
    seq = list(itertools.islice(itertools.cycle(as_ops(op)), depth))
    try:
        return stencil_window_chain(
            x, [(o.offsets, o.weight, o.radii) for o in seq]
        )
    except ValueError as e:
        raise ValueError(
            f"interior {spec.interior} too small for a depth-{depth} "
            f"chain of the cycle {[o.radii for o in as_ops(op)]}: {e}"
        ) from None


# ---------------------------------------------------------------------------
# legacy 26-point entry points (kept as thin wrappers over the per-dim API)
# ---------------------------------------------------------------------------

def stencil26(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """One 26-point update of the still-valid window (halos current)."""
    return stencil_apply(local, spec, op=STENCIL26)


def stencil26_interior(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """First-application update of the deep interior (no halo reads);
    returns the ``interior - 2`` block at origin ``radii + 1``."""
    return stencil_interior_chain(local, spec, 1, STENCIL26)[0]


def stencil_iterations(local: jax.Array, spec: HaloSpec, steps: int) -> jax.Array:
    """``steps`` 26-point applications on one exchange (shrinking valid
    region)."""
    return stencil_steps(local, spec, steps, STENCIL26)


# ---------------------------------------------------------------------------
# overlap: the exchange hidden behind the interior chain
# ---------------------------------------------------------------------------

def overlapped_stencil_iteration(
    local: jax.Array,
    spec: HaloSpec,
    comm,
    axis_name: str = "ranks",
    types=None,
    steps: int = 2,
    probe: Optional[dict] = None,
    plan: Optional[HaloPlan] = None,
    op: Ops = STENCIL26,
) -> jax.Array:
    """One exchange + ``steps`` cycle repeats with the wire hidden behind
    steps-deep interior pipelining.

    ``op`` is one op or a heterogeneous cycle; ``steps`` counts cycle
    repeats (``steps * len(ops)`` applications total).  The fused
    collective is issued immediately (:func:`ihalo_exchange`); while it
    is in flight the :func:`stencil_interior_chain` precomputes every
    fused application's deep interior — not just the first one — so XLA
    sees ``depth + 1`` independent dataflows (collective ∥ chain) it is
    free to overlap.  After ``wait()`` the real shrinking-region
    applications run and each chain block is spliced over its (bit-
    identical) region, keeping the early compute live in the graph
    without changing the result.  Bit-identical to ``halo_exchange`` +
    ``stencil_cycle``.

    ``probe``, when given, records ``pending_during_interior`` (the wire
    op was still pending when the chain was built — the overlap
    invariant) and ``pipeline_depth`` (how many applications had a
    nonempty deep interior to precompute).
    """
    ops = as_ops(op)
    if any(n > v for n, v in zip(cycle_halo_radii(ops, steps), spec.radii)):
        raise ValueError(
            f"halo radii {spec.radii} cannot host {steps} repeats of "
            f"cycle radii {cycle_radii(ops)}"
        )
    depth = max_pipeline_depth(spec, ops, steps)
    req = ihalo_exchange(local, spec, comm, axis_name, types, plan)  # wire NOW
    chain = stencil_interior_chain(local, spec, depth, ops)  # overlaps the wire
    if probe is not None:
        probe["pending_during_interior"] = not req.completed
        probe["pipeline_depth"] = depth
    full = req.wait()
    valid = spec.radii
    seq = op_sequence(ops, steps)
    shrink = _cum_shrink(ops, len(seq))
    for k, o in enumerate(seq, 1):
        full = stencil_apply(full, spec, valid, o)
        valid = tuple(v - r for v, r in zip(valid, o.radii))
        if k <= depth:
            origin = tuple(hr + c for hr, c in zip(spec.radii, shrink[k - 1]))
            full = jax.lax.dynamic_update_slice(full, chain[k - 1], origin)
    return full
