"""26-point 3D stencil update (paper §6.4: "standard 26 point" stencil,
radius-2 halos, periodic boundaries, 4-byte gridpoints).

The radius-2 halo lets each exchange amortize over two local stencil
applications (a standard deep-halo optimization; it keeps the
exchange:compute ratio of the paper's setup).
"""

from __future__ import annotations

import itertools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.halo.exchange import HaloPlan, HaloSpec, ihalo_exchange

__all__ = [
    "stencil26",
    "stencil26_interior",
    "stencil_iterations",
    "overlapped_stencil_iteration",
]

_NEIGHBORS = tuple(
    d for d in itertools.product((-1, 0, 1), repeat=3) if d != (0, 0, 0)
)


def stencil26(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """One 26-point update of the interior; halos must be current.

    new[i] = (1-w)*u[i] + w/26 * sum_{26 neighbors} u[i+d]
    """
    r = spec.scalar_radius
    nz, ny, nx = spec.interior
    w = jnp.float32(0.4)
    acc = jnp.zeros((nz + 2 * (r - 1), ny + 2 * (r - 1), nx + 2 * (r - 1)),
                    local.dtype)
    # shifted views of the (interior + 1-cell shell) region
    for dz, dy, dx in _NEIGHBORS:
        acc = acc + jax.lax.dynamic_slice(
            local,
            (r - 1 + dz + 0, r - 1 + dy + 0, r - 1 + dx + 0),
            acc.shape,
        )
    center = jax.lax.dynamic_slice(local, (r - 1, r - 1, r - 1), acc.shape)
    new_inner = (1 - w) * center + (w / 26.0) * acc
    # write back the updated (interior + shell(r-1)) region
    return jax.lax.dynamic_update_slice(local, new_inner, (r - 1, r - 1, r - 1))


def stencil_iterations(local: jax.Array, spec: HaloSpec, steps: int) -> jax.Array:
    """``steps`` local stencil applications (valid until the halo depth
    is exhausted: steps <= radius)."""
    assert steps <= spec.scalar_radius
    for _ in range(steps):
        local = stencil26(local, spec)
    return local


def stencil26_interior(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """First-application update of the DEEP interior: every cell whose
    1-neighborhood lies entirely inside the interior, i.e. the cells
    whose new values do not read any halo cell.

    Returns the ``(nz-2, ny-2, nx-2)`` block of updated values (origin
    ``(r+1, r+1, r+1)`` in the local allocation).  Because a halo
    exchange only *writes* halo shells, this block is bit-identical to
    the same region of ``stencil26(exchanged, spec)`` — which is what
    makes it legal to compute while the exchange is still on the wire.
    """
    r = spec.scalar_radius
    nz, ny, nx = spec.interior
    assert min(nz, ny, nx) > 2, "deep interior needs interior dims > 2"
    w = jnp.float32(0.4)
    shape = (nz - 2, ny - 2, nx - 2)
    acc = jnp.zeros(shape, local.dtype)
    for dz, dy, dx in _NEIGHBORS:
        acc = acc + jax.lax.dynamic_slice(
            local, (r + 1 + dz, r + 1 + dy, r + 1 + dx), shape
        )
    center = jax.lax.dynamic_slice(local, (r + 1, r + 1, r + 1), shape)
    return (1 - w) * center + (w / 26.0) * acc


def overlapped_stencil_iteration(
    local: jax.Array,
    spec: HaloSpec,
    comm,
    axis_name: str = "ranks",
    types=None,
    steps: int = 2,
    probe: Optional[dict] = None,
    plan: Optional[HaloPlan] = None,
) -> jax.Array:
    """One halo-exchange + ``steps``-stencil iteration with the exchange
    wire time hidden behind interior compute (ROADMAP: `Request` overlap
    via :func:`ihalo_exchange`).

    Pipeline: the fused collective is issued immediately
    (:func:`ihalo_exchange`), the deep-interior update — which needs no
    halo data — is computed while the wire op is in flight, then
    ``wait()`` materializes the halos and only the remaining rim of the
    first application depends on them.  The deep-interior values are
    spliced into the first application's result, so XLA sees two
    independent dataflows (collective ∥ interior compute) it is free to
    overlap.  Bit-identical to ``halo_exchange`` + ``stencil_iterations``.

    ``probe``, when given, records ``pending_during_interior``: whether
    the request was still pending when the interior compute was built —
    the overlap invariant tests assert.
    """
    assert steps <= spec.scalar_radius
    r = spec.scalar_radius
    req = ihalo_exchange(local, spec, comm, axis_name, types, plan)  # wire NOW
    inner = stencil26_interior(local, spec)   # overlaps the collective
    if probe is not None:
        probe["pending_during_interior"] = not req.completed
    full = req.wait()
    stepped = stencil26(full, spec)
    # splice the precomputed (identical) deep-interior values: keeps the
    # early compute live in the graph without changing the result
    stepped = jax.lax.dynamic_update_slice(stepped, inner, (r + 1, r + 1, r + 1))
    for _ in range(steps - 1):
        stepped = stencil26(stepped, spec)
    return stepped
