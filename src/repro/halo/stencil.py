"""26-point 3D stencil update (paper §6.4: "standard 26 point" stencil,
radius-2 halos, periodic boundaries, 4-byte gridpoints).

The radius-2 halo lets each exchange amortize over two local stencil
applications (a standard deep-halo optimization; it keeps the
exchange:compute ratio of the paper's setup).
"""

from __future__ import annotations

import itertools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.halo.exchange import HaloSpec

__all__ = ["stencil26", "stencil_iterations"]

_NEIGHBORS = tuple(
    d for d in itertools.product((-1, 0, 1), repeat=3) if d != (0, 0, 0)
)


def stencil26(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """One 26-point update of the interior; halos must be current.

    new[i] = (1-w)*u[i] + w/26 * sum_{26 neighbors} u[i+d]
    """
    r = spec.radius
    nz, ny, nx = spec.interior
    w = jnp.float32(0.4)
    acc = jnp.zeros((nz + 2 * (r - 1), ny + 2 * (r - 1), nx + 2 * (r - 1)),
                    local.dtype)
    # shifted views of the (interior + 1-cell shell) region
    for dz, dy, dx in _NEIGHBORS:
        acc = acc + jax.lax.dynamic_slice(
            local,
            (r - 1 + dz + 0, r - 1 + dy + 0, r - 1 + dx + 0),
            acc.shape,
        )
    center = jax.lax.dynamic_slice(local, (r - 1, r - 1, r - 1), acc.shape)
    new_inner = (1 - w) * center + (w / 26.0) * acc
    # write back the updated (interior + shell(r-1)) region
    return jax.lax.dynamic_update_slice(local, new_inner, (r - 1, r - 1, r - 1))


def stencil_iterations(local: jax.Array, spec: HaloSpec, steps: int) -> jax.Array:
    """``steps`` local stencil applications (valid until the halo depth
    is exhausted: steps <= radius)."""
    assert steps <= spec.radius
    for _ in range(steps):
        local = stencil26(local, spec)
    return local
