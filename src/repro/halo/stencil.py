"""Per-dimension-radius stencil kernels with shrinking-region deep-halo
application (paper §6.4: "standard 26 point" stencil, radius-2 halos,
periodic boundaries, 4-byte gridpoints).

A :class:`StencilOp` describes one weighted box-neighborhood update with
*per-dimension* radii ``(rz, ry, rx)`` — the paper's 26-point stencil is
``StencilOp((1, 1, 1))``; a train-style workload that smooths deeper
along the slow axis is ``StencilOp((2, 1, 1))``.  Nothing here requires
a symmetric radius any more (the old ``HaloSpec.scalar_radius`` guard is
gone): the halo radii, the stencil radii, and the valid-region
bookkeeping are all per-dimension tuples.

Deep halos trade wire for redundant compute: after one exchange at halo
depth ``valid``, each application of a radius-``r`` op leaves a region
deeper by ``r`` invalid, so :func:`stencil_apply` computes exactly the
still-valid window — interior plus a shell of ``valid - r`` — and
:func:`stencil_steps` walks ``valid`` down step by step.  With halo
depth ``s * r`` that amortizes ONE exchange over ``s`` applications,
bit-exact against the step-per-exchange reference on the interior
(ghost-shell cells are recomputed redundantly; that redundancy is what
:meth:`repro.comm.perfmodel.PerfModel.price_program` prices against the
saved wire time).  :class:`repro.halo.program.HaloProgram` compiles the
whole schedule.

All window arithmetic goes through the shared
:func:`repro.kernels.ops.stencil_window_update` primitive, so the
full-allocation path, the shrinking-region path, and the dense interior
chain of the overlap pipeline accumulate in the same order — which is
what makes their overlapping regions bit-identical and the overlap
splice legal.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.halo.exchange import HaloPlan, HaloSpec, ihalo_exchange
from repro.kernels.ops import stencil_window_update

__all__ = [
    "StencilOp",
    "STENCIL26",
    "stencil_apply",
    "stencil_steps",
    "stencil_interior_chain",
    "max_pipeline_depth",
    "stencil26",
    "stencil26_interior",
    "stencil_iterations",
    "overlapped_stencil_iteration",
]


@dataclass(frozen=True)
class StencilOp:
    """One weighted box-neighborhood update with per-dimension radii.

    ``new[i] = (1-w) * u[i] + w/N * sum over the N offsets d of u[i+d]``
    where the offsets are every nonzero point of the
    ``[-rz..rz] x [-ry..ry] x [-rx..rx]`` box.
    """

    radii: Tuple[int, int, int] = (1, 1, 1)
    weight: float = 0.4

    def __post_init__(self):
        r = tuple(int(x) for x in self.radii)
        if len(r) != 3 or any(x < 1 for x in r):
            raise ValueError(f"stencil radii must be 3 positive ints, got {r}")
        object.__setattr__(self, "radii", r)

    @property
    def offsets(self) -> Tuple[Tuple[int, int, int], ...]:
        """All nonzero neighbor offsets, in a deterministic order (the
        accumulation order — part of the bit-exactness contract)."""
        rz, ry, rx = self.radii
        return tuple(
            d
            for d in itertools.product(
                range(-rz, rz + 1), range(-ry, ry + 1), range(-rx, rx + 1)
            )
            if d != (0, 0, 0)
        )

    @property
    def nneighbors(self) -> int:
        rz, ry, rx = self.radii
        return (2 * rz + 1) * (2 * ry + 1) * (2 * rx + 1) - 1

    def halo_radii(self, steps: int) -> Tuple[int, int, int]:
        """Per-dimension halo depth that lets ``steps`` applications run
        on one exchange."""
        return tuple(steps * r for r in self.radii)


#: the paper's 26-point stencil (radius 1 in every dimension)
STENCIL26 = StencilOp((1, 1, 1))


def _as_radii(valid, spec: HaloSpec) -> Tuple[int, int, int]:
    if valid is None:
        return spec.radii
    if isinstance(valid, int):
        return (valid, valid, valid)
    return tuple(valid)


def stencil_apply(
    local: jax.Array,
    spec: HaloSpec,
    valid=None,
    op: StencilOp = STENCIL26,
) -> jax.Array:
    """One stencil application over the still-valid window.

    ``valid`` is the per-dimension halo depth whose cells currently hold
    correct values (defaults to the full ``spec.radii`` — i.e. "the
    exchange just ran").  The update writes interior plus a shell of
    ``valid - op.radii`` — exactly the cells whose whole neighborhood is
    valid — so after the call the valid depth has shrunk by ``op.radii``.
    """
    valid = _as_radii(valid, spec)
    radii = spec.radii
    for v, r, hr in zip(valid, op.radii, radii):
        if v < r:
            raise ValueError(
                f"valid halo depth {valid} is shallower than the stencil "
                f"radii {op.radii}; exchange first"
            )
        if v > hr:
            raise ValueError(f"valid depth {valid} exceeds halo radii {radii}")
    shell = tuple(v - r for v, r in zip(valid, op.radii))
    origin = tuple(hr - s for hr, s in zip(radii, shell))
    shape = tuple(n + 2 * s for n, s in zip(spec.interior, shell))
    updated = stencil_window_update(local, op.offsets, op.weight, origin, shape)
    return jax.lax.dynamic_update_slice(local, updated, origin)


def stencil_steps(
    local: jax.Array,
    spec: HaloSpec,
    steps: int,
    op: StencilOp = STENCIL26,
    valid=None,
) -> jax.Array:
    """``steps`` applications on one exchange, the valid region shrinking
    by ``op.radii`` per step (valid until the halo depth is exhausted:
    ``steps * op.radii <= valid``)."""
    valid = _as_radii(valid, spec)
    for v, r in zip(valid, op.radii):
        if steps * r > v:
            raise ValueError(
                f"{steps} steps of radii {op.radii} exhaust the valid halo "
                f"depth {valid}"
            )
    for _ in range(steps):
        local = stencil_apply(local, spec, valid, op)
        valid = tuple(v - r for v, r in zip(valid, op.radii))
    return local


def max_pipeline_depth(spec: HaloSpec, op: StencilOp, steps: int) -> int:
    """How many of the ``steps`` fused applications have a nonempty deep
    interior (every dim must keep >= 1 cell after shrinking ``k * r``
    from each side) — the depth :func:`stencil_interior_chain` can
    precompute while the exchange is on the wire."""
    depth = 0
    for k in range(1, steps + 1):
        if any(n - 2 * k * r < 1 for n, r in zip(spec.interior, op.radii)):
            break
        depth = k
    return depth


def stencil_interior_chain(
    local: jax.Array,
    spec: HaloSpec,
    depth: int,
    op: StencilOp = STENCIL26,
) -> List[jax.Array]:
    """Steps-deep pipelining: applications ``1..depth`` restricted to the
    cells that need NO halo data at all.

    Block ``k`` (1-indexed) holds the application-``k`` values of the
    interior shrunk by ``k * op.radii`` per side — computable from
    ``local``'s interior alone, before any exchange completes.  Because a
    halo exchange only *writes* halo shells, each block is bit-identical
    to the same region of the post-exchange application (same primitive,
    same accumulation order), which is what makes it legal to splice the
    chain into the real iteration while the wire op is still in flight.
    """
    x = jax.lax.dynamic_slice(local, spec.radii, spec.interior)
    blocks: List[jax.Array] = []
    for _ in range(depth):
        shape = tuple(s - 2 * r for s, r in zip(x.shape, op.radii))
        if any(s < 1 for s in shape):
            raise ValueError(
                f"interior {spec.interior} too small for a depth-"
                f"{len(blocks) + 1} chain of radii {op.radii}"
            )
        x = stencil_window_update(x, op.offsets, op.weight, op.radii, shape)
        blocks.append(x)
    return blocks


# ---------------------------------------------------------------------------
# legacy 26-point entry points (kept as thin wrappers over the per-dim API)
# ---------------------------------------------------------------------------

def stencil26(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """One 26-point update of the still-valid window (halos current)."""
    return stencil_apply(local, spec, op=STENCIL26)


def stencil26_interior(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """First-application update of the deep interior (no halo reads);
    returns the ``interior - 2`` block at origin ``radii + 1``."""
    return stencil_interior_chain(local, spec, 1, STENCIL26)[0]


def stencil_iterations(local: jax.Array, spec: HaloSpec, steps: int) -> jax.Array:
    """``steps`` 26-point applications on one exchange (shrinking valid
    region)."""
    return stencil_steps(local, spec, steps, STENCIL26)


# ---------------------------------------------------------------------------
# overlap: the exchange hidden behind the interior chain
# ---------------------------------------------------------------------------

def overlapped_stencil_iteration(
    local: jax.Array,
    spec: HaloSpec,
    comm,
    axis_name: str = "ranks",
    types=None,
    steps: int = 2,
    probe: Optional[dict] = None,
    plan: Optional[HaloPlan] = None,
    op: StencilOp = STENCIL26,
) -> jax.Array:
    """One exchange + ``steps`` applications with the wire hidden behind
    steps-deep interior pipelining.

    The fused collective is issued immediately (:func:`ihalo_exchange`);
    while it is in flight the :func:`stencil_interior_chain` precomputes
    every fused application's deep interior — not just the first one —
    so XLA sees ``depth + 1`` independent dataflows (collective ∥ chain)
    it is free to overlap.  After ``wait()`` the real shrinking-region
    applications run and each chain block is spliced over its (bit-
    identical) region, keeping the early compute live in the graph
    without changing the result.  Bit-identical to ``halo_exchange`` +
    ``stencil_steps``.

    ``probe``, when given, records ``pending_during_interior`` (the wire
    op was still pending when the chain was built — the overlap
    invariant) and ``pipeline_depth`` (how many applications had a
    nonempty deep interior to precompute).
    """
    for v, r in zip(spec.radii, op.radii):
        if steps * r > v:
            raise ValueError(
                f"halo radii {spec.radii} cannot host {steps} steps of "
                f"stencil radii {op.radii}"
            )
    depth = max_pipeline_depth(spec, op, steps)
    req = ihalo_exchange(local, spec, comm, axis_name, types, plan)  # wire NOW
    chain = stencil_interior_chain(local, spec, depth, op)  # overlaps the wire
    if probe is not None:
        probe["pending_during_interior"] = not req.completed
        probe["pipeline_depth"] = depth
    full = req.wait()
    valid = spec.radii
    for k in range(1, steps + 1):
        full = stencil_apply(full, spec, valid, op)
        valid = tuple(v - r for v, r in zip(valid, op.radii))
        if k <= depth:
            origin = tuple(hr + k * r for hr, r in zip(spec.radii, op.radii))
            full = jax.lax.dynamic_update_slice(full, chain[k - 1], origin)
    return full
