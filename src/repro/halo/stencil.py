"""Per-dimension-radius stencil kernels with shrinking-region deep-halo
application (paper §6.4: "standard 26 point" stencil, radius-2 halos,
periodic boundaries, 4-byte gridpoints).

A :class:`StencilOp` describes one weighted box-neighborhood update with
*per-dimension* radii ``(rz, ry, rx)`` — the paper's 26-point stencil is
``StencilOp((1, 1, 1))``; a train-style workload that smooths deeper
along the slow axis is ``StencilOp((2, 1, 1))``.  Nothing here requires
a symmetric radius any more (the old ``HaloSpec.scalar_radius`` guard is
gone): the halo radii, the stencil radii, and the valid-region
bookkeeping are all per-dimension tuples.

Deep halos trade wire for redundant compute: after one exchange at halo
depth ``valid``, each application of a radius-``r`` op leaves a region
deeper by ``r`` invalid, so :func:`stencil_apply` computes exactly the
still-valid window — interior plus a shell of ``valid - r`` — and
:func:`stencil_steps` walks ``valid`` down step by step.  With halo
depth ``s * r`` that amortizes ONE exchange over ``s`` applications,
bit-exact against the step-per-exchange reference on the interior
(ghost-shell cells are recomputed redundantly; that redundancy is what
:meth:`repro.comm.perfmodel.PerfModel.price_program` prices against the
saved wire time).  :class:`repro.halo.program.HaloProgram` compiles the
whole schedule.

Ops also compose into *cycles*: a heterogeneous sequence
``[op_1..op_k]`` (a predictor/corrector pair, a smoother sweep) applied
in order and repeated.  One cycle pass consumes :func:`cycle_radii` of
valid halo per dimension — the per-op radii summed — so a halo of depth
``repeats * cycle_radii`` hosts ``repeats`` whole cycles on ONE
exchange (:func:`stencil_cycle`); every helper here accepts either a
single :class:`StencilOp` or a sequence of them.

All window arithmetic goes through the shared
:func:`repro.kernels.ops.stencil_window_update` /
:func:`~repro.kernels.ops.stencil_window_chain` primitives, so the
full-allocation path, the shrinking-region path, and the dense interior
chain of the overlap pipeline accumulate in the same order — which is
what makes their overlapping regions bit-identical and the overlap
splice legal.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.halo.exchange import (
    DIRECTIONS,
    HaloPlan,
    HaloSpec,
    ihalo_exchange,
    make_halo_plan,
)
from repro.kernels.ops import stencil_window_chain, stencil_window_update

__all__ = [
    "StencilOp",
    "STENCIL26",
    "as_ops",
    "cycle_halo_radii",
    "cycle_radii",
    "op_sequence",
    "stencil_apply",
    "stencil_steps",
    "stencil_cycle",
    "stencil_interior_chain",
    "max_pipeline_depth",
    "stencil26",
    "stencil26_interior",
    "stencil_iterations",
    "OVERLAP_MODES",
    "HaloRegion",
    "halo_regions",
    "overlap_region_descriptors",
    "resolve_overlap_mode",
    "overlapped_stencil_iteration",
]

#: one op or a heterogeneous cycle of them — every consumer normalizes
#: through :func:`as_ops`
Ops = Union["StencilOp", Sequence["StencilOp"]]


@dataclass(frozen=True)
class StencilOp:
    """One weighted box-neighborhood update with per-dimension radii.

    ``new[i] = (1-w) * u[i] + w/N * sum over the N offsets d of u[i+d]``
    where the offsets are every nonzero point of the
    ``[-rz..rz] x [-ry..ry] x [-rx..rx]`` box.
    """

    radii: Tuple[int, int, int] = (1, 1, 1)
    weight: float = 0.4

    def __post_init__(self):
        r = tuple(int(x) for x in self.radii)
        if len(r) != 3 or any(x < 1 for x in r):
            raise ValueError(f"stencil radii must be 3 positive ints, got {r}")
        object.__setattr__(self, "radii", r)

    @property
    def offsets(self) -> Tuple[Tuple[int, int, int], ...]:
        """All nonzero neighbor offsets, in a deterministic order (the
        accumulation order — part of the bit-exactness contract)."""
        rz, ry, rx = self.radii
        return tuple(
            d
            for d in itertools.product(
                range(-rz, rz + 1), range(-ry, ry + 1), range(-rx, rx + 1)
            )
            if d != (0, 0, 0)
        )

    @property
    def nneighbors(self) -> int:
        rz, ry, rx = self.radii
        return (2 * rz + 1) * (2 * ry + 1) * (2 * rx + 1) - 1

    def halo_radii(self, steps: int) -> Tuple[int, int, int]:
        """Per-dimension halo depth that lets ``steps`` applications run
        on one exchange."""
        return tuple(steps * r for r in self.radii)


#: the paper's 26-point stencil (radius 1 in every dimension)
STENCIL26 = StencilOp((1, 1, 1))


def as_ops(op: Ops) -> Tuple[StencilOp, ...]:
    """Normalize one op or an op sequence into a nonempty cycle tuple."""
    ops = (op,) if isinstance(op, StencilOp) else tuple(op)
    if not ops or not all(isinstance(o, StencilOp) for o in ops):
        raise ValueError(f"expected a StencilOp or a nonempty sequence, got {op!r}")
    return ops


def cycle_radii(op: Ops) -> Tuple[int, int, int]:
    """Per-dimension valid-halo depth ONE cycle pass consumes — the
    per-op radii summed in application order."""
    ops = as_ops(op)
    return tuple(sum(o.radii[d] for o in ops) for d in range(3))


def cycle_halo_radii(op: Ops, repeats: int) -> Tuple[int, int, int]:
    """Per-dimension halo depth that hosts ``repeats`` whole cycle
    passes on one exchange (the cycle analogue of
    :meth:`StencilOp.halo_radii`)."""
    return tuple(repeats * r for r in cycle_radii(op))


def op_sequence(op: Ops, repeats: int) -> Tuple[StencilOp, ...]:
    """The flattened application schedule: the cycle repeated
    ``repeats`` times (``repeats * len(ops)`` applications)."""
    if repeats < 1:
        raise ValueError(f"cycle repeats must be >= 1, got {repeats}")
    return as_ops(op) * repeats


def _as_radii(valid, spec: HaloSpec) -> Tuple[int, int, int]:
    if valid is None:
        return spec.radii
    if isinstance(valid, int):
        return (valid, valid, valid)
    return tuple(valid)


def stencil_apply(
    local: jax.Array,
    spec: HaloSpec,
    valid=None,
    op: StencilOp = STENCIL26,
) -> jax.Array:
    """One stencil application over the still-valid window.

    ``valid`` is the per-dimension halo depth whose cells currently hold
    correct values (defaults to the full ``spec.radii`` — i.e. "the
    exchange just ran").  The update writes interior plus a shell of
    ``valid - op.radii`` — exactly the cells whose whole neighborhood is
    valid — so after the call the valid depth has shrunk by ``op.radii``.
    """
    valid = _as_radii(valid, spec)
    radii = spec.radii
    for v, r, hr in zip(valid, op.radii, radii):
        if v < r:
            raise ValueError(
                f"valid halo depth {valid} is shallower than the stencil "
                f"radii {op.radii}; exchange first"
            )
        if v > hr:
            raise ValueError(f"valid depth {valid} exceeds halo radii {radii}")
    shell = tuple(v - r for v, r in zip(valid, op.radii))
    origin = tuple(hr - s for hr, s in zip(radii, shell))
    shape = tuple(n + 2 * s for n, s in zip(spec.interior, shell))
    updated = stencil_window_update(local, op.offsets, op.weight, origin, shape)
    return jax.lax.dynamic_update_slice(local, updated, origin)


def stencil_cycle(
    local: jax.Array,
    spec: HaloSpec,
    op: Ops,
    repeats: int = 1,
    valid=None,
) -> jax.Array:
    """``repeats`` passes of a (possibly heterogeneous) op cycle on one
    exchange, the valid region shrinking by each op's radii per
    application (valid until the halo depth is exhausted:
    ``repeats * cycle_radii(op) <= valid``)."""
    valid = _as_radii(valid, spec)
    need = cycle_halo_radii(op, repeats)
    if any(n > v for n, v in zip(need, valid)):
        raise ValueError(
            f"{repeats} repeats of cycle radii {cycle_radii(op)} exhaust "
            f"the valid halo depth {valid}"
        )
    for o in op_sequence(op, repeats):
        local = stencil_apply(local, spec, valid, o)
        valid = tuple(v - r for v, r in zip(valid, o.radii))
    return local


def stencil_steps(
    local: jax.Array,
    spec: HaloSpec,
    steps: int,
    op: StencilOp = STENCIL26,
    valid=None,
) -> jax.Array:
    """``steps`` applications of ONE op on one exchange (the single-op
    cycle — see :func:`stencil_cycle` for heterogeneous cycles)."""
    return stencil_cycle(local, spec, (op,), steps, valid)


def _cum_shrink(op: Ops, applications: int) -> List[Tuple[int, int, int]]:
    """Cumulative per-dimension shrink after each of the first
    ``applications`` applications of the repeating cycle."""
    cum = (0, 0, 0)
    out = []
    for o in itertools.islice(itertools.cycle(as_ops(op)), applications):
        cum = tuple(c + r for c, r in zip(cum, o.radii))
        out.append(cum)
    return out


def max_pipeline_depth(spec: HaloSpec, op: Ops, steps: int) -> int:
    """How many of the ``steps * len(ops)`` fused applications have a
    nonempty deep interior (every dim must keep >= 1 cell after the
    cumulative shrink from each side) — the depth
    :func:`stencil_interior_chain` can precompute while the exchange is
    on the wire.  ``steps`` counts cycle repeats."""
    ops = as_ops(op)
    depth = 0
    for k, cum in enumerate(_cum_shrink(ops, steps * len(ops)), 1):
        if any(n - 2 * c < 1 for n, c in zip(spec.interior, cum)):
            break
        depth = k
    return depth


def stencil_interior_chain(
    local: jax.Array,
    spec: HaloSpec,
    depth: int,
    op: Ops = STENCIL26,
) -> List[jax.Array]:
    """Steps-deep pipelining: applications ``1..depth`` of the repeating
    op cycle, restricted to the cells that need NO halo data at all.

    Block ``k`` (1-indexed) holds the application-``k`` values of the
    interior shrunk by the cycle's cumulative radii per side —
    computable from ``local``'s interior alone, before any exchange
    completes.  Because a halo exchange only *writes* halo shells, each
    block is bit-identical to the same region of the post-exchange
    application (same primitive, same accumulation order), which is what
    makes it legal to splice the chain into the real iteration while the
    wire op is still in flight.
    """
    x = jax.lax.dynamic_slice(local, spec.radii, spec.interior)
    seq = list(itertools.islice(itertools.cycle(as_ops(op)), depth))
    try:
        return stencil_window_chain(
            x, [(o.offsets, o.weight, o.radii) for o in seq]
        )
    except ValueError as e:
        raise ValueError(
            f"interior {spec.interior} too small for a depth-{depth} "
            f"chain of the cycle {[o.radii for o in as_ops(op)]}: {e}"
        ) from None


# ---------------------------------------------------------------------------
# legacy 26-point entry points (kept as thin wrappers over the per-dim API)
# ---------------------------------------------------------------------------

def stencil26(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """One 26-point update of the still-valid window (halos current)."""
    return stencil_apply(local, spec, op=STENCIL26)


def stencil26_interior(local: jax.Array, spec: HaloSpec) -> jax.Array:
    """First-application update of the deep interior (no halo reads);
    returns the ``interior - 2`` block at origin ``radii + 1``."""
    return stencil_interior_chain(local, spec, 1, STENCIL26)[0]


def stencil_iterations(local: jax.Array, spec: HaloSpec, steps: int) -> jax.Array:
    """``steps`` 26-point applications on one exchange (shrinking valid
    region)."""
    return stencil_steps(local, spec, steps, STENCIL26)


# ---------------------------------------------------------------------------
# region decomposition: core + faces/edges/corners of the first application
# ---------------------------------------------------------------------------

#: how :func:`overlapped_stencil_iteration` hides the wire:
#: ``monolithic`` waits for the fused collective then applies every rim
#: at once; ``region`` drains delta classes and computes each rim region
#: as its classes land; ``auto`` lets the model pick (pinned as an
#: ``overlap/mode=...`` decision)
OVERLAP_MODES = ("monolithic", "region", "auto")


@dataclass(frozen=True)
class HaloRegion:
    """One region of the FIRST fused application's output window.

    ``sig`` places it in the 3^3 core/face/edge/corner decomposition:
    ``sig[a] == 0`` means the region's axis-``a`` span reads no halo in
    that axis; ``-1``/``+1`` mean it reads the low/high halo shell.  The
    core is ``(0, 0, 0)``; the 6 faces have one nonzero component, the
    12 edges two, the 8 corners three (regions that come out empty for
    the given geometry are dropped).

    ``origin``/``shape`` locate the region in the local allocation (the
    same coordinates :func:`stencil_apply` writes).  ``bands`` lists the
    halo-shell bands the region's cells may read; ``transfers`` the
    ``DIRECTIONS`` indices of the recv transfers that fill those bands —
    the region may be computed as soon as exactly those transfers have
    been unpacked.
    """

    sig: Tuple[int, int, int]
    origin: Tuple[int, int, int]
    shape: Tuple[int, int, int]
    bands: Tuple[Tuple[int, int, int], ...]
    transfers: Tuple[int, ...]

    @property
    def cells(self) -> int:
        return self.shape[0] * self.shape[1] * self.shape[2]


def halo_regions(spec: HaloSpec, op: Ops) -> Tuple[HaloRegion, ...]:
    """Decompose the first application's output window into core +
    faces/edges/corners.

    Per axis the window ``[0, w)`` (``w = n + 2 * (hr - r)``, origin
    ``r`` in the allocation) splits at ``m1 = min(hr, w)`` and
    ``m2 = max(w - hr, m1)``: cells below ``m1`` read the low halo
    shell, cells at ``m2`` and above read the high shell, the middle
    reads neither.  The three intervals partition ``[0, w)`` by
    construction — also when the interior is shallower than ``2r`` and
    the low/high read-sets overlap; the cut then lands so each cell
    stays in exactly one interval and the boundary intervals' dependency
    sets widen to *both* sides.  A region is the product of one interval
    per axis, so the nonempty regions exactly partition the window (the
    property test asserts no overlap, no gap).

    The dependency set is the per-axis product of ``{0} | sides`` minus
    the all-zero band — a superset of the bands actually read (exact for
    ``hr <= 2r``; for deeper halos a rim cell may skip the interior in
    an axis, and the superset only ever delays that region's compute,
    never corrupts it).
    """
    ops = as_ops(op)
    first = ops[0]
    axes = []
    for n, hr, r in zip(spec.interior, spec.radii, first.radii):
        shell = hr - r
        o = r
        w = n + 2 * shell
        m1 = min(hr, w)
        m2 = max(w - hr, m1)
        low_sides = {-1} | ({+1} if m1 > w - hr else set())
        high_sides = {+1} | ({-1} if m2 < hr else set())
        axes.append({
            -1: (o, m1, low_sides),
            0: (o + m1, m2 - m1, set()),
            +1: (o + m2, w - m2, high_sides),
        })
    regions = []
    for sig in itertools.product((-1, 0, 1), repeat=3):
        origin, shape, sides = [], [], []
        for a, s in enumerate(sig):
            start, length, sd = axes[a][s]
            origin.append(start)
            shape.append(length)
            sides.append(sorted({0} | sd))
        if any(length <= 0 for length in shape):
            continue
        bands = tuple(
            b for b in itertools.product(*sides) if b != (0, 0, 0)
        )
        transfers = tuple(sorted(
            DIRECTIONS.index((-b[0], -b[1], -b[2])) for b in bands
        ))
        regions.append(HaloRegion(
            sig, tuple(origin), tuple(shape), bands, transfers
        ))
    return tuple(regions)


def _transfer_classes(wire) -> dict:
    """Transfer index -> delta-class index of the exchange's WirePlan."""
    out = {}
    for g, grp in enumerate(wire.groups):
        for i in grp.transfers:
            out[i] = g
    return out


def overlap_region_descriptors(
    spec: HaloSpec, op: Ops, wire
) -> Tuple[int, List[Tuple[int, Tuple[int, ...]]]]:
    """Reduce the geometry to what the model prices: the core window
    bytes plus one ``(window_bytes, dep_class_ids)`` pair per rim region
    (:meth:`repro.comm.perfmodel.PerfModel.price_overlap` — the model
    never sees halo coordinates, only bytes and dependencies)."""
    eb = spec.element.size
    cls_of = _transfer_classes(wire)
    core_bytes = 0
    rims: List[Tuple[int, Tuple[int, ...]]] = []
    for reg in halo_regions(spec, op):
        nb = reg.cells * eb
        if reg.sig == (0, 0, 0):
            core_bytes += nb
        else:
            deps = tuple(sorted({cls_of[i] for i in reg.transfers}))
            rims.append((nb, deps))
    return core_bytes, rims


def resolve_overlap_mode(
    spec: HaloSpec, comm, plan: HaloPlan, op: Ops = STENCIL26
) -> str:
    """Model-priced monolithic-vs-region choice for this exchange,
    pinned as an ``overlap/mode=...`` decision
    (:meth:`~repro.comm.perfmodel.PerfModel.choose_overlap_mode`)."""
    ops = as_ops(op)
    core_bytes, rims = overlap_region_descriptors(spec, ops, plan.wire)
    mode, _, _ = comm.model.choose_overlap_mode(
        plan.wire, rims, core_bytes, ops[0].nneighbors
    )
    return mode


def _apply_region_split(req, spec: HaloSpec, ops: Tuple[StencilOp, ...],
                        wire, chain_core, probe: Optional[dict]):
    """The first fused application, region-split: drain delta classes in
    completion order (``NeighborRequest.wait_any``) and compute each rim
    region the moment its dependency classes have been unpacked.

    Rim windows *read* overlapping cells (a face's neighborhood reaches
    into the adjacent edges), so the computed windows are collected as
    deferred patches and spliced only after every class has drained —
    each region thus reads pre-application values exactly like the
    monolithic full-window update, and the result is bit-identical.  The
    core, when nonempty, is the interior chain's first block, computed
    while the wire was in flight.
    """
    first = ops[0]
    cls_of = _transfer_classes(wire)
    rims = [r for r in halo_regions(spec, ops) if r.sig != (0, 0, 0)]
    deps = [frozenset(cls_of[i] for i in r.transfers) for r in rims]
    landed: set = set()
    done = [False] * len(rims)
    patches = []
    order: List[Tuple[int, int, int]] = []

    def sweep() -> None:
        for i, reg in enumerate(rims):
            if not done[i] and deps[i] <= landed:
                win = stencil_window_update(
                    req.buffer, first.offsets, first.weight,
                    reg.origin, reg.shape,
                )
                patches.append((reg.origin, win))
                done[i] = True
                order.append(reg.sig)

    while req.pending:
        landed.add(req.wait_any().index)
        sweep()
    full = req.wait()
    for origin, win in patches:
        full = jax.lax.dynamic_update_slice(full, win, origin)
    if chain_core is not None:
        core_origin = tuple(
            hr + r for hr, r in zip(spec.radii, first.radii)
        )
        full = jax.lax.dynamic_update_slice(full, chain_core, core_origin)
    if probe is not None:
        probe["rim_regions"] = len(rims)
        probe["region_order"] = tuple(order)
        probe["class_drain_order"] = tuple(req.drained)
    return full


# ---------------------------------------------------------------------------
# overlap: the exchange hidden behind the interior chain
# ---------------------------------------------------------------------------

def overlapped_stencil_iteration(
    local: jax.Array,
    spec: HaloSpec,
    comm,
    axis_name: str = "ranks",
    types=None,
    steps: int = 2,
    probe: Optional[dict] = None,
    plan: Optional[HaloPlan] = None,
    op: Ops = STENCIL26,
    mode: str = "monolithic",
) -> jax.Array:
    """One exchange + ``steps`` cycle repeats with the wire hidden behind
    steps-deep interior pipelining.

    ``op`` is one op or a heterogeneous cycle; ``steps`` counts cycle
    repeats (``steps * len(ops)`` applications total).  The fused
    collective is issued immediately (:func:`ihalo_exchange`); while it
    is in flight the :func:`stencil_interior_chain` precomputes every
    fused application's deep interior — not just the first one — so XLA
    sees ``depth + 1`` independent dataflows (collective ∥ chain) it is
    free to overlap.

    ``mode`` picks how the first application consumes the wire
    (:data:`OVERLAP_MODES`):

    ``monolithic``  ``wait()`` for the whole fused exchange, then the
                    shrinking-region applications run and each chain
                    block is spliced over its (bit-identical) region.
    ``region``      drain per-delta-class requests in completion order
                    and compute each core/face/edge/corner region of the
                    first application as *its* classes land
                    (:func:`halo_regions`); applications ``2..`` follow
                    the monolithic path.  Bit-identical to it.
    ``auto``        the model prices both on the system tables and the
                    choice is pinned as an ``overlap/mode=...`` decision
                    (:func:`resolve_overlap_mode`).

    All modes are bit-identical to ``halo_exchange`` + ``stencil_cycle``.

    ``probe``, when given, records ``pending_during_interior`` (the wire
    op was still pending when the chain was built — the overlap
    invariant), ``pipeline_depth`` (how many applications had a nonempty
    deep interior to precompute) and ``overlap_mode`` (the resolved
    mode; region mode adds ``rim_regions``, ``region_order`` and
    ``class_drain_order``).
    """
    ops = as_ops(op)
    if mode not in OVERLAP_MODES:
        raise ValueError(
            f"unknown overlap mode {mode!r}; expected one of {OVERLAP_MODES}"
        )
    if any(n > v for n, v in zip(cycle_halo_radii(ops, steps), spec.radii)):
        raise ValueError(
            f"halo radii {spec.radii} cannot host {steps} repeats of "
            f"cycle radii {cycle_radii(ops)}"
        )
    if mode != "monolithic" and plan is None:
        plan = make_halo_plan(spec, comm, types)
    if mode == "auto":
        mode = resolve_overlap_mode(spec, comm, plan, ops)
    depth = max_pipeline_depth(spec, ops, steps)
    req = ihalo_exchange(local, spec, comm, axis_name, types, plan)  # wire NOW
    chain = stencil_interior_chain(local, spec, depth, ops)  # overlaps the wire
    if probe is not None:
        probe["pending_during_interior"] = not req.completed
        probe["pipeline_depth"] = depth
        probe["overlap_mode"] = mode
    valid = spec.radii
    seq = op_sequence(ops, steps)
    shrink = _cum_shrink(ops, len(seq))
    if mode == "region":
        full = _apply_region_split(
            req, spec, ops, plan.wire,
            chain[0] if depth >= 1 else None, probe,
        )
        valid = tuple(v - r for v, r in zip(valid, ops[0].radii))
        first_k = 2
    else:
        full = req.wait()
        first_k = 1
    for k, o in enumerate(seq, 1):
        if k < first_k:
            continue
        full = stencil_apply(full, spec, valid, o)
        valid = tuple(v - r for v, r in zip(valid, o.radii))
        if k <= depth:
            origin = tuple(hr + c for hr, c in zip(spec.radii, shrink[k - 1]))
            full = jax.lax.dynamic_update_slice(full, chain[k - 1], origin)
    return full
