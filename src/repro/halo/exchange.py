"""3D stencil halo exchange on datatype-described halo regions
(paper §6.4 case study).

Each rank owns an interior block of ``(nz, ny, nx)`` gridpoints inside a
local allocation ``(nz+2r, ny+2r, nx+2r)`` (halo shells of radius ``r``).
The 26 neighbor regions (6 faces, 12 edges, 8 corners, periodic domain)
are each described by an MPI-style ``Subarray`` datatype — "a variety of
different 3D strided datatypes" — committed once and exchanged every
iteration through a :class:`~repro.comm.api.Communicator`.

The paper transports the packed buffers with one ``MPI_Alltoallv``; this
is :meth:`Communicator.neighbor_alltoallv`: all 26 regions are packed at
their **exact** wire extents into one flat buffer laid out by a
:class:`~repro.comm.wireplan.WirePlan`, and the plan's wire schedule
moves exactly those bytes — on a periodic process grid the 26 directions
collapse into the distinct displacement classes mod the grid (7 on a
2x2x2 grid), each class a single exact-payload wire op (or one native
ragged collective where the running JAX provides it).  The whole layout
— committed types, strategy selection, wire plan — is built ONCE at
:func:`make_halo_step` time (:class:`HaloPlan`); every iteration after
that is dictionary lookups.

Halos may be asymmetric: ``HaloSpec.radius`` accepts a per-dimension
``(rz, ry, rx)`` tuple, and the region datatypes, allocations, and wire
layout all follow the per-dimension radii (the ragged wire layout is
what makes this free — unequal region sizes never padded each other).

On a two-level machine (a communicator constructed with a
:class:`repro.comm.topology.Topology`), the same planning pass annotates
each delta class with the link tier it crosses: classes that stay on one
node price at the fast tier, node-crossing classes at the slow tier, and
the model may pick the ``tiered`` schedule — every class bound for the
same peer node coalesced into ONE slow-tier collective, corrected to its
true destination rank by cheap intra-node hops.  Nothing here changes:
the topology rides ``Communicator.plan_neighbor`` into the wire plan.

Switching the communicator policy between baseline and model selection
reproduces the paper's comparison with zero changes here.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm.api import (
    Communicator,
    Request,
    Strategy,
    WirePlan,
    as_communicator,
)
from repro.core.commit import CommittedType
from repro.core.datatypes import FLOAT, Named, Subarray

__all__ = [
    "HaloSpec",
    "HaloPlan",
    "DIRECTIONS",
    "halo_exchange",
    "ihalo_exchange",
    "make_halo_types",
    "make_halo_plan",
    "make_halo_step",
]

#: the 26 neighbor directions (dz, dy, dx)
DIRECTIONS: Tuple[Tuple[int, int, int], ...] = tuple(
    d for d in itertools.product((-1, 0, 1), repeat=3) if d != (0, 0, 0)
)


@dataclass(frozen=True)
class HaloSpec:
    """Geometry of one rank's local block.

    ``radius`` is either one scalar (the paper's symmetric radius-2
    setup) or a per-dimension ``(rz, ry, rx)`` tuple for asymmetric
    halos (e.g. a deeper halo on the slow axis only).
    """

    grid: Tuple[int, int, int]     # process grid (pz, py, px)
    interior: Tuple[int, int, int]  # (nz, ny, nx) gridpoints per rank
    radius: Union[int, Tuple[int, int, int]] = 2  # paper: stencil radius 2
    element: Named = FLOAT          # paper: 4-byte gridpoints

    @property
    def radii(self) -> Tuple[int, int, int]:
        """Per-dimension halo radii (scalar radius broadcast).  Every
        consumer — region datatypes, allocations, and the stencil
        kernels — is per-dimension aware; the old ``scalar_radius``
        symmetry guard is gone."""
        if isinstance(self.radius, tuple):
            return self.radius
        return (self.radius, self.radius, self.radius)

    @property
    def alloc(self) -> Tuple[int, int, int]:
        return tuple(n + 2 * r for n, r in zip(self.interior, self.radii))

    @property
    def nranks(self) -> int:
        return int(np.prod(self.grid))

    def coords(self, rank: int) -> Tuple[int, int, int]:
        pz, py, px = self.grid
        return (rank // (py * px), (rank // px) % py, rank % px)

    def rank_of(self, c: Sequence[int]) -> int:
        pz, py, px = self.grid
        return (c[0] % pz) * py * px + (c[1] % py) * px + (c[2] % px)

    def perm(self, d: Tuple[int, int, int]) -> List[Tuple[int, int]]:
        """ppermute edges: every rank sends toward direction ``d``
        (periodic)."""
        return [
            (r, self.rank_of(tuple(ci + di for ci, di in zip(self.coords(r), d))))
            for r in range(self.nranks)
        ]


def _region_type(spec: HaloSpec, d, kind: str) -> Subarray:
    """Subarray datatype for the send/recv region of direction ``d``.

    kind="send": the interior slab facing ``d``.
    kind="recv": the halo shell on side ``-d`` (filled by the neighbor at
    ``-d`` during round ``d``; see module docstring).
    """
    radii = spec.radii
    sizes_zyx = spec.alloc
    sub, start = [], []
    for axis in range(3):
        n = spec.interior[axis]
        r = radii[axis]
        di = d[axis]
        if di == 0:
            sub.append(n)
            start.append(r)
        else:
            sub.append(r)
            if kind == "send":
                start.append(r if di < 0 else n)       # low/high interior slab
            else:
                start.append(n + r if di < 0 else 0)   # halo shell on side -d
    # paper order: index 0 = innermost (x); local arrays are (z, y, x)
    return Subarray(
        tuple(reversed(sizes_zyx)),
        tuple(reversed(sub)),
        tuple(reversed(start)),
        spec.element,
    )


def make_halo_types(
    spec: HaloSpec, comm
) -> Dict[Tuple[int, int, int], Tuple[CommittedType, CommittedType]]:
    """Commit all 26 (send, recv) datatypes once (paper: 26 MPI_Pack +
    26 MPI_Unpack per iteration on committed types).  Accepts a
    Communicator or the deprecated Interposer shim."""
    return {
        d: (comm.commit(_region_type(spec, d, "send")),
            comm.commit(_region_type(spec, d, "recv")))
        for d in DIRECTIONS
    }


@dataclass(frozen=True)
class HaloPlan:
    """Everything a halo exchange needs, computed once: the committed
    (send, recv) types, their permutations, the selected strategies, and
    the exact-byte :class:`~repro.comm.wireplan.WirePlan`.  Build with
    :func:`make_halo_plan` at setup time (``make_halo_step`` does); the
    per-iteration host work is then dictionary lookups only."""

    spec: HaloSpec
    send_cts: Tuple[CommittedType, ...]
    recv_cts: Tuple[CommittedType, ...]
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]
    strategies: Tuple[Strategy, ...]
    wire: WirePlan

    @property
    def wire_bytes(self) -> int:
        """Exact bytes one exchange puts on the wire (the ragged
        optimum: the sum of per-peer packed extents)."""
        return self.wire.wire_bytes


def make_halo_plan(
    spec: HaloSpec, comm, types=None, schedule_policy: Optional[str] = None
) -> HaloPlan:
    """Commit the 26 region types, select strategies, and lay out the
    exact-byte wire plan — the full setup cost of a halo exchange, paid
    once.  ``schedule_policy`` defaults to the communicator's policy
    (model-priced: grouped launch latencies traded against uniform
    padding bytes — see :meth:`Communicator.plan_neighbor`); pass
    ``"exact"`` for the byte-exact ladder the wire-bytes gates assert."""
    comm = as_communicator(comm)
    if types is None:
        types = make_halo_types(spec, comm)
    send_cts = tuple(types[d][0] for d in DIRECTIONS)
    recv_cts = tuple(types[d][1] for d in DIRECTIONS)
    perms = tuple(tuple(spec.perm(d)) for d in DIRECTIONS)
    strategies, wire = comm.plan_neighbor(
        send_cts, perms, schedule_policy=schedule_policy
    )
    return HaloPlan(
        spec=spec,
        send_cts=send_cts,
        recv_cts=recv_cts,
        perms=perms,
        strategies=strategies,
        wire=wire,
    )


def ihalo_exchange(
    local: jax.Array,
    spec: HaloSpec,
    comm,
    axis_name: str = "ranks",
    types=None,
    plan: Optional[HaloPlan] = None,
) -> Request:
    """Nonblocking 26-neighbor halo exchange: the fused wire transport
    (exact ragged payloads) is issued immediately; ``wait()``
    materializes the 26 unpacks.  Must run inside shard_map over a 1D
    mesh axis of ``spec.nranks`` devices.  Pass a prebuilt ``plan``
    (:func:`make_halo_plan`) to skip per-call planning."""
    comm = as_communicator(comm)
    if plan is None:
        plan = make_halo_plan(spec, comm, types)
    return comm.ineighbor_alltoallv(
        local,
        plan.send_cts,
        plan.recv_cts,
        plan.perms,
        axis_name,
        plan=plan.wire,
        strategies=plan.strategies,
    )


def halo_exchange(
    local: jax.Array,
    spec: HaloSpec,
    comm,
    axis_name: str = "ranks",
    types=None,
    plan: Optional[HaloPlan] = None,
) -> jax.Array:
    """One full 26-neighbor halo exchange for this rank's ``local`` block
    (exact wire bytes, fused schedule).  Returns ``local`` with all halo
    shells filled."""
    return ihalo_exchange(local, spec, comm, axis_name, types, plan).wait()


def make_halo_step(spec: HaloSpec, comm, mesh: Mesh, axis_name="ranks",
                   schedule_policy: Optional[str] = None):
    """jit-compiled shard_map wrapper: (nranks*az, ay, ax) global array,
    sharded on the leading axis, -> exchanged.  The halo plan (types,
    strategies, wire layout) is built here, once."""
    plan = make_halo_plan(spec, comm, schedule_policy=schedule_policy)

    def step(local):
        return halo_exchange(local, spec, comm, axis_name, plan=plan)

    fn = shard_map(
        step,
        mesh=mesh,
        in_specs=P(axis_name),
        out_specs=P(axis_name),
        check_vma=False,
    )
    return jax.jit(fn)
