"""repro.halo — the paper's §6.4 3D stencil halo-exchange case study."""

from repro.halo.exchange import (
    DIRECTIONS,
    HaloSpec,
    halo_exchange,
    ihalo_exchange,
    make_halo_step,
    make_halo_types,
)
from repro.halo.stencil import stencil26, stencil_iterations

__all__ = [
    "DIRECTIONS",
    "HaloSpec",
    "halo_exchange",
    "ihalo_exchange",
    "make_halo_step",
    "make_halo_types",
    "stencil26",
    "stencil_iterations",
]
