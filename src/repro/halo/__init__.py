"""repro.halo — the paper's §6.4 3D stencil halo-exchange case study."""

from repro.halo.exchange import (
    DIRECTIONS,
    HaloPlan,
    HaloSpec,
    halo_exchange,
    ihalo_exchange,
    make_halo_plan,
    make_halo_step,
    make_halo_types,
)
from repro.halo.stencil import (
    overlapped_stencil_iteration,
    stencil26,
    stencil26_interior,
    stencil_iterations,
)

__all__ = [
    "DIRECTIONS",
    "HaloPlan",
    "HaloSpec",
    "halo_exchange",
    "ihalo_exchange",
    "make_halo_plan",
    "make_halo_step",
    "make_halo_types",
    "overlapped_stencil_iteration",
    "stencil26",
    "stencil26_interior",
    "stencil_iterations",
]
