"""Model assembly: embeddings + scan-over-layers + heads, for every
assigned architecture family, with train / prefill / decode entrypoints.

Layer parameters are stacked on a leading L axis and applied with
``lax.scan`` (+ optional ``jax.checkpoint`` remat) — essential both for
runtime (single compiled block) and for the 40-cell dry-run's compile
times.

Entry points (all pure functions of (params, batch...)):

    forward(params, batch)              -> (logits, aux)    train shapes
    prefill(params, batch)              -> (last_logits, cache)
    decode_step(params, cache, tok, t)  -> (logits, cache)
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import blocks as B
from repro.models.layers import init_dense, init_norm, rms_norm

__all__ = ["Model", "build_model"]


def _dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def _stack_init(init_fn, key, n: int):
    """vmap a per-layer init over n layer keys -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


class Model:
    """Functional model wrapper; all state lives in explicit pytrees."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        fam = cfg.family
        if fam in ("dense", "vlm"):
            self._block = B.dense_block
            self._block_init = B.init_dense_block
            self._block_decode = B.dense_block_decode
        elif fam == "moe":
            self._block = B.moe_block
            self._block_init = B.init_moe_block
            self._block_decode = B.moe_block_decode
        elif fam == "ssm":
            self._block = B.mamba2_block
            self._block_init = B.init_mamba2_block
            self._block_decode = B.mamba2_block_decode
        elif fam == "rwkv":
            self._block = B.rwkv6_block
            self._block_init = B.init_rwkv6_block
            self._block_decode = B.rwkv6_block_decode
        elif fam == "hybrid":
            self._block = B.mamba2_block
            self._block_init = B.init_mamba2_block
            self._block_decode = B.mamba2_block_decode
        elif fam == "encdec":
            self._block = B.dense_block
            self._block_init = B.init_dense_block
            self._block_decode = B.dense_block_decode
        else:
            raise ValueError(f"unknown family {fam}")

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        dt = _dtype(cfg)
        k_emb, k_layers, k_extra, k_head = jax.random.split(key, 4)
        params: Dict[str, Any] = {
            "embed": {"vocab": init_dense(k_emb, cfg.vocab_size, cfg.d_model, dt)},
            "layers": _stack_init(
                lambda k: self._block_init(k, cfg, dt), k_layers, cfg.num_layers
            ),
            "final_norm": init_norm(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = init_dense(k_head, cfg.d_model, cfg.vocab_size, dt)
        if cfg.family == "hybrid":
            params["shared"] = B.init_dense_block(k_extra, cfg, dt)
        if cfg.family == "encdec":
            ke1, ke2, ke3 = jax.random.split(k_extra, 3)
            params["encoder"] = {
                "layers": _stack_init(
                    lambda k: B.init_dense_block(k, cfg, dt), ke1,
                    cfg.encoder_layers,
                ),
                "final_norm": init_norm(cfg.d_model, dt),
            }
            params["xattn"] = _stack_init(
                lambda k: B.init_cross_attention(k, cfg, dt), ke2, cfg.num_layers
            )
        return params

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------
    def _embed(self, params, tokens):
        e = jnp.take(params["embed"]["vocab"], tokens, axis=0)
        return e * jnp.asarray(math.sqrt(self.cfg.d_model), e.dtype)

    def _head(self, params, x):
        x = rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        w = (
            params["embed"]["vocab"].T
            if self.cfg.tie_embeddings
            else params["lm_head"]
        )
        logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
        return constrain(logits, "batch", None, "vocab")

    # ------------------------------------------------------------------
    # layer stacks (train / prefill direction)
    # ------------------------------------------------------------------
    def _run_stack(self, stacked, x, positions, *, causal=True, collect_kv=False):
        cfg = self.cfg

        def body(carry, lp):
            h, aux = carry
            h, (a, kv) = self._block(lp, h, cfg, positions, causal=causal) \
                if cfg.family in ("dense", "vlm", "moe", "encdec") \
                else self._block(lp, h, cfg, positions)
            h = constrain(h, "batch", "seq", None)
            out = kv if collect_kv else None
            return (h, aux + a), out

        fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), kvs = lax.scan(fn, (x, jnp.float32(0.0)), stacked)
        return x, aux, kvs

    def _run_hybrid(self, params, x, positions):
        """Zamba2: stacked Mamba2 layers + one shared attention block
        applied every ``attn_every`` layers."""
        cfg = self.cfg
        k = cfg.attn_every
        L = cfg.num_layers
        groups = L // k
        stacked = jax.tree.map(
            lambda a: a.reshape(groups, k, *a.shape[1:]), params["layers"]
        )
        shared = params["shared"]

        def group_body(carry, lp_group):
            h, aux = carry

            def inner(c, lp):
                hh, au = c
                hh, (a, _) = B.mamba2_block(lp, hh, cfg)
                return (hh, au + a), None

            (h, aux), _ = lax.scan(inner, (h, aux), lp_group)
            h, (a, _) = B.dense_block(shared, h, cfg, positions)
            h = constrain(h, "batch", "seq", None)
            return (h, aux + a), None

        fn = jax.checkpoint(group_body) if cfg.remat else group_body
        (x, aux), _ = lax.scan(fn, (x, jnp.float32(0.0)), stacked)
        return x, aux

    # ------------------------------------------------------------------
    # forward (training shapes; returns full logits)
    # ------------------------------------------------------------------
    def forward(self, params, batch: Dict[str, jax.Array]):
        """batch: tokens (B,S) int32 [+ positions / patch_embeds /
        enc_embeds per family].  Returns (logits (B,S,V) f32, aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        Bsz, S = tokens.shape
        x = self._embed(params, tokens)

        if cfg.family == "vlm":
            # prepend precomputed vision patch embeddings (frontend stub)
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)[:, :S]
        x = constrain(x, "batch", "seq", None)

        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (Bsz, S))
        if cfg.mrope and positions.ndim == 2:
            positions = jnp.broadcast_to(positions, (3, *positions.shape))

        if cfg.family == "hybrid":
            x, aux = self._run_hybrid(params, x, positions)
        elif cfg.family == "encdec":
            enc = batch["enc_embeds"].astype(x.dtype)
            enc = constrain(enc, "batch", "seq", None)
            enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1]), enc.shape[:2])
            enc, _, _ = self._run_stack(
                params["encoder"]["layers"], enc, enc_pos, causal=False
            )
            enc = rms_norm(enc, params["encoder"]["final_norm"], cfg.norm_eps)
            x, aux = self._run_encdec_decoder(params, x, positions, enc)
        else:
            x, aux, _ = self._run_stack(params["layers"], x, positions)

        return self._head(params, x), aux

    def _run_encdec_decoder(self, params, x, positions, enc):
        cfg = self.cfg

        def body(carry, lp):
            h, aux = carry
            layer, xa = lp
            h, (a, _) = B.dense_block(layer, h, cfg, positions)
            h = B.cross_attention(xa, h, cfg, B.encode_kv(xa, enc, cfg))
            h = constrain(h, "batch", "seq", None)
            return (h, aux + a), None

        fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = lax.scan(
            fn, (x, jnp.float32(0.0)), (params["layers"], params["xattn"])
        )
        return x, aux

    # ------------------------------------------------------------------
    # prefill: forward + return serving cache and last-position logits
    # ------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        tokens = batch["tokens"]
        Bsz, S = tokens.shape
        x = self._embed(params, tokens)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            patches = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([patches, x], axis=1)[:, :S]
        x = constrain(x, "batch", "seq", None)
        positions = jnp.broadcast_to(jnp.arange(S), (Bsz, S))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions, (3, Bsz, S))

        if cfg.family in ("dense", "vlm", "moe"):
            x, aux, kvs = self._run_stack(
                params["layers"], x, positions, collect_kv=True
            )
            k_cache = constrain(kvs[0].astype(_kv_dtype(cfg)),
                                None, "batch", "kv_seq", None, None)
            v_cache = constrain(kvs[1].astype(_kv_dtype(cfg)),
                                None, "batch", "kv_seq", None, None)
            cache = {"k": k_cache, "v": v_cache}
        elif cfg.family in ("ssm", "rwkv", "hybrid", "encdec"):
            raise NotImplementedError(
                "prefill caches for recurrent/encdec families are built by "
                "their decode drivers"
            )
        logits = self._head(params, x[:, -1:, :])
        return logits[:, 0], cache

    # ------------------------------------------------------------------
    # decode: one token, cache carried
    # ------------------------------------------------------------------
    def init_cache(
        self, batch_size: int, max_len: int, enc_len: Optional[int] = None
    ) -> Dict[str, Any]:
        """Allocate the decode cache (family-specific)."""
        cfg = self.cfg
        L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
        kvdt = _kv_dtype(cfg)
        if cfg.family in ("dense", "vlm", "moe", "encdec"):
            S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            cache = {
                "k": jnp.zeros((L, batch_size, S, KV, hd), kvdt),
                "v": jnp.zeros((L, batch_size, S, KV, hd), kvdt),
                "kpos": jnp.full((S,), -1, jnp.int32),
            }
            if cfg.family == "encdec":
                se = enc_len or max_len
                cache["xk"] = jnp.zeros((L, batch_size, se, KV, hd), _dtype(cfg))
                cache["xv"] = jnp.zeros((L, batch_size, se, KV, hd), _dtype(cfg))
            return cache
        if cfg.family == "ssm":
            return self._mamba_cache(cfg.num_layers, batch_size)
        if cfg.family == "rwkv":
            H = cfg.d_model // cfg.ssm_head_dim
            hd2 = cfg.ssm_head_dim
            return {
                "shift_t": jnp.zeros((L, batch_size, cfg.d_model), _dtype(cfg)),
                "shift_c": jnp.zeros((L, batch_size, cfg.d_model), _dtype(cfg)),
                "wkv": jnp.zeros((L, batch_size, H, hd2, hd2), jnp.float32),
            }
        if cfg.family == "hybrid":
            groups = cfg.num_layers // cfg.attn_every
            S = min(max_len, cfg.sliding_window or max_len)
            c = self._mamba_cache(cfg.num_layers, batch_size)
            c["shared_k"] = jnp.zeros((groups, batch_size, S, KV, hd), kvdt)
            c["shared_v"] = jnp.zeros((groups, batch_size, S, KV, hd), kvdt)
            c["kpos"] = jnp.full((S,), -1, jnp.int32)
            return c
        raise ValueError(cfg.family)

    def _mamba_cache(self, L, batch_size):
        cfg = self.cfg
        d_inner = 2 * cfg.d_model
        H = d_inner // cfg.ssm_head_dim
        conv_ch = d_inner + 2 * cfg.ssm_state
        return {
            "conv": jnp.zeros(
                (L, batch_size, cfg.ssm_conv_width - 1, conv_ch), _dtype(cfg)
            ),
            "ssm": jnp.zeros(
                (L, batch_size, H, cfg.ssm_state, cfg.ssm_head_dim), jnp.float32
            ),
        }

    def decode_step(self, params, cache, tokens: jax.Array, t: jax.Array):
        """tokens: (B,) int32 current input token; t: scalar position.
        Returns (logits (B,V) f32, updated cache)."""
        cfg = self.cfg
        Bsz = tokens.shape[0]
        x = self._embed(params, tokens[:, None])
        pos = jnp.broadcast_to(t, (Bsz, 1))
        if cfg.mrope:
            pos = jnp.broadcast_to(pos, (3, Bsz, 1))

        if cfg.family == "encdec":
            x, cache = self._decode_encdec(params, cache, x, t, pos)
        elif cfg.family in ("dense", "vlm", "moe"):
            S = cache["k"].shape[2]
            slot = t % S
            if cfg.cache_update == "deferred":
                from repro.models.layers import ring_update_stacked

                # mask the stale slot row during attention; new (k, v)
                # rows are attended explicitly and written once for all
                # layers after the scan (one sharded update)
                kpos_mask = jnp.where(
                    jnp.arange(S) == slot, -1, cache["kpos"]
                )

                def body(h, inp):
                    lp, kc, vc = inp
                    h, (k_new, v_new) = self._block_decode(
                        lp, h, cfg, kc, vc, t, pos, kpos_mask
                    )
                    return h, (k_new, v_new)

                x, (k_rows, v_rows) = lax.scan(
                    body, x, (params["layers"], cache["k"], cache["v"])
                )
                kpos = jnp.where(jnp.arange(S) == slot, t, cache["kpos"])
                cache = {
                    "k": ring_update_stacked(cache["k"], k_rows, slot),
                    "v": ring_update_stacked(cache["v"], v_rows, slot),
                    "kpos": kpos,
                }
            else:
                kpos = jnp.where(jnp.arange(S) == slot, t, cache["kpos"])

                def body(h, inp):
                    lp, kc, vc = inp
                    h, (kc, vc) = self._block_decode(
                        lp, h, cfg, kc, vc, t, pos, kpos
                    )
                    return h, (kc, vc)

                x, (k_new, v_new) = lax.scan(
                    body, x, (params["layers"], cache["k"], cache["v"])
                )
                cache = {"k": k_new, "v": v_new, "kpos": kpos}
        elif cfg.family == "ssm":
            def body(h, inp):
                lp, conv, ssm = inp
                h, (conv, ssm) = B.mamba2_block_decode(lp, h, cfg, conv, ssm)
                return h, (conv, ssm)

            x, (conv, ssm) = lax.scan(
                body, x, (params["layers"], cache["conv"], cache["ssm"])
            )
            cache = {"conv": conv, "ssm": ssm}
        elif cfg.family == "rwkv":
            def body(h, inp):
                lp, st, sc, wkv = inp
                h, (st, sc, wkv) = B.rwkv6_block_decode(lp, h, cfg, st, sc, wkv)
                return h, (st, sc, wkv)

            x, (st, sc, wkv) = lax.scan(
                body,
                x,
                (params["layers"], cache["shift_t"], cache["shift_c"], cache["wkv"]),
            )
            cache = {"shift_t": st, "shift_c": sc, "wkv": wkv}
        elif cfg.family == "hybrid":
            x, cache = self._decode_hybrid(params, cache, x, t, pos)
        else:
            raise ValueError(cfg.family)

        logits = self._head(params, x)
        return logits[:, 0], cache

    def _decode_hybrid(self, params, cache, x, t, pos):
        cfg = self.cfg
        k = cfg.attn_every
        groups = cfg.num_layers // k
        S = cache["shared_k"].shape[2]
        slot = t % S
        kpos = jnp.where(jnp.arange(S) == slot, t, cache["kpos"])
        g = lambda a: jax.tree.map(
            lambda v: v.reshape(groups, k, *v.shape[1:]), a
        )
        stacked = g(params["layers"])
        conv_g = cache["conv"].reshape(groups, k, *cache["conv"].shape[1:])
        ssm_g = cache["ssm"].reshape(groups, k, *cache["ssm"].shape[1:])

        def group_body(h, inp):
            lp_group, conv, ssm, kc, vc = inp

            def inner(hh, ii):
                lpi, ci, si = ii
                hh, (ci, si) = B.mamba2_block_decode(lpi, hh, cfg, ci, si)
                return hh, (ci, si)

            h, (conv, ssm) = lax.scan(inner, h, (lp_group, conv, ssm))
            h, (kc, vc) = B.dense_block_decode(
                params["shared"], h, cfg, kc, vc, t, pos, kpos
            )
            return h, (conv, ssm, kc, vc)

        x, (conv, ssm, kc, vc) = lax.scan(
            group_body, x,
            (stacked, conv_g, ssm_g, cache["shared_k"], cache["shared_v"]),
        )
        cache = {
            "conv": conv.reshape(cfg.num_layers, *conv.shape[2:]),
            "ssm": ssm.reshape(cfg.num_layers, *ssm.shape[2:]),
            "shared_k": kc,
            "shared_v": vc,
            "kpos": kpos,
        }
        return x, cache


    # ------------------------------------------------------------------
    # encoder-decoder serving helpers
    # ------------------------------------------------------------------
    def encode(self, params, enc_embeds: jax.Array) -> jax.Array:
        """Run the encoder once over frontend-stub embeddings."""
        cfg = self.cfg
        enc = constrain(enc_embeds.astype(_dtype(cfg)), "batch", "seq", None)
        pos = jnp.broadcast_to(jnp.arange(enc.shape[1]), enc.shape[:2])
        enc, _, _ = self._run_stack(
            params["encoder"]["layers"], enc, pos, causal=False
        )
        return rms_norm(enc, params["encoder"]["final_norm"], cfg.norm_eps)

    def make_cross_cache(self, params, enc_out: jax.Array):
        """Precompute per-layer cross-attention K/V (reused every decode
        step): (L, B, S_enc, KV, hd) pair."""
        cfg = self.cfg
        ks, vs = jax.vmap(lambda xa: B.encode_kv(xa, enc_out, cfg))(
            params["xattn"]
        )
        return ks, vs

    def _decode_encdec(self, params, cache, x, t, pos):
        cfg = self.cfg
        S = cache["k"].shape[2]
        slot = t % S
        kpos = jnp.where(jnp.arange(S) == slot, t, cache["kpos"])

        def body(h, inp):
            lp, xa, kc, vc, xk, xv = inp
            h, (kc, vc) = B.dense_block_decode(lp, h, cfg, kc, vc, t, pos, kpos)
            h = B.cross_attention(xa, h, cfg, (xk, xv))
            return h, (kc, vc)

        x, (k_new, v_new) = lax.scan(
            body,
            x,
            (params["layers"], params["xattn"], cache["k"], cache["v"],
             cache["xk"], cache["xv"]),
        )
        return x, {
            "k": k_new, "v": v_new, "kpos": kpos,
            "xk": cache["xk"], "xv": cache["xv"],
        }


def _kv_dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "int8": jnp.int8}[cfg.kv_cache_dtype]


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
