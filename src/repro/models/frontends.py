"""Modality frontend STUBS (per the assignment: "the modality frontend is
a STUB — input_specs() provides precomputed frame/patch embeddings").

The backbone consumes (B, S, d_model) embeddings; these helpers define
the stub shapes and, for smoke tests, generate random embeddings.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

__all__ = ["audio_frame_spec", "vision_patch_spec", "mrope_position_spec",
           "random_frontend_batch"]


def audio_frame_spec(cfg: ModelConfig, batch: int, frames: int):
    """Precomputed audio frame embeddings (seamless-m4t speech encoder
    input after the conformer feature stub)."""
    return jax.ShapeDtypeStruct((batch, frames, cfg.d_model), jnp.bfloat16)


def vision_patch_spec(cfg: ModelConfig, batch: int):
    """Precomputed vision patch embeddings (qwen2-vl ViT stub)."""
    return jax.ShapeDtypeStruct((batch, cfg.num_patches, cfg.d_model), jnp.bfloat16)


def mrope_position_spec(batch: int, seq: int):
    """(3, B, S) t/h/w position ids for M-RoPE (text tokens share all
    three streams; patch tokens get spatial ids)."""
    return jax.ShapeDtypeStruct((3, batch, seq), jnp.int32)


def random_frontend_batch(cfg: ModelConfig, key, batch: int, seq: int) -> Dict:
    """Random stub tensors for smoke tests."""
    out = {}
    if cfg.frontend == "audio":
        out["enc_embeds"] = (
            jax.random.normal(key, (batch, seq, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
    elif cfg.frontend == "vision":
        out["patch_embeds"] = (
            jax.random.normal(key, (batch, cfg.num_patches, cfg.d_model)) * 0.02
        ).astype(jnp.bfloat16)
        # t/h/w ids: patches get a 16x16-ish grid, text advances t
        npatch = cfg.num_patches
        side = int(npatch ** 0.5)
        t = jnp.concatenate([jnp.zeros((npatch,), jnp.int32),
                             jnp.arange(1, seq - npatch + 1)])
        h = jnp.concatenate([jnp.repeat(jnp.arange(side), side),
                             jnp.arange(1, seq - npatch + 1)])
        w = jnp.concatenate([jnp.tile(jnp.arange(side), side),
                             jnp.arange(1, seq - npatch + 1)])
        pos3 = jnp.stack([t, h, w])[:, None, :].repeat(batch, 1)
        out["positions"] = pos3
    return out
