"""Chunked linear attention with decay — the shared recurrence behind
Mamba2 (SSD, scalar per-head decay) and RWKV6 (Finch, data-dependent
per-channel decay).

State per head: S in R^{dk x dv}.

scalar decay (Mamba2, inclusive of current token):
    S_t = exp(a_t) * S_{t-1} + k_t v_t^T          y_t = q_t @ S_t

vector decay (RWKV6, exclusive + bonus u):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T           y_t = q_t @ (S_{t-1} + diag(u) k_t v_t^T)

Training uses the chunkwise-parallel form (intra-chunk attention matrix +
inter-chunk state carry, scanned over chunks); decoding uses the O(1)
single-step update.  fp32 state and accumulators.

Numerical note (vector decay): the chunk form rescales keys by
exp(-cumsum(log w)); per-step log-decay is clamped to >= -LOG_CLAMP so
the within-chunk cumulative stays in fp32 range (chunk 32 x 1.2 = 38.4
=> exp() <= 5e16).  Exactness vs. the sequential reference is preserved
whenever decays respect the clamp (tests check both paths agree).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "chunked_scalar_decay",
    "chunked_vector_decay",
    "step_scalar_decay",
    "step_vector_decay",
    "LOG_CLAMP",
    "VEC_CHUNK",
]

LOG_CLAMP = 1.2   # max |log decay| per step for the vector-decay path
VEC_CHUNK = 32
SCALAR_CHUNK = 64


def _split_chunks(x: jax.Array, n: int) -> jax.Array:
    """(B, S, ...) -> (n, B, S/n, ...) for scanning."""
    B, S = x.shape[:2]
    return jnp.moveaxis(x.reshape(B, n, S // n, *x.shape[2:]), 1, 0)


# ---------------------------------------------------------------------------
# scalar decay (Mamba2 SSD)
# ---------------------------------------------------------------------------

def chunked_scalar_decay(
    q: jax.Array,            # (B, S, H, dk) — or (B, S, dk) shared heads
    k: jax.Array,            # (B, S, H, dk) — or (B, S, dk) shared heads
    v: jax.Array,            # (B, S, H, dv)
    log_decay: jax.Array,    # (B, S, H)  <= 0
    state0: Optional[jax.Array] = None,  # (B, H, dk, dv) fp32
    chunk: int = SCALAR_CHUNK,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,dv), final state (B,H,dk,dv)).

    Mamba2's B/C projections are shared across heads (ngroups=1): pass
    them 3D and the head broadcast happens per-chunk inside the scan —
    materializing (B,S,H,dk) in HBM costs H x the traffic (the dominant
    memory term of the hybrid/ssm train cells before this change)."""
    B, S = q.shape[:2]
    H = v.shape[2]
    dk = q.shape[-1]
    dv = v.shape[-1]
    shared = q.ndim == 3
    n = max(S // chunk, 1)
    chunk = S // n
    assert S % n == 0

    if state0 is None:
        state0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    qc, kc, vc = (_split_chunks(x, n) for x in (q, k, v))
    ldc = _split_chunks(log_decay.astype(jnp.float32), n)

    def step(S_in, inp):
        qb, kb, vb, ld = inp                       # (B, C, H, *)
        if shared:
            qb = jnp.broadcast_to(qb[:, :, None, :], (B, chunk, H, dk))
            kb = jnp.broadcast_to(kb[:, :, None, :], (B, chunk, H, dk))
        cum = jnp.cumsum(ld, axis=1)               # inclusive (B, C, H)
        # inter-chunk: y += (q_t e^{cum_t}) @ S_in
        q_in = qb.astype(jnp.float32) * jnp.exp(cum)[..., None]
        y_inter = jnp.einsum("bchk,bhkv->bchv", q_in, S_in)
        # intra-chunk: A[t,tau] = (q_t . k_tau) e^{cum_t - cum_tau}, tau <= t
        logits = jnp.einsum(
            "bchk,bghk->bhcg", qb.astype(jnp.float32), kb.astype(jnp.float32)
        )
        rel = cum[:, :, None, :] - cum[:, None, :, :]       # (B, C, G, H)
        rel = jnp.moveaxis(rel, -1, 1)                      # (B, H, C, G)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        A = jnp.where(tri[None, None], logits * jnp.exp(rel), 0.0)
        y_intra = jnp.einsum("bhcg,bghv->bchv", A, vb.astype(jnp.float32))
        # state update: S_out = e^{cum_C} S_in + sum_tau e^{cum_C - cum_tau} k v
        decay_all = jnp.exp(cum[:, -1, :])                  # (B, H)
        k_scaled = kb.astype(jnp.float32) * jnp.exp(
            cum[:, -1:, :] - cum
        )[..., None]
        S_out = (
            S_in * decay_all[..., None, None]
            + jnp.einsum("bchk,bchv->bhkv", k_scaled, vb.astype(jnp.float32))
        )
        return S_out, y_inter + y_intra

    state, ys = lax.scan(step, state0, (qc, kc, vc, ldc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dv)
    return y.astype(v.dtype), state


def step_scalar_decay(q, k, v, log_decay, state):
    """Decode step.  q,k: (B,H,dk), v: (B,H,dv), log_decay: (B,H),
    state: (B,H,dk,dv).  Returns (y (B,H,dv), state)."""
    state = state * jnp.exp(log_decay.astype(jnp.float32))[..., None, None]
    state = state + jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# vector decay (RWKV6)
# ---------------------------------------------------------------------------

def chunked_vector_decay(
    q: jax.Array,            # (B, S, H, dk)   ("r" in RWKV)
    k: jax.Array,            # (B, S, H, dk)
    v: jax.Array,            # (B, S, H, dv)
    log_decay: jax.Array,    # (B, S, H, dk)  <= 0   (log w_t)
    bonus: jax.Array,        # (H, dk)  u
    state0: Optional[jax.Array] = None,
    chunk: int = VEC_CHUNK,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,dv), final state (B,H,dk,dv))."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    n = max(S // chunk, 1)
    chunk = S // n
    assert S % n == 0

    if state0 is None:
        state0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    ld = jnp.clip(log_decay.astype(jnp.float32), -LOG_CLAMP, 0.0)
    qc, kc, vc = (_split_chunks(x, n) for x in (q, k, v))
    ldc = _split_chunks(ld, n)

    def step(S_in, inp):
        qb, kb, vb, ldb = inp                     # (B, C, H, *)
        cum = jnp.cumsum(ldb, axis=1)             # inclusive  (B,C,H,dk)
        cum_ex = cum - ldb                        # exclusive
        q_in = qb.astype(jnp.float32) * jnp.exp(cum_ex)
        y_inter = jnp.einsum("bchk,bhkv->bchv", q_in, S_in)
        k_resc = kb.astype(jnp.float32) * jnp.exp(-cum)
        # strict lower triangular intra-chunk attention
        A = jnp.einsum("bchk,bghk->bhcg", q_in, k_resc)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        A = jnp.where(tri[None, None], A, 0.0)
        y_intra = jnp.einsum("bhcg,bghv->bchv", A, vb.astype(jnp.float32))
        # bonus (current token) term
        qk = jnp.einsum(
            "bchk,hk,bchk->bch",
            qb.astype(jnp.float32),
            bonus.astype(jnp.float32),
            kb.astype(jnp.float32),
        )
        y_bonus = qk[..., None] * vb.astype(jnp.float32)
        # state carry
        W_C = jnp.exp(cum[:, -1])                 # (B,H,dk)
        k_carry = kb.astype(jnp.float32) * jnp.exp(cum[:, -1:] - cum)
        S_out = S_in * W_C[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", k_carry, vb.astype(jnp.float32)
        )
        return S_out, y_inter + y_intra + y_bonus

    state, ys = lax.scan(step, state0, (qc, kc, vc, ldc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dv)
    return y.astype(v.dtype), state


def step_vector_decay(q, k, v, log_decay, bonus, state):
    """Decode step.  q,k,log_decay: (B,H,dk), v: (B,H,dv), bonus: (H,dk),
    state: (B,H,dk,dv)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    w = jnp.exp(jnp.clip(log_decay.astype(jnp.float32), -LOG_CLAMP, 0.0))
    att = state + bonus.astype(jnp.float32)[None, :, :, None] * jnp.einsum(
        "bhk,bhv->bhkv", kf, vf
    )
    y = jnp.einsum("bhk,bhkv->bhv", qf, att)
    state = state * w[..., None] + jnp.einsum("bhk,bhv->bhkv", kf, vf)
    return y.astype(v.dtype), state
