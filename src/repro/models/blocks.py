"""Per-layer blocks for every assigned architecture family.

Each block is a pure function ``block(params, x, ctx) -> (x, aux)`` with
an optional decode variant carrying per-layer state.  Parameters are
plain dicts whose key paths drive sharding (repro.distributed.sharding).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import linear_attn as la
from repro.models.layers import (
    decode_attention,
    flash_attention,
    gated_mlp,
    init_dense,
    init_norm,
    mrope,
    rms_norm,
    rope,
)

__all__ = [
    "init_dense_block",
    "dense_block",
    "dense_block_decode",
    "init_moe_block",
    "moe_block",
    "init_mamba2_block",
    "mamba2_block",
    "mamba2_block_decode",
    "init_rwkv6_block",
    "rwkv6_block",
    "rwkv6_block_decode",
    "init_cross_attention",
    "cross_attention",
]


# ===========================================================================
# attention (GQA + bias + qk_norm + SWA + RoPE/M-RoPE)
# ===========================================================================

def _init_attn(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "norm": init_norm(D, dtype),
        "wq": init_dense(ks[0], D, H * hd, dtype),
        "wk": init_dense(ks[1], D, KV * hd, dtype),
        "wv": init_dense(ks[2], D, KV * hd, dtype),
        "wo": init_dense(ks[3], H * hd, D, dtype),
    }
    if cfg.qkv_bias:
        p["bias_q"] = jnp.zeros((H * hd,), dtype)
        p["bias_k"] = jnp.zeros((KV * hd,), dtype)
        p["bias_v"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd, dtype)
        p["k_norm"] = init_norm(hd, dtype)
    return p


def _project_qkv(p, x, cfg: ModelConfig):
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bias_q"], k + p["bias_k"], v + p["bias_v"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _apply_rope(q, k, cfg: ModelConfig, positions):
    if cfg.mrope:
        return mrope(q, k, positions, cfg.mrope_sections, cfg.rope_theta)
    return rope(q, k, positions, cfg.rope_theta)


def attention(p, x, cfg: ModelConfig, positions, *, causal=True):
    """Full-sequence attention (training / prefill).  positions: (B,S)
    int32 — or (3,B,S) for M-RoPE."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    q, k = _apply_rope(q, k, cfg, positions)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    o = flash_attention(
        q, k, v, causal=causal, window=cfg.sliding_window
    )
    o = o.reshape(*x.shape[:2], -1) @ p["wo"]
    return x + o, (k, v)


def attention_decode(p, x, cfg: ModelConfig, k_cache, v_cache, t, positions,
                     kpos=None):
    """Single-token attention against the cache.  x: (B,1,D); caches:
    (B,S,KV,hd) (S possibly sequence-sharded); t: scalar current pos;
    kpos: (S,) absolute position of each slot incl. the current token
    (rolling ring buffer for SWA) — None for plain arange caches."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = _project_qkv(p, h, cfg)
    q, k = _apply_rope(q, k, cfg, positions)
    # ring-buffer cache write at position t % S (rolling for SWA):
    # "dus" = in-place dynamic-update-slice (XLA aliases the donated
    # buffer: traffic = one row); "onehot" = masked full rewrite (the
    # naive baseline kept for the perf-iteration comparison)
    S = k_cache.shape[1]
    slot = t % S
    if cfg.cache_update == "deferred":
        # don't write the cache here: return the new row; the model-level
        # driver batches all layers' writes into one sharded update.
        # kpos must exclude the stale slot row (caller ensures it).
        o = decode_attention(
            q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), t,
            window=cfg.sliding_window, kpos=kpos, current=(k, v),
        )
        o = o.reshape(x.shape[0], 1, -1) @ p["wo"]
        return x + o, (k, v)
    if cfg.cache_update == "ring":
        from repro.models.layers import ring_update
        k_cache = ring_update(k_cache, k, slot)
        v_cache = ring_update(v_cache, v, slot)
    elif cfg.cache_update == "dus":
        zero = jnp.zeros((), jnp.int32)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (zero, slot, zero, zero))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (zero, slot, zero, zero))
    else:
        onehot = (jnp.arange(S) == slot).astype(k_cache.dtype)[None, :, None, None]
        k_cache = k_cache * (1 - onehot) + k.astype(k_cache.dtype) * onehot
        v_cache = v_cache * (1 - onehot) + v.astype(v_cache.dtype) * onehot
    o = decode_attention(
        q, k_cache.astype(q.dtype), v_cache.astype(q.dtype), t,
        window=cfg.sliding_window, kpos=kpos,
    )
    o = o.reshape(x.shape[0], 1, -1) @ p["wo"]
    return x + o, (k_cache, v_cache)


# ===========================================================================
# dense transformer block
# ===========================================================================

def init_dense_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ka, km = jax.random.split(key)
    kg, ki, ko = jax.random.split(km, 3)
    return {
        "attn": _init_attn(ka, cfg, dtype),
        "mlp": {
            "norm": init_norm(cfg.d_model, dtype),
            "w_gate": init_dense(kg, cfg.d_model, cfg.d_ff, dtype),
            "w_in": init_dense(ki, cfg.d_model, cfg.d_ff, dtype),
            "w_out": init_dense(ko, cfg.d_ff, cfg.d_model, dtype),
        },
    }


def _mlp_res(p, x, cfg):
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    return x + gated_mlp(p, h, cfg.activation)


def dense_block(p, x, cfg: ModelConfig, positions, *, causal=True):
    x, kv = attention(p["attn"], x, cfg, positions, causal=causal)
    x = constrain(x, "batch", "seq", None)
    x = _mlp_res(p["mlp"], x, cfg)
    return x, (jnp.float32(0.0), kv)


def dense_block_decode(p, x, cfg: ModelConfig, k_cache, v_cache, t, positions,
                       kpos=None):
    x, (k_cache, v_cache) = attention_decode(
        p["attn"], x, cfg, k_cache, v_cache, t, positions, kpos
    )
    x = _mlp_res(p["mlp"], x, cfg)
    return x, (k_cache, v_cache)


# ===========================================================================
# cross-attention (encoder-decoder)
# ===========================================================================

def init_cross_attention(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    return _init_attn(key, cfg, dtype)


def cross_attention(p, x, cfg: ModelConfig, enc_kv):
    """Decoder cross-attention; enc_kv = (k, v) precomputed from encoder
    output: (B, S_enc, KV, hd)."""
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = enc_kv
    o = flash_attention(q, k, v, causal=False)
    return x + o.reshape(B, S, -1) @ p["wo"]


def encode_kv(p, enc_out, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (once per
    sequence; reused by every decode step)."""
    B, S, _ = enc_out.shape
    KV, hd = cfg.num_kv_heads, cfg.hd
    k = (enc_out @ p["wk"]).reshape(B, S, KV, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


# ===========================================================================
# MoE block (top-2, GShard-style grouped capacity dispatch)
# ===========================================================================

def init_moe_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ka, kr, kg, ki, ko = jax.random.split(key, 5)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    scale = 1.0 / math.sqrt(D)
    fscale = 1.0 / math.sqrt(F)

    def expert(k, din, dout, s):
        return (jax.random.normal(k, (E, din, dout), jnp.float32) * s).astype(dtype)

    return {
        "attn": _init_attn(ka, cfg, dtype),
        "moe": {
            "norm": init_norm(D, dtype),
            "router": init_dense(kr, D, E, jnp.float32),  # fp32 router
            "w_gate": expert(kg, D, F, scale),
            "w_in": expert(ki, D, F, scale),
            "w_out": expert(ko, F, D, fscale),
        },
    }


def moe_ffn(p, x, cfg: ModelConfig):
    """Grouped top-k dispatch with capacity (GShard), mesh-aligned.

    Groups are (batch, seq-block) pairs — reshaping (B, S, D) to
    (B, S/gs, gs, D) only *splits* the sequence dim, so when B is
    data-sharded and S model-sharded the grouping moves NO bytes (the
    flat (B*S/gs, gs) form re-partitions the whole activation tensor
    across the mesh every layer — measured 4.3 TB/dev of all-gather on
    mixtral train_4k; see EXPERIMENTS.md §Perf iteration M2).

    Returns (y, aux) where aux is the load-balancing loss.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    gs = min(cfg.moe_group_size, S)
    nsb = S // gs
    xg = x.reshape(B, nsb, gs, D)
    xg = constrain(xg, "batch", "seq", None, None)
    cap = max(int(gs * K / E * cfg.moe_capacity_factor), 1)

    logits = jnp.einsum(
        "bnsd,de->bnse", xg.astype(jnp.float32), p["router"]
    )
    probs = jax.nn.softmax(logits, axis=-1)
    density = jnp.mean(probs, axis=2)                       # (B,n,E)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), E)
    density_hard = jnp.mean(top1, axis=2)
    aux = E * jnp.mean(jnp.sum(density * density_hard, -1))

    gate_vals, gate_idx = lax.top_k(probs, K)               # (B,n,gs,K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (B,n,gs,K,E)
    flat = onehot.reshape(B, nsb, gs * K, E)
    pos = jnp.cumsum(flat, axis=2) - flat
    pos = pos.reshape(B, nsb, gs, K, E)
    keep = (pos < cap) * onehot
    slot = jnp.einsum("bnske->bnsk", pos * keep).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(slot, cap, dtype=jnp.float32)
    dispatch = jnp.einsum("bnske,bnskc->bnsec", keep, slot_oh)
    combine = jnp.einsum("bnsk,bnske,bnskc->bnsec", gate_vals, keep, slot_oh)

    xin = jnp.einsum("bnsec,bnsd->ebncd", dispatch, xg.astype(jnp.float32))
    # "tp": seq-blocks gathered over model, expert hidden sharded over
    #       model (GShard baseline); "dp": tokens stay fully sharded and
    #       expert weights gather (REFUTED for the 100B archs: weight
    #       gathers dominate — kept for ablation)
    if cfg.moe_parallel == "dp":
        seq_ax, ff_ax = "seq", None
    else:
        seq_ax, ff_ax = None, "d_ff"
    xin = constrain(xin.astype(x.dtype), "expert", "batch", seq_ax, None, None)
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.activation]
    h = act(jnp.einsum("ebncd,edf->ebncf", xin, p["w_gate"])) * jnp.einsum(
        "ebncd,edf->ebncf", xin, p["w_in"]
    )
    h = constrain(h, "expert", "batch", seq_ax, None, ff_ax)
    out = jnp.einsum("ebncf,efd->ebncd", h, p["w_out"])
    y = jnp.einsum("bnsec,ebncd->bnsd", combine.astype(x.dtype), out)
    return y.reshape(B, S, D), aux


def moe_block(p, x, cfg: ModelConfig, positions, *, causal=True):
    x, kv = attention(p["attn"], x, cfg, positions, causal=causal)
    x = constrain(x, "batch", "seq", None)
    h = rms_norm(x, p["moe"]["norm"], cfg.norm_eps)
    y, aux = moe_ffn(p["moe"], h, cfg)
    return x + y, (aux, kv)


def moe_block_decode(p, x, cfg: ModelConfig, k_cache, v_cache, t, positions,
                     kpos=None):
    x, (k_cache, v_cache) = attention_decode(
        p["attn"], x, cfg, k_cache, v_cache, t, positions, kpos
    )
    h = rms_norm(x, p["moe"]["norm"], cfg.norm_eps)
    y, _ = moe_ffn(p["moe"], h, cfg)
    return x + y, (k_cache, v_cache)


# ===========================================================================
# Mamba2 block (SSD with scalar per-head decay)
# ===========================================================================

def _mamba_dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    ds = cfg.ssm_state
    conv_ch = d_inner + 2 * ds
    return d_inner, H, ds, conv_ch


def init_mamba2_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    d_inner, H, ds, conv_ch = _mamba_dims(cfg)
    D = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    d_proj = 2 * d_inner + 2 * ds + H  # z, xBC, dt
    return {
        "ssm": {
            "norm": init_norm(D, dtype),
            "in_proj": init_dense(k1, D, d_proj, dtype),
            "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, conv_ch),
                                         jnp.float32) * 0.2).astype(dtype),
            "conv_bias": jnp.zeros((conv_ch,), dtype),
            "A_log": jnp.zeros((H,), jnp.float32),        # A = -exp(0) = -1
            "dt_bias": jnp.zeros((H,), jnp.float32),
            "skip_D": jnp.ones((H,), jnp.float32),
            "out_norm": init_norm(d_inner, dtype),
            "out_proj": init_dense(k3, d_inner, D, dtype),
        }
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d.  x: (B,S,C); w: (width,C)."""
    width, C = w.shape
    # dimension numbers: NHC x HIO -> NHC, depthwise via feature_group_count
    out = lax.conv_general_dilated(
        x,
        w.astype(x.dtype)[:, None, :],  # (H=width, I=1, O=C)
        window_strides=(1,),
        padding=[(width - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=C,
    )
    return out + b.astype(x.dtype)


def _mamba_inner(p, h, cfg, conv_in_state=None):
    """Shared projection/conv/split for train+decode.  h: (B,S,D)."""
    d_inner, H, ds, conv_ch = _mamba_dims(cfg)
    proj = h @ p["in_proj"]
    z, xBC, dt = jnp.split(proj, [d_inner, d_inner + conv_ch], axis=-1)
    return z, xBC, dt


def mamba2_block(p, x, cfg: ModelConfig, positions=None):
    ps = p["ssm"]
    d_inner, H, ds, conv_ch = _mamba_dims(cfg)
    B, S, D = x.shape
    h = rms_norm(x, ps["norm"], cfg.norm_eps)
    z, xBC, dt = _mamba_inner(ps, h, cfg)
    xBC = jax.nn.silu(_causal_conv(xBC, ps["conv_w"], ps["conv_bias"]))
    xc, B_, C_ = jnp.split(xBC, [d_inner, d_inner + ds], axis=-1)
    hd = cfg.ssm_head_dim
    v = xc.reshape(B, S, H, hd)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + ps["dt_bias"])   # (B,S,H)
    log_decay = -jnp.exp(ps["A_log"])[None, None, :] * dtp
    # B_/C_ are shared across heads (ngroups=1): pass 3D, broadcast
    # per-chunk inside the recurrence (saves H x HBM traffic)
    y, _ = la.chunked_scalar_decay(
        C_, B_, v * dtp[..., None].astype(v.dtype), log_decay
    )
    y = y + ps["skip_D"].astype(v.dtype)[None, None, :, None] * v
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), ps["out_norm"], cfg.norm_eps)
    return x + y @ ps["out_proj"], (jnp.float32(0.0), None)


def mamba2_block_decode(p, x, cfg: ModelConfig, conv_state, ssm_state):
    """x: (B,1,D); conv_state: (B,width-1,conv_ch); ssm_state:
    (B,H,ds,hd) fp32."""
    ps = p["ssm"]
    d_inner, H, ds, conv_ch = _mamba_dims(cfg)
    B = x.shape[0]
    h = rms_norm(x, ps["norm"], cfg.norm_eps)
    z, xBC, dt = _mamba_inner(ps, h, cfg)
    window = jnp.concatenate([conv_state, xBC], axis=1)  # (B,width,ch)
    conv_state = window[:, 1:]
    conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                      ps["conv_w"].astype(jnp.float32)) + ps["conv_bias"].astype(jnp.float32)
    xBC1 = jax.nn.silu(conv).astype(x.dtype)
    xc, B_, C_ = jnp.split(xBC1, [d_inner, d_inner + ds], axis=-1)
    hd = cfg.ssm_head_dim
    v = xc.reshape(B, H, hd)
    dtp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + ps["dt_bias"])  # (B,H)
    log_decay = -jnp.exp(ps["A_log"])[None, :] * dtp
    k = jnp.broadcast_to(B_[:, None, :], (B, H, ds))
    q = jnp.broadcast_to(C_[:, None, :], (B, H, ds))
    y, ssm_state = la.step_scalar_decay(
        q, k, v * dtp[..., None].astype(v.dtype), log_decay, ssm_state
    )
    y = y + ps["skip_D"].astype(v.dtype)[None, :, None] * v
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), ps["out_norm"], cfg.norm_eps)
    return x + y @ ps["out_proj"], (conv_state, ssm_state)


# ===========================================================================
# RWKV6 block (Finch: data-dependent per-channel decay)
# ===========================================================================

def _rwkv_dims(cfg: ModelConfig):
    hd = cfg.ssm_head_dim
    H = cfg.d_model // hd
    return H, hd


def init_rwkv6_block(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    D, F = cfg.d_model, cfg.d_ff
    H, hd = _rwkv_dims(cfg)
    ks = jax.random.split(key, 10)
    lora = 64
    p = {
        "norm_t": init_norm(D, dtype),
        "norm_c": init_norm(D, dtype),
        "ln_x": init_norm(D, dtype),
        "u": (jax.random.normal(ks[0], (H, hd), jnp.float32) * 0.1),
        "w0": jnp.full((D,), -2.0, jnp.float32),  # w = exp(-exp(w0)) ~ 0.87
        "wr": init_dense(ks[1], D, D, dtype),
        "wk": init_dense(ks[2], D, D, dtype),
        "wv": init_dense(ks[3], D, D, dtype),
        "wg": init_dense(ks[4], D, D, dtype),
        "wo": init_dense(ks[5], D, D, dtype),
        "w_lora_a": init_dense(ks[6], D, lora, dtype),
        "w_lora_b": (jax.random.normal(ks[7], (lora, D), jnp.float32) * 0.01).astype(dtype),
        "ck": init_dense(ks[8], D, F, dtype),
        "cv": init_dense(ks[9], F, D, dtype),
        "cr": init_dense(jax.random.fold_in(key, 99), D, D, dtype),
    }
    for name in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w", "mu_ck", "mu_cr"):
        p[name] = jnp.full((D,), 0.5, dtype)
    return {"rwkv": p}


def _shift(x, last):
    """Token shift: previous token's features.  x: (B,S,D); last: (B,D)
    from the previous segment (zeros at sequence start)."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def rwkv6_block(p, x, cfg: ModelConfig, positions=None, shift_t=None, shift_c=None):
    pr = p["rwkv"]
    B, S, D = x.shape
    H, hd = _rwkv_dims(cfg)
    if shift_t is None:
        shift_t = jnp.zeros((B, D), x.dtype)
    if shift_c is None:
        shift_c = jnp.zeros((B, D), x.dtype)

    # --- time mix ---
    h = rms_norm(x, pr["norm_t"], cfg.norm_eps)
    hx = _shift(h, shift_t)

    def mixed(mu):
        return h + (hx - h) * mu

    r = (mixed(pr["mu_r"]) @ pr["wr"]).reshape(B, S, H, hd)
    k = (mixed(pr["mu_k"]) @ pr["wk"]).reshape(B, S, H, hd)
    v = (mixed(pr["mu_v"]) @ pr["wv"]).reshape(B, S, H, hd)
    g = mixed(pr["mu_g"]) @ pr["wg"]
    # data-dependent decay (the Finch contribution): w0 + lora(x)
    ww = pr["w0"] + (
        jnp.tanh(mixed(pr["mu_w"]) @ pr["w_lora_a"]) @ pr["w_lora_b"]
    ).astype(jnp.float32)
    log_decay = -jnp.exp(ww).reshape(B, S, H, hd)

    y, _ = la.chunked_vector_decay(r, k, v, log_decay, pr["u"])
    y = rms_norm(y.reshape(B, S, D), pr["ln_x"], cfg.norm_eps)
    x = x + (y * jax.nn.silu(g)) @ pr["wo"]

    # --- channel mix ---
    h2 = rms_norm(x, pr["norm_c"], cfg.norm_eps)
    h2x = _shift(h2, shift_c)
    kk = h2 + (h2x - h2) * pr["mu_ck"]
    rr = h2 + (h2x - h2) * pr["mu_cr"]
    kk = jnp.square(jax.nn.relu(kk @ pr["ck"]))
    x = x + jax.nn.sigmoid(rr @ pr["cr"]) * (kk @ pr["cv"])
    return x, (jnp.float32(0.0), (h[:, -1, :], h2[:, -1, :]))


def rwkv6_block_decode(p, x, cfg: ModelConfig, shift_t, shift_c, wkv_state):
    """x: (B,1,D); shift_t/c: (B,D); wkv_state: (B,H,hd,hd) fp32."""
    pr = p["rwkv"]
    B, _, D = x.shape
    H, hd = _rwkv_dims(cfg)

    h = rms_norm(x, pr["norm_t"], cfg.norm_eps)[:, 0]     # (B,D)
    hx = shift_t

    def mixed(mu):
        return h + (hx - h) * mu

    r = (mixed(pr["mu_r"]) @ pr["wr"]).reshape(B, H, hd)
    k = (mixed(pr["mu_k"]) @ pr["wk"]).reshape(B, H, hd)
    v = (mixed(pr["mu_v"]) @ pr["wv"]).reshape(B, H, hd)
    g = mixed(pr["mu_g"]) @ pr["wg"]
    ww = pr["w0"] + (
        jnp.tanh(mixed(pr["mu_w"]) @ pr["w_lora_a"]) @ pr["w_lora_b"]
    ).astype(jnp.float32)
    log_decay = -jnp.exp(ww).reshape(B, H, hd)
    y, wkv_state = la.step_vector_decay(r, k, v, log_decay, pr["u"], wkv_state)
    y = rms_norm(y.reshape(B, D), pr["ln_x"], cfg.norm_eps)
    x = x + ((y * jax.nn.silu(g)) @ pr["wo"])[:, None, :]
    shift_t = h

    h2 = rms_norm(x, pr["norm_c"], cfg.norm_eps)[:, 0]
    kk = h2 + (shift_c - h2) * pr["mu_ck"]
    rr = h2 + (shift_c - h2) * pr["mu_cr"]
    kk = jnp.square(jax.nn.relu(kk @ pr["ck"]))
    x = x + (jax.nn.sigmoid(rr @ pr["cr"]) * (kk @ pr["cv"]))[:, None, :]
    shift_c = h2
    return x, (shift_t, shift_c, wkv_state)
