"""Core transformer layers: RMSNorm, RoPE / M-RoPE, chunked (flash-style)
attention with GQA / sliding-window / qk-norm / bias, and gated MLP.

Pure functional JAX: every layer is ``apply(params_dict, x, ...)`` with
parameters as plain dicts of arrays; bf16 matmuls, fp32 softmax/norm
accumulators.  Sequence-chunked online-softmax attention keeps the score
matrix out of HBM (required for the 32k prefill shapes).
"""

from __future__ import annotations

import functools
import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import shard_map
from repro.distributed.sharding import constrain

__all__ = [
    "rms_norm",
    "rope",
    "mrope",
    "flash_attention",
    "decode_attention",
    "gated_mlp",
    "init_dense",
    "init_norm",
]

ATTN_CHUNK = 1024  # kv-chunk for online softmax


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, dims: int, theta: float) -> jax.Array:
    """(..., dims/2) angles for integer positions."""
    freqs = theta ** (-jnp.arange(0, dims, 2, dtype=jnp.float32) / dims)
    return positions[..., None].astype(jnp.float32) * freqs


def _apply_angles(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, D); angles: (B, S, D/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def rope(q, k, positions, theta: float = 1e4):
    """Standard RoPE.  positions: (B, S) int."""
    d = q.shape[-1]
    ang = _rope_angles(positions, d, theta)
    return _apply_angles(q, ang).astype(q.dtype), _apply_angles(k, ang).astype(k.dtype)


def mrope(q, k, positions3, sections: Tuple[int, int, int], theta: float = 1e4):
    """Multimodal RoPE (Qwen2-VL): head_dim is split into (t, h, w)
    sections, each rotated by its own position stream.

    positions3: (3, B, S) — temporal/height/width position ids (equal for
    text tokens, spatial for vision patches; provided by the frontend
    stub).
    """
    d = q.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    # build per-pair angles by section
    parts = []
    for i, sec in enumerate(sections):
        freqs_i = theta ** (
            -(jnp.arange(0, d, 2, dtype=jnp.float32) / d)
        )  # full ladder; slice below keeps interleaving simple
        parts.append(
            positions3[i][..., None].astype(jnp.float32)
            * freqs_i[sum(sections[:i]) : sum(sections[: i + 1])]
        )
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, d/2)
    return _apply_angles(q, ang).astype(q.dtype), _apply_angles(k, ang).astype(k.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention (flash-style, pure jnp)
# ---------------------------------------------------------------------------

def _mask(
    qpos: jax.Array, kpos: jax.Array, causal: bool, window: Optional[int]
) -> jax.Array:
    """(Sq, Sk) boolean validity mask from absolute positions."""
    ok = jnp.ones((qpos.shape[-1], kpos.shape[-1]), bool)
    if causal:
        ok = ok & (qpos[:, None] >= kpos[None, :])
    if window is not None:
        ok = ok & (qpos[:, None] - kpos[None, :] < window)
    return ok



def _heads_shardable(H: int) -> bool:
    """True iff the merged H dim divides the physical heads axis — the
    merged-head layout then lets score/cotangent tensors shard.  For
    non-divisible head counts (qwen2's 14, qwen2-vl's 12) the split
    (KVH, G) layout is kept and XLA's inference picks a sharding
    (typically over the query-sequence dim), which measures ~3.7x fewer
    per-device FLOPs than forcing the merged layout."""
    from repro.distributed.sharding import active

    mesh, rules = active()
    if mesh is None:
        return False
    phys = rules.resolve("heads", mesh, H)
    return phys is not None


def _flash_forward(q, k, v, causal, window, q_offset, chunk, merged):
    """Online-softmax forward; returns (out, m, l) with fp32 stats.

    Heads are kept MERGED (H = KVH*G) and k/v repeated per chunk: the
    score tensors then shard over the model axis whenever H divides it
    (a split (KVH, G) layout cannot — e.g. mixtral's KVH=8, G=6 on a
    16-way axis — and silently replicates, costing TB of gathers)."""
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    scale = 1.0 / math.sqrt(D)

    nchunks = max(Sk // chunk, 1)
    chunk = Sk // nchunks
    assert Sk % nchunks == 0, (Sk, chunk)

    kc = k.reshape(B, nchunks, chunk, KVH, D)
    vc = v.reshape(B, nchunks, chunk, KVH, D)
    qpos = q_offset + jnp.arange(Sq)

    qq = q if merged else q.reshape(B, Sq, KVH, G, D)

    def step(carry, inputs):
        acc, m, l = carry
        kb, vb, cidx = inputs
        kpos = cidx * chunk + jnp.arange(chunk)
        if merged:
            kb = jnp.repeat(kb, G, axis=2)      # (B, C, H, D)
            vb = jnp.repeat(vb, G, axis=2)
            s = jnp.einsum(
                "bqhd,bchd->bqhc", qq, kb, preferred_element_type=jnp.float32
            ) * scale
            s = constrain(s, "batch", None, "heads", None)
        else:
            s = jnp.einsum(
                "bqkgd,bckd->bqkgc", qq, kb,
                preferred_element_type=jnp.float32,
            ) * scale
            s = s.reshape(B, Sq, H, chunk)
        ok = _mask(qpos, kpos, causal, window)  # (Sq, chunk)
        s = jnp.where(ok[None, :, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[None, :, None, :], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        if merged:
            pv = jnp.einsum(
                "bqhc,bchd->bqhd", p.astype(v.dtype), vb,
                preferred_element_type=jnp.float32,
            )
        else:
            pv = jnp.einsum(
                "bqkgc,bckd->bqkgd",
                p.reshape(B, Sq, KVH, G, chunk).astype(v.dtype), vb,
                preferred_element_type=jnp.float32,
            ).reshape(B, Sq, H, D)
        acc_new = acc * alpha[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    m0 = jnp.full((B, Sq, H), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, H), jnp.float32)
    (acc, m, l), _ = lax.scan(
        step,
        (acc0, m0, l0),
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            jnp.arange(nchunks),
        ),
    )
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.astype(q.dtype), m, l


@functools.lru_cache(maxsize=None)
def _flash_vjp(causal: bool, window, q_offset: int, chunk: int, merged: bool):
    """custom_vjp flash attention specialized to static config.

    The flash *backward* recomputes p per kv-chunk from the saved
    softmax stats (m, l) and accumulates dq/dk/dv chunked — cotangents
    never materialize (Sq, Sk) scores, stay in the inputs' dtype outside
    the chunk loop, and (crucially for SP sharding) never create the
    full-sequence f32 carry tensors that autodiff-through-scan does
    (those were the dominant all-gathers on every train cell).
    """

    @jax.custom_vjp
    def fa(q, k, v):
        out, _, _ = _flash_forward(q, k, v, causal, window, q_offset, chunk,
                                   merged)
        return out

    def fwd(q, k, v):
        out, m, l = _flash_forward(q, k, v, causal, window, q_offset, chunk,
                                   merged)
        return out, (q, k, v, out, m, l)

    def bwd(res, do):
        q, k, v, out, m, l = res
        B, Sq, H, D = q.shape
        _, Sk, KVH, _ = k.shape
        G = H // KVH
        scale = 1.0 / math.sqrt(D)
        nchunks = max(Sk // chunk, 1)
        ck = Sk // nchunks

        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        inv_l = 1.0 / jnp.maximum(l, 1e-37)
        # delta = rowsum(do * out)  (B,Sq,H)
        delta = jnp.einsum(
            "bqhd,bqhd->bqh", do.astype(jnp.float32), out.astype(jnp.float32)
        )
        kc = k.reshape(B, nchunks, ck, KVH, D)
        vc = v.reshape(B, nchunks, ck, KVH, D)
        qpos = q_offset + jnp.arange(Sq)

        qg = q if merged else q.reshape(B, Sq, KVH, G, D)
        dog = do if merged else do.reshape(B, Sq, KVH, G, D)

        def step(dq_acc, inputs):
            kb, vb, cidx = inputs
            kpos = cidx * ck + jnp.arange(ck)
            if merged:
                kbr = jnp.repeat(kb, G, axis=2)
                vbr = jnp.repeat(vb, G, axis=2)
                s = jnp.einsum(
                    "bqhd,bchd->bqhc", qg, kbr,
                    preferred_element_type=jnp.float32,
                ) * scale
                s = constrain(s, "batch", None, "heads", None)
                ok = _mask(qpos, kpos, causal, window)
                p = jnp.exp(s - m_safe[..., None]) * inv_l[..., None]
                p = jnp.where(ok[None, :, None, :], p, 0.0)
                dv_f = jnp.einsum("bqhc,bqhd->bchd", p, dog.astype(jnp.float32))
                dp = jnp.einsum(
                    "bqhd,bchd->bqhc", dog, vbr,
                    preferred_element_type=jnp.float32,
                )
                ds = p * (dp - delta[..., None]) * scale
                ds = constrain(ds, "batch", None, "heads", None)
                dq_acc = dq_acc + jnp.einsum(
                    "bqhc,bchd->bqhd", ds.astype(q.dtype), kbr,
                    preferred_element_type=jnp.float32,
                )
                dk_f = jnp.einsum("bqhc,bqhd->bchd", ds, qg.astype(jnp.float32))
                dk_c = dk_f.reshape(B, ck, KVH, G, D).sum(3)
                dv_c = dv_f.reshape(B, ck, KVH, G, D).sum(3)
            else:
                ms = m_safe.reshape(B, Sq, KVH, G)
                il = inv_l.reshape(B, Sq, KVH, G)
                dl = delta.reshape(B, Sq, KVH, G)
                s = jnp.einsum(
                    "bqkgd,bckd->bqkgc", qg, kb,
                    preferred_element_type=jnp.float32,
                ) * scale
                ok = _mask(qpos, kpos, causal, window)
                p = jnp.exp(s - ms[..., None]) * il[..., None]
                p = jnp.where(ok[None, :, None, None, :], p, 0.0)
                dv_c = jnp.einsum("bqkgc,bqkgd->bckd", p,
                                  dog.astype(jnp.float32))
                dp = jnp.einsum(
                    "bqkgd,bckd->bqkgc", dog, vb,
                    preferred_element_type=jnp.float32,
                )
                ds = p * (dp - dl[..., None]) * scale
                dq_acc = dq_acc + jnp.einsum(
                    "bqkgc,bckd->bqkgd", ds.astype(q.dtype), kb,
                    preferred_element_type=jnp.float32,
                ).reshape(B, Sq, H, D)
                dk_c = jnp.einsum("bqkgc,bqkgd->bckd", ds,
                                  qg.astype(jnp.float32))
            return dq_acc, (dk_c.astype(k.dtype), dv_c.astype(v.dtype))

        dq0 = jnp.zeros((B, Sq, H, D), jnp.float32)
        if merged:
            dq0 = constrain(dq0, "batch", None, "heads", None)
        dq, (dks, dvs) = lax.scan(
            step,
            dq0,
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
             jnp.arange(nchunks)),
        )
        dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, KVH, D)
        dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, KVH, D)
        return dq.astype(q.dtype), dk, dv

    fa.defvjp(fwd, bwd)
    return fa


def flash_attention(
    q: jax.Array,          # (B, Sq, H, D)
    k: jax.Array,          # (B, Sk, KVH, D)
    v: jax.Array,          # (B, Sk, KVH, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    chunk: int = ATTN_CHUNK,
) -> jax.Array:
    """Online-softmax attention over kv chunks; GQA via head grouping.
    Never materializes the (Sq, Sk) score matrix; custom chunked VJP
    (see _flash_vjp).  Head layout (merged vs split) picked per the
    active mesh (see _heads_shardable)."""
    merged = _heads_shardable(q.shape[2])
    return _flash_vjp(causal, window, q_offset, chunk, merged)(q, k, v)


def ring_update(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write one token into a (possibly sequence-sharded) ring-buffer
    cache at ``slot`` along axis 1, touching only the owning shard.

    A plain ``dynamic_update_slice`` on a sharded dim is lowered by GSPMD
    to a *select over the full local shard* (full local rewrite per layer
    per step).  Here we shard_map over the sequence axis: each shard runs
    a ``lax.cond`` that either does a local in-place DUS (owning shard)
    or passes its block through untouched — traffic is one row.
    cache: (B, S, KV, hd); new: (B, 1, KV, hd).
    """
    from repro.distributed.sharding import active

    mesh, rules = active()
    phys = rules.resolve("kv_seq", mesh, cache.shape[1]) if mesh else None
    new = new.astype(cache.dtype)
    zero = jnp.zeros((), jnp.int32)
    if phys is None or mesh is None:
        return lax.dynamic_update_slice(cache, new, (zero, slot, zero, zero))
    if isinstance(phys, tuple):
        phys = phys[0]
    batch_phys = rules.resolve("batch", mesh, cache.shape[0])
    from jax.sharding import PartitionSpec as P

    def upd(c, n, s):
        ax = lax.axis_index(phys)
        s_loc = c.shape[1]
        local = s[0] - ax * s_loc
        inb = (local >= 0) & (local < s_loc)

        def write(c):
            return lax.dynamic_update_slice(
                c, n, (zero, jnp.clip(local, 0, s_loc - 1), zero, zero)
            )

        return lax.cond(inb, write, lambda c: c, c)

    spec_c = P(batch_phys, phys, None, None)
    return shard_map(
        upd,
        mesh=mesh,
        in_specs=(spec_c, P(batch_phys, None, None, None), P()),
        out_specs=spec_c,
        check_vma=False,
    )(cache, new, slot[None])


def ring_update_stacked(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Batched deferred cache write: one sharded update for ALL layers.
    cache: (L, B, S, KV, hd); new: (L, B, 1, KV, hd).  Traffic = L rows
    (vs. L full-cache restacks when the layer scan carries the caches)."""
    from repro.distributed.sharding import active

    mesh, rules = active()
    phys = rules.resolve("kv_seq", mesh, cache.shape[2]) if mesh else None
    new = new.astype(cache.dtype)
    zero = jnp.zeros((), jnp.int32)
    if phys is None or mesh is None:
        return lax.dynamic_update_slice(
            cache, new, (zero, zero, slot, zero, zero)
        )
    if isinstance(phys, tuple):
        phys = phys[0]
    batch_phys = rules.resolve("batch", mesh, cache.shape[1])
    from jax.sharding import PartitionSpec as P

    def upd(c, n, s):
        ax = lax.axis_index(phys)
        s_loc = c.shape[2]
        local = s[0] - ax * s_loc
        inb = (local >= 0) & (local < s_loc)

        def write(c):
            return lax.dynamic_update_slice(
                c, n, (zero, zero, jnp.clip(local, 0, s_loc - 1), zero, zero)
            )

        return lax.cond(inb, write, lambda c: c, c)

    spec_c = P(None, batch_phys, phys, None, None)
    return shard_map(
        upd,
        mesh=mesh,
        in_specs=(spec_c, P(None, batch_phys, None, None, None), P()),
        out_specs=spec_c,
        check_vma=False,
    )(cache, new, slot[None])


def decode_attention(
    q: jax.Array,        # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KVH, D) — S may be sharded over 'model'
    v_cache: jax.Array,
    t: jax.Array,        # current position (scalar int32)
    *,
    window: Optional[int] = None,
    kpos: Optional[jax.Array] = None,  # (S,) absolute position per slot
                                       # (-1 = empty); for rolling caches
    current: Optional[tuple] = None,   # deferred-write: (k_new, v_new)
                                       # (B,1,KVH,D) not yet in the cache
) -> jax.Array:
    """Single-token attention against a (possibly sequence-sharded) KV
    cache.  Elementwise masking + reductions keep the cache sharded;
    GSPMD inserts the small cross-shard softmax reductions."""
    B, _, H, D = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    qg = q.reshape(B, KVH, G, D)
    if kpos is None:
        kpos = jnp.arange(S)
        valid = kpos <= t
    else:
        valid = (kpos >= 0) & (kpos <= t)
    if window is not None:
        valid = valid & (kpos > t - window)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) / math.sqrt(D)
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    if current is not None:
        # deferred-write mode: the current token's (k, v) are not in the
        # cache yet; attend to them explicitly (cache row at `slot` is
        # stale and must be masked out by the caller's kpos)
        k_cur, v_cur = current
        s_cur = jnp.einsum(
            "bkgd,bkd->bkg", qg, k_cur[:, 0].astype(qg.dtype),
            preferred_element_type=jnp.float32,
        )[..., None] / math.sqrt(D)
        s = jnp.concatenate([s, s_cur], axis=-1)
        p = jax.nn.softmax(s, axis=-1)
        p_cache, p_cur = p[..., :-1], p[..., -1:]
        out = jnp.einsum(
            "bkgs,bskd->bkgd", p_cache.astype(v_cache.dtype), v_cache,
            preferred_element_type=jnp.float32,
        ) + p_cur * v_cur[:, 0, :, None, :].astype(jnp.float32)
        return out.reshape(B, 1, H, D).astype(q.dtype)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU family)
# ---------------------------------------------------------------------------

def gated_mlp(p: dict, x: jax.Array, activation: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[activation]
    h = act(x @ p["w_gate"]) * (x @ p["w_in"])
    h = constrain(h, "batch", None, "d_ff")
    return h @ p["w_out"]
