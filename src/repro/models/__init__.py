"""repro.models — the LM substrate: layers, blocks, and family assembly."""

from repro.models.model import Model, build_model
