"""Trace CLI.

::

    python -m repro.obs summary  TRACE.json
    python -m repro.obs validate TRACE.json

``summary`` renders the text flamechart: the span hierarchy with
observed phase wall time joined against the PerfModel predictions each
span recorded at trace time.  ``validate`` is the CI invariant check
(exit 1 on any violation): well-formed Chrome-trace JSON, every
``exchange`` span carrying a decision signature, and at most one
exchange per ``program_iteration`` (communication avoidance: exchanges
per application <= 1/s for a ``program/s=N`` decision).
"""

from __future__ import annotations

import argparse
import sys

from repro.obs.export import load_chrome_trace, summary, validate


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    sp = sub.add_parser("summary", help="text flamechart, obs vs pred")
    sp.add_argument("trace", help="Chrome-trace JSON (--trace output)")
    vp = sub.add_parser("validate", help="CI invariant check (exit 1)")
    vp.add_argument("trace", help="Chrome-trace JSON (--trace output)")
    args = ap.parse_args(argv)

    try:
        trace = load_chrome_trace(args.trace)
    except (OSError, ValueError) as e:
        print(f"error: unreadable trace {args.trace}: {e}", file=sys.stderr)
        return 2
    if args.cmd == "summary":
        print(summary(trace))
        return 0
    errors = validate(trace)
    if errors:
        for e in errors:
            print(f"INVALID: {e}", file=sys.stderr)
        return 1
    events = trace.get("traceEvents", ())
    n_ex = sum(1 for ev in events if ev.get("name") == "exchange")
    print(f"trace OK: {len(events)} events, {n_ex} exchange spans, "
          "signatures present, <=1 exchange per iteration")
    return 0


if __name__ == "__main__":
    sys.exit(main())
