"""repro.obs — structured tracing + metrics for the exchange stack.

Three small modules, one discipline (observe the same decomposition the
model prices):

* :mod:`repro.obs.trace` — hierarchical spans (``program_iteration`` →
  ``exchange`` → ``plan``/``pack``/``wire``/``unpack`` → ``stencil``)
  with decision signatures and predicted-seconds attributes,
  tracer-guarded like the telemetry probe;
* :mod:`repro.obs.metrics` — process-local counters/gauges
  (:meth:`Communicator.stats` publishes; ``save()`` persists);
* :mod:`repro.obs.export` — Chrome-trace JSON (Perfetto /
  ``chrome://tracing``), text flamechart summaries joining observed
  phase times against model predictions, and the CI trace validator.

``python -m repro.obs {summary,validate} TRACE.json`` is the CLI.
"""

from repro.obs.export import (
    aggregate_events,
    aggregate_spans,
    load_chrome_trace,
    save_chrome_trace,
    summary,
    to_chrome_trace,
    validate,
)
from repro.obs.metrics import (
    METRICS_FILENAME,
    METRICS_FORMAT,
    MetricsRegistry,
    default_metrics,
    publish_comm_stats,
)
from repro.obs.trace import (
    DEFAULT_MAX_SPANS,
    PHASES,
    TRACE_FORMAT,
    Span,
    Tracer,
    attribute_program_iteration,
)

__all__ = [
    "TRACE_FORMAT",
    "PHASES",
    "DEFAULT_MAX_SPANS",
    "Span",
    "Tracer",
    "attribute_program_iteration",
    "METRICS_FORMAT",
    "METRICS_FILENAME",
    "MetricsRegistry",
    "default_metrics",
    "publish_comm_stats",
    "to_chrome_trace",
    "save_chrome_trace",
    "load_chrome_trace",
    "aggregate_spans",
    "aggregate_events",
    "summary",
    "validate",
]
