"""Process-local metrics registry: counters and gauges.

The span tree (:mod:`repro.obs.trace`) answers "where did the time go";
this module answers "how much traffic went through" with a handful of
named scalars a host can snapshot at any point:

counters (cumulative)
    exchanges issued (``comm.wire_ops``), exact payload bytes moved
    (``comm.wire_payload_bytes``), per-delta-class issue tallies
    (``comm.wire_class.<plan>/c<g>.ops`` / ``.bytes``), decision-cache
    hits/misses, drift findings.
gauges (instantaneous)
    telemetry ring occupancy (how full the observation windows are),
    per-delta-class drain position from the last region-split drain
    (``comm.wire_class.<plan>/c<g>.drain_order``).

:meth:`repro.comm.api.Communicator.stats` publishes its counters here
on every call (see :func:`publish_comm_stats`), and
``production_communicator``'s ``save()`` persists the snapshot to
``metrics.json`` next to the decisions file — so
``python -m repro.fleet stats`` can inspect a host's counters next to
its bundle generation without attaching to the process.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional, Union

__all__ = [
    "METRICS_FORMAT",
    "METRICS_FILENAME",
    "MetricsRegistry",
    "default_metrics",
    "publish_comm_stats",
]

#: bump when the persisted snapshot schema changes incompatibly
METRICS_FORMAT = 1

#: the metrics snapshot lives next to ``decisions.json`` in the store
METRICS_FILENAME = "metrics.json"


class MetricsRegistry:
    """Named counters + gauges, process-local, no locks (jax dispatch is
    single-threaded per process; the hot-path cost is one dict write)."""

    def __init__(self):
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}

    # -- writes ----------------------------------------------------------
    def inc(self, name: str, delta: float = 1.0) -> None:
        self._counters[name] = self._counters.get(name, 0.0) + delta

    def set_counter(self, name: str, value: float) -> None:
        """Install a cumulative value owned elsewhere (e.g. the
        Communicator's own ``wire_ops`` tally) — last write wins."""
        self._counters[name] = float(value)

    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    # -- reads -----------------------------------------------------------
    def counter(self, name: str) -> float:
        return self._counters.get(name, 0.0)

    def gauge(self, name: str) -> float:
        return self._gauges.get(name, 0.0)

    def __len__(self) -> int:
        return len(self._counters) + len(self._gauges)

    def snapshot(self) -> dict:
        """Point-in-time copy, key-sorted (deterministic)."""
        return {
            "counters": dict(sorted(self._counters.items())),
            "gauges": dict(sorted(self._gauges.items())),
        }

    def clear(self) -> None:
        self._counters.clear()
        self._gauges.clear()

    # -- report ----------------------------------------------------------
    def report(self) -> str:
        lines = [f"{'metric':32s} {'kind':7s} {'value':>16s}"]
        for name, v in sorted(self._counters.items()):
            shown = f"{int(v)}" if float(v).is_integer() else f"{v:.6g}"
            lines.append(f"{name:32s} {'counter':7s} {shown:>16s}")
        for name, v in sorted(self._gauges.items()):
            lines.append(f"{name:32s} {'gauge':7s} {v:>16.4f}")
        return "\n".join(lines)

    # -- persistence -----------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {"format": METRICS_FORMAT, **self.snapshot()}, indent=2
        )

    @staticmethod
    def from_json(s: str) -> "MetricsRegistry":
        d = json.loads(s)
        if d.get("format") != METRICS_FORMAT:
            raise ValueError(
                f"metrics snapshot format {d.get('format')!r} != "
                f"{METRICS_FORMAT}"
            )
        m = MetricsRegistry()
        for k, v in d.get("counters", {}).items():
            m.set_counter(k, v)
        for k, v in d.get("gauges", {}).items():
            m.set_gauge(k, v)
        return m

    def save(self, path: Union[str, Path]) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        tmp = p.with_suffix(".tmp")
        tmp.write_text(self.to_json())
        tmp.replace(p)
        return p

    @staticmethod
    def load(path: Union[str, Path]) -> "MetricsRegistry":
        """Load a persisted snapshot; an absent file yields an empty
        registry."""
        p = Path(path)
        if not p.exists():
            return MetricsRegistry()
        return MetricsRegistry.from_json(p.read_text())


_DEFAULT = MetricsRegistry()


def default_metrics() -> MetricsRegistry:
    """The process-global registry everything publishes into."""
    return _DEFAULT


def publish_comm_stats(
    stats: Dict[str, int],
    telemetry=None,
    registry: Optional[MetricsRegistry] = None,
) -> MetricsRegistry:
    """Mirror a :meth:`Communicator.stats` dict (plus the attached
    telemetry's ring occupancy) into the registry.  Counters are the
    communicator's own cumulative tallies, installed as-is."""
    m = registry if registry is not None else _DEFAULT
    m.set_counter("comm.exchanges", stats.get("wire_ops", 0))
    m.set_counter("comm.wire_payload_bytes",
                  stats.get("wire_payload_bytes", 0))
    m.set_counter("comm.wire_classes", stats.get("wire_classes", 0))
    for key, v in (stats.get("wire_class_ops") or {}).items():
        m.set_counter(f"comm.wire_class.{key}.ops", v)
    for key, v in (stats.get("wire_class_bytes") or {}).items():
        m.set_counter(f"comm.wire_class.{key}.bytes", v)
    for key, v in (stats.get("wire_class_drains") or {}).items():
        m.set_gauge(f"comm.wire_class.{key}.drain_order", v)
    m.set_counter("comm.compress.exchanges",
                  stats.get("compress_exchanges", 0))
    m.set_counter("comm.compress.capacity_bytes",
                  stats.get("compress_capacity_bytes", 0))
    m.set_counter("comm.compress.stream_bytes",
                  stats.get("compress_stream_bytes", 0))
    m.set_gauge("comm.compress.ratio", stats.get("compress_ratio", 1.0))
    m.set_counter("comm.committed_types", stats.get("committed_types", 0))
    m.set_counter("comm.commit_hits", stats.get("commit_hits", 0))
    hits = stats.get("model_hits", 0)
    m.set_counter("decisions.cache_hits", hits)
    m.set_counter("decisions.cache_misses",
                  max(stats.get("model_lookups", 0) - hits, 0))
    if telemetry is not None:
        rows = telemetry.aggregates()
        cap = sum(a.capacity for a in rows)
        m.set_counter("telemetry.observations",
                      sum(a.total_count for a in rows))
        m.set_gauge("telemetry.ring_occupancy",
                    (sum(a.count for a in rows) / cap) if cap else 0.0)
    return m
