"""Span export: Chrome-trace JSON, text flamecharts, trace validation.

Chrome-trace JSON (the ``traceEvents`` "X" complete-event form) loads
directly in Perfetto / ``chrome://tracing``.  Every event keeps its
span/parent ids and attributes in ``args``, so a saved trace round-trips
losslessly: :func:`aggregate_events` rebuilds the per-decision phase
sums :mod:`repro.fleet.drift` consumes, and :func:`summary` renders the
flamechart with *observed* wall time beside the *predicted* model terms
each span recorded at trace time (``args.pred``) — model error visible
per phase, per exchange, without the model in hand.

:func:`validate` is the CI invariant check on an exported trace:

* well-formed Chrome-trace JSON (``traceEvents`` list of timed events);
* every ``exchange`` span carries a decision signature (``fingerprint``
  + ``strategy``);
* every ``wire_class`` span (per-delta-class completion, region-split
  overlap) identifies its class: a ``class`` index plus the wire-plan
  key (``fingerprint`` on eager drains, ``key`` on attributed ones);
* communication avoidance holds: a ``program_iteration`` span with
  fusion depth ``s`` contains at most ONE exchange and at least
  ``s`` stencil applications — exchanges per application <= 1/s.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.obs.trace import PHASES, TRACE_FORMAT, Span, Tracer

__all__ = [
    "to_chrome_trace",
    "save_chrome_trace",
    "load_chrome_trace",
    "aggregate_spans",
    "aggregate_events",
    "summary",
    "validate",
]

#: Perfetto category per span name (anything else renders as "misc")
_CATEGORIES = {
    "program_iteration": "program",
    "exchange": "comm",
    "plan": "comm",
    "pack": "comm",
    "wire": "comm",
    "wire_class": "comm",
    "unpack": "comm",
    "stencil": "compute",
}


def _jsonable(v):
    """Span attributes are free-form; coerce the numpy scalars that leak
    in from shape math so json.dumps never chokes."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    item = getattr(v, "item", None)
    if callable(item):
        try:
            return item()
        except Exception:  # pragma: no cover - exotic attr types
            pass
    return str(v)


def to_chrome_trace(tracer: Tracer) -> dict:
    """The tracer's spans as a Chrome-trace JSON object (timestamps in
    microseconds relative to the earliest span)."""
    spans = tracer.spans
    epoch = min((s.start for s in spans), default=0.0)
    events = []
    for s in spans:
        args = {k: _jsonable(v) for k, v in s.attrs.items()}
        args["span_id"] = s.span_id
        if s.parent_id is not None:
            args["parent_id"] = s.parent_id
        events.append({
            "name": s.name,
            "cat": _CATEGORIES.get(s.name, "misc"),
            "ph": "X",
            "ts": (s.start - epoch) * 1e6,
            "dur": s.duration * 1e6,
            "pid": 0,
            "tid": 0,
            "args": args,
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "format": TRACE_FORMAT,
            "generator": "repro.obs",
            "dropped_spans": tracer.dropped,
        },
    }


def save_chrome_trace(tracer: Tracer, path: Union[str, Path]) -> Path:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(to_chrome_trace(tracer), indent=1))
    return p


def load_chrome_trace(path: Union[str, Path]) -> dict:
    return json.loads(Path(path).read_text())


# ---------------------------------------------------------------------------
# aggregation (the drift-attribution feed)
# ---------------------------------------------------------------------------

def _events_as_spans(events: Sequence[dict]) -> List[Span]:
    """Rebuild light :class:`Span` records from exported events (events
    without a ``span_id`` — foreign traces — are skipped)."""
    out = []
    for ev in events:
        args = ev.get("args") or {}
        sid = args.get("span_id")
        if sid is None or ev.get("ph") != "X":
            continue
        attrs = {k: v for k, v in args.items()
                 if k not in ("span_id", "parent_id")}
        out.append(Span(
            name=ev.get("name", ""),
            start=float(ev.get("ts", 0.0)) * 1e-6,
            duration=float(ev.get("dur", 0.0)) * 1e-6,
            span_id=int(sid),
            parent_id=args.get("parent_id"),
            attrs=attrs,
        ))
    return out


def aggregate_spans(
    spans: Sequence[Span],
) -> Dict[str, Dict[str, Dict[str, float]]]:
    """Per-decision-fingerprint phase sums:
    ``{fingerprint: {phase: {count, observed, predicted, attributed}}}``.

    Each pack/wire/unpack/stencil span is credited to the nearest
    enclosing span carrying a ``fingerprint`` attribute (the decision
    key), summing observed wall seconds and the predicted seconds the
    span recorded (``pred``).  ``attributed`` counts the spans whose
    timing was model-proportioned rather than directly measured, so a
    consumer can tell a real per-phase observation from a scaled one.
    """
    by_id = {s.span_id: s for s in spans}
    out: Dict[str, Dict[str, Dict[str, float]]] = {}
    for s in spans:
        if s.name not in PHASES:
            continue
        p = by_id.get(s.parent_id) if s.parent_id is not None else None
        while p is not None and "fingerprint" not in p.attrs:
            p = (by_id.get(p.parent_id)
                 if p.parent_id is not None else None)
        if p is None:
            continue
        fp = str(p.attrs["fingerprint"])
        rec = out.setdefault(fp, {}).setdefault(
            s.name,
            {"count": 0, "observed": 0.0, "predicted": 0.0,
             "attributed": 0},
        )
        rec["count"] += 1
        rec["observed"] += s.duration
        rec["predicted"] += float(s.attrs.get("pred", 0.0) or 0.0)
        if s.attrs.get("attributed"):
            rec["attributed"] += 1
    return out


def aggregate_events(
    trace: dict,
) -> Dict[str, Dict[str, Dict[str, float]]]:
    """:func:`aggregate_spans` over a loaded Chrome-trace dict — the
    file-based path into ``DriftDetector.audit(trace=...)``."""
    return aggregate_spans(_events_as_spans(trace.get("traceEvents", ())))


# ---------------------------------------------------------------------------
# text flamechart (predicted vs observed)
# ---------------------------------------------------------------------------

def _children(spans: Sequence[Span]) -> Dict[Optional[int], List[Span]]:
    kids: Dict[Optional[int], List[Span]] = {}
    for s in spans:
        kids.setdefault(s.parent_id, []).append(s)
    for v in kids.values():
        v.sort(key=lambda s: s.start)
    return kids


def _render_group(lines: List[str], group: List[Span],
                  kids: Dict[Optional[int], List[Span]],
                  indent: int) -> None:
    """One flamechart row per (name, signature) sibling group: count,
    observed mean, predicted mean, obs/pred ratio."""
    n = len(group)
    obs = sum(s.duration for s in group)
    pred = sum(float(s.attrs.get("pred", 0.0) or 0.0) for s in group)
    head = group[0]
    sig = ""
    if "fingerprint" in head.attrs:
        sig = (f" fp={head.attrs['fingerprint']}"
               f" {head.attrs.get('strategy', '')}")
        if "schedule" in head.attrs:
            sig += f" {head.attrs['schedule']}/{head.attrs.get('wire_bytes', '?')}B"
    attributed = any(s.attrs.get("attributed") for s in group)
    ratio = f"{obs / pred:8.3f}" if pred > 0 else f"{'-':>8s}"
    lines.append(
        f"{'  ' * indent}{head.name:<{max(24 - 2 * indent, 8)}s}"
        f" n={n:<5d} obs={obs / n * 1e6:10.1f}us"
        f" pred={pred / n * 1e6:10.1f}us obs/pred={ratio}"
        f"{' [attributed]' if attributed else ''}{sig}"
    )
    # recurse: pool the whole sibling group's children, regroup by name
    sub: Dict[Tuple[str, str], List[Span]] = {}
    order: List[Tuple[str, str]] = []
    for s in group:
        for c in kids.get(s.span_id, ()):
            key = (c.name, str(c.attrs.get("fingerprint", "")))
            if key not in sub:
                sub[key] = []
                order.append(key)
            sub[key].append(c)
    for key in order:
        _render_group(lines, sub[key], kids, indent + 1)


def summary(trace: dict) -> str:
    """Text flamechart of an exported trace: the span hierarchy with
    observed phase means joined against the PerfModel predictions each
    span carried (``pred``) — the ``python -m repro.obs summary``
    output."""
    spans = _events_as_spans(trace.get("traceEvents", ()))
    if not spans:
        return "trace summary: no spans"
    kids = _children(spans)
    total = sum(s.duration for s in kids.get(None, ()))
    dropped = (trace.get("otherData") or {}).get("dropped_spans", 0)
    lines = [
        f"trace summary: {len(spans)} spans, "
        f"{total * 1e6:.1f}us at the root"
        + (f", {dropped} dropped" if dropped else "")
    ]
    roots: Dict[Tuple[str, str], List[Span]] = {}
    order: List[Tuple[str, str]] = []
    for s in kids.get(None, ()):
        key = (s.name, str(s.attrs.get("fingerprint", "")))
        if key not in roots:
            roots[key] = []
            order.append(key)
        roots[key].append(s)
    for key in order:
        _render_group(lines, roots[key], kids, 0)
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# validation (the CI invariant check)
# ---------------------------------------------------------------------------

def validate(trace: dict) -> List[str]:
    """Invariant-check an exported trace; returns the violations (empty
    = valid).  See module docstring for the checked invariants."""
    errors: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        if ev.get("ph") != "X":
            errors.append(f"event {i}: ph={ev.get('ph')!r} != 'X'")
        if not isinstance(ev.get("name"), str):
            errors.append(f"event {i}: missing name")
        for k in ("ts", "dur"):
            if not isinstance(ev.get(k), (int, float)):
                errors.append(f"event {i}: {k} not numeric")
    if errors:
        return errors

    spans = _events_as_spans(events)
    kids = _children(spans)
    for s in spans:
        if s.name == "exchange":
            for k in ("fingerprint", "strategy"):
                if not s.attrs.get(k):
                    errors.append(
                        f"exchange span {s.span_id}: no decision "
                        f"signature ({k} missing)"
                    )
        if s.name == "wire_class":
            if s.attrs.get("class") is None:
                errors.append(
                    f"wire_class span {s.span_id}: no class index"
                )
            if not (s.attrs.get("fingerprint") or s.attrs.get("key")):
                errors.append(
                    f"wire_class span {s.span_id}: no wire-plan key "
                    "(fingerprint/key missing)"
                )
        if s.name == "program_iteration":
            steps = int(s.attrs.get("steps", 1) or 1)
            ex = [c for c in kids.get(s.span_id, ())
                  if c.name == "exchange"]
            st = [c for c in kids.get(s.span_id, ())
                  if c.name == "stencil"]
            if len(ex) > 1:
                errors.append(
                    f"program_iteration span {s.span_id}: {len(ex)} "
                    "exchanges in one iteration (expected <= 1)"
                )
            if ex and len(st) < steps:
                errors.append(
                    f"program_iteration span {s.span_id}: "
                    f"{len(st)} stencil applications < steps={steps} — "
                    f"exchanges per application exceed 1/s"
                )
    return errors
