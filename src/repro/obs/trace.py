"""Structured tracing: hierarchical spans over the exchange stack.

The telemetry ring buffers (:mod:`repro.fleet.telemetry`) answer "is
this decision's *total* wall time tracking the model?" — one scalar per
decision key.  TEMPI's empirical claim is finer than that: the latency
of a non-contiguous exchange decomposes into pack / wire / unpack terms
the model prices *separately*, and Hunold et al. show the terms drift
independently.  This module records that decomposition as it happens:

* :class:`Span` — one timed region with free-form attributes.  The
  hierarchy mirrors the execution structure::

      program_iteration            (one deep-halo iteration)
        exchange                   (the fused collective, decision-keyed)
          plan                     (host-side WirePlan construction)
          pack / wire / unpack     (the paper's three phases)
            wire_class × classes   (per-delta-class completion, under
                                    wire/unpack — region-split overlap)
        stencil × applications     (per-application compute)

  Every ``exchange`` span carries the decision signature: the
  fingerprint the :class:`~repro.measure.decisions.DecisionCache` keys
  on, the chosen strategy/schedule, ``wire_bytes``, and — for deep-halo
  programs — the fusion depth ``s=N``.  Phase spans carry the model's
  predicted seconds (``pred``), so an exported trace joins observed
  against predicted without the model in hand.

* :class:`Tracer` — the per-process recorder.  It is **tracer-guarded**
  exactly like the telemetry probe: a ``perf_counter`` pair inside a
  ``jit``/``shard_map`` trace measures tracing, not transfer, so
  :meth:`Tracer.span` records nothing unless
  ``jax.core.trace_state_clean()`` says execution is eager (callers
  additionally skip on tracer *operands*, same as telemetry).  Eager
  paths time phases with ``block_until_ready`` at each span exit;
  compiled (fused) iterations are recorded after the fact by
  :func:`attribute_program_iteration`, which splits the observed AOT
  iteration time across phases in the model's predicted proportions and
  marks the children ``attributed=True``.

Export to Chrome-trace JSON / text flamecharts lives in
:mod:`repro.obs.export`; ``python -m repro.obs`` is the CLI.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence

import jax

__all__ = [
    "TRACE_FORMAT",
    "PHASES",
    "DEFAULT_MAX_SPANS",
    "Span",
    "Tracer",
    "attribute_program_iteration",
]

#: bump when the exported span schema changes incompatibly
TRACE_FORMAT = 1

#: the phase span names drift attribution understands (module order is
#: the execution order inside an exchange)
PHASES = ("pack", "wire", "unpack", "stencil")

#: span-count cap — a million-iteration job must not grow an unbounded
#: trace; past the cap spans are dropped and counted, never an error
DEFAULT_MAX_SPANS = 200_000


def _trace_state_clean() -> bool:
    """True when no jax trace is being staged (eager execution)."""
    fn = getattr(jax.core, "trace_state_clean", None)
    if fn is None:  # pragma: no cover - very old jax
        return True
    return bool(fn())


@dataclass(slots=True)
class Span:
    """One recorded region.  ``start`` is ``perf_counter`` seconds (the
    tracer exports relative to its earliest span); ``attrs`` is free-form
    but ``exchange`` spans carry the decision signature and phase spans
    carry the model's predicted seconds under ``pred``."""

    name: str
    start: float
    duration: float
    span_id: int
    parent_id: Optional[int] = None
    attrs: Dict[str, object] = field(default_factory=dict)


class Tracer:
    """Low-overhead hierarchical span recorder (process-local).

    Attach to a :class:`~repro.comm.api.Communicator` (``tracer=...``)
    or request one from ``production_communicator(tracer=True)``; the
    launch drivers expose it as ``--trace PATH``.
    """

    def __init__(self, enabled: bool = True,
                 max_spans: int = DEFAULT_MAX_SPANS):
        self.enabled = bool(enabled)
        self.max_spans = int(max_spans)
        self.dropped = 0
        self._spans: List[Span] = []
        self._stack: List[int] = []
        self._next_id = 0

    # -- state -----------------------------------------------------------
    @property
    def active(self) -> bool:
        """Whether :meth:`span` would record right now: enabled AND not
        inside a jax trace (the tracer guard)."""
        return self.enabled and _trace_state_clean()

    @property
    def spans(self) -> List[Span]:
        return self._spans

    def __len__(self) -> int:
        return len(self._spans)

    def clear(self) -> None:
        self._spans.clear()
        self._stack.clear()
        self.dropped = 0
        self._next_id = 0

    # -- recording -------------------------------------------------------
    def _alloc(self, name: str, start: float, duration: float,
               parent_id: Optional[int], attrs: Dict[str, object]
               ) -> Optional[Span]:
        spans = self._spans
        if len(spans) >= self.max_spans:
            self.dropped += 1
            return None
        sp = Span(name, start, duration, self._next_id, parent_id, attrs)
        self._next_id += 1
        spans.append(sp)
        return sp

    @contextmanager
    def span(self, name: str, **attrs) -> Iterator[Optional[Span]]:
        """Record a timed region.  Yields the :class:`Span` (mutate
        ``.attrs`` freely before exit) — or ``None`` when guarded off
        (inside a jax trace, disabled, or at the span cap), in which
        case nothing is recorded and the body runs untouched.

        The caller owns synchronization: block (``block_until_ready``)
        before exit or the span under-reports async dispatch.
        """
        if not self.active:
            yield None
            return
        parent = self._stack[-1] if self._stack else None
        sp = self._alloc(name, time.perf_counter(), 0.0, parent, attrs)
        if sp is None:
            yield None
            return
        self._stack.append(sp.span_id)
        try:
            yield sp
        finally:
            sp.duration = time.perf_counter() - sp.start
            self._stack.pop()

    def add_manual(self, name: str, start: float, duration: float,
                   parent: Optional[Span] = None, **attrs) -> Optional[Span]:
        """Record a span with explicit timing (compiled-iteration
        attribution, host-side planning timed outside a ``with``).
        Nests under ``parent`` when given, else under the innermost open
        :meth:`span`, else at the root."""
        if not self.enabled:
            return None
        parent_id = (
            parent.span_id if parent is not None
            else (self._stack[-1] if self._stack else None)
        )
        return self._alloc(name, float(start), float(duration), parent_id,
                           attrs)

    # -- aggregation -----------------------------------------------------
    def phase_aggregates(self) -> Dict[str, Dict[str, Dict[str, float]]]:
        """Per-decision-fingerprint phase sums for drift attribution:
        ``{fingerprint: {phase: {count, observed, predicted}}}``.  Each
        phase span is credited to the nearest enclosing span that
        carries a ``fingerprint`` attribute (the decision key).  See
        :func:`repro.obs.export.aggregate_phases`."""
        from repro.obs.export import aggregate_spans

        return aggregate_spans(self._spans)


def attribute_program_iteration(
    tracer: Tracer,
    program,
    t0: float,
    seconds: float,
    phases: Dict[str, float],
    iteration: Optional[int] = None,
    class_pred: Sequence[float] = (),
) -> Optional[Span]:
    """Record one *compiled* deep-halo iteration as an attributed span
    tree.

    Inside ``jit`` the phases are fused — only the whole-iteration wall
    time (``seconds``, timed by the launch layer around the AOT-compiled
    step) is observable.  This splits it across the pack/wire/unpack/
    stencil children in the proportions of the model's per-phase
    predictions (``phases``, from
    :func:`repro.fleet.telemetry.predict_program_phases`), marking every
    span ``attributed=True`` so consumers know the split is model-shaped
    while the totals are measured.  The ``exchange`` child carries the
    program's full decision signature.

    ``class_pred`` (the model's per-delta-class completion times, from
    :meth:`~repro.comm.perfmodel.PerfModel.price_class_completions`)
    additionally attributes the wire span across its delta classes:
    one ``wire_class`` child per class, each spanning wire-start to its
    predicted completion fraction of the wire span — the per-direction
    view drift attribution uses to see which link is slow when the
    iteration runs region-split overlap.
    """
    total = sum(phases.values())
    if total <= 0.0 or not tracer.enabled:
        return None
    # this runs once per compiled iteration on the launch hot loop —
    # gated at <2% of an iteration by `bench_measure --assert-trace-
    # overhead` — so the fingerprint (a content hash) is computed once
    # and spans are allocated directly, skipping add_manual's kwargs
    scale = seconds / total
    fingerprint = program.fingerprint
    steps = program.steps
    strategy = f"program/s={steps}"
    attrs: Dict[str, object] = {
        "fingerprint": fingerprint, "strategy": strategy,
        "steps": steps, "cycle_len": program.cycle_len,
        "pinned": bool(program.pinned), "attributed": True, "pred": total,
    }
    if iteration is not None:
        attrs["iteration"] = int(iteration)
    alloc = tracer._alloc
    it = alloc("program_iteration", t0, seconds, None, attrs)
    if it is None:
        return None
    wire = program.plan.wire
    pred_ex = phases.get("pack", 0.0) + phases.get("wire", 0.0) \
        + phases.get("unpack", 0.0)
    ex = alloc(
        "exchange", t0, pred_ex * scale, it.span_id,
        {"fingerprint": fingerprint, "strategy": strategy,
         "schedule": wire.schedule, "wire_bytes": int(wire.issued_bytes),
         "attributed": True, "pred": pred_ex},
    )
    ex_id = ex.span_id if ex is not None else it.span_id
    cursor = t0
    for ph in ("pack", "wire", "unpack"):
        p = phases.get(ph, 0.0)
        d = p * scale
        sp = alloc(ph, cursor, d, ex_id, {"pred": p, "attributed": True})
        if ph == "wire" and sp is not None and class_pred:
            # per-delta-class completion profile: each class's span runs
            # wire-start -> its predicted completion fraction
            last = max(class_pred) or 1.0
            for g, tc in enumerate(class_pred):
                alloc("wire_class", cursor, d * (float(tc) / last),
                      sp.span_id,
                      {"pred": float(tc), "attributed": True,
                       "class": g,
                       "key": f"{wire.fingerprint}/c{g}"})
        cursor += d
    napp = max(program.applications, 1)
    pred_st = phases.get("stencil", 0.0)
    per = pred_st * scale / napp
    for a in range(napp):
        alloc("stencil", cursor, per, it.span_id,
              {"pred": pred_st / napp, "attributed": True,
               "application": a})
        cursor += per
    return it
