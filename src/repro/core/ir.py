"""Internal representation of datatypes and translation (paper §3.1).

Every committed MPI-like datatype is first *translated* into a ``Type``
tree whose nodes carry ``TypeData``:

* ``DenseData(offset, extent)``  — a run of contiguous bytes (plays the
  role of a named type).
* ``StreamData(offset, stride, count)`` — a strided sequence of ``count``
  elements of the (single) child type, ``stride`` bytes apart.

The tree structure mirrors the construction pattern of the MPI datatype;
equivalent datatypes may translate to *different* trees (Fig. 2), which
is exactly why the canonicalization pass (``repro.core.canonicalize``)
exists.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Union

from repro.core.datatypes import (
    Contiguous,
    Datatype,
    Hvector,
    Named,
    Subarray,
    Vector,
)

__all__ = ["DenseData", "StreamData", "Type", "translate"]


@dataclass
class DenseData:
    """A sequence of contiguous bytes (paper §3.1 item 1)."""

    offset: int  # bytes between the lower bound and the first byte
    extent: int  # number of contiguous bytes

    def clone(self) -> "DenseData":
        return DenseData(self.offset, self.extent)


@dataclass
class StreamData:
    """A strided stream of elements of the child type (paper §3.1 item 2)."""

    offset: int  # bytes, as DenseData
    stride: int  # bytes between the start of consecutive elements
    count: int   # number of elements in the stream

    def clone(self) -> "StreamData":
        return StreamData(self.offset, self.stride, self.count)


TypeData = Union[DenseData, StreamData]


@dataclass
class Type:
    """A node of the IR tree.  ``data`` discriminates the node kind; the
    nodes in our subset have zero (DenseData) or one (StreamData) child.
    """

    data: TypeData
    children: List["Type"] = field(default_factory=list)

    @property
    def child(self) -> Optional["Type"]:
        return self.children[0] if self.children else None

    def clone(self) -> "Type":
        return Type(self.data.clone(), [c.clone() for c in self.children])

    # -- debugging helpers --------------------------------------------------
    def pretty(self, indent: int = 0) -> str:
        pad = "  " * indent
        d = self.data
        if isinstance(d, DenseData):
            s = f"{pad}DenseData{{offset:{d.offset}, extent:{d.extent}}}"
        else:
            s = (
                f"{pad}StreamData{{offset:{d.offset}, count:{d.count}, "
                f"stride:{d.stride}}}"
            )
        return "\n".join([s] + [c.pretty(indent + 1) for c in self.children])

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return self.pretty()


# ---------------------------------------------------------------------------
# Translation (paper §3.1): one case per supported datatype constructor.
# ---------------------------------------------------------------------------

def translate(dt: Datatype) -> Type:
    """Convert an MPI-like datatype description into the ``Type`` IR.

    The recursion mirrors the paper: each constructor maps to a DenseData
    or StreamData node, then its ``oldtype`` is translated and attached as
    the child.  Named types are the base case.
    """
    if isinstance(dt, Named):
        # "translated into a DenseData with the extent field equal to the
        #  extent of the named type, and offset 0"
        return Type(DenseData(0, dt.extent))

    if isinstance(dt, Contiguous):
        # "a special case StreamData where the stride matches the size of
        #  the element.  It is not DenseData as oldtype may not be dense."
        return Type(
            StreamData(offset=0, stride=dt.oldtype.extent, count=dt.count),
            [translate(dt.oldtype)],
        )

    if isinstance(dt, Vector):
        # Two nested StreamData: parent = repeated blocks, child = repeated
        # elements within each block.
        child_stride = dt.oldtype.extent
        child = Type(
            StreamData(offset=0, stride=child_stride, count=dt.blocklength),
            [translate(dt.oldtype)],
        )
        parent = Type(
            StreamData(
                offset=0, stride=child_stride * dt.stride, count=dt.count
            ),
            [child],
        )
        return parent

    if isinstance(dt, Hvector):
        # As Vector, but the parent stride is given directly in bytes.
        child = Type(
            StreamData(
                offset=0, stride=dt.oldtype.extent, count=dt.blocklength
            ),
            [translate(dt.oldtype)],
        )
        parent = Type(
            StreamData(offset=0, stride=dt.stride_bytes, count=dt.count),
            [child],
        )
        return parent

    if isinstance(dt, Subarray):
        # A nest of StreamData equal to the dimension of the subarray.
        # Dimension i's stride is extent(oldtype) * prod(sizes[:i]); its
        # offset (given in elements) is converted to bytes.
        e = dt.oldtype.extent
        node = translate(dt.oldtype)
        for i in range(len(dt.sizes)):
            stride = e * math.prod(dt.sizes[:i])
            node = Type(
                StreamData(
                    offset=dt.starts[i] * stride,
                    stride=stride,
                    count=dt.subsizes[i],
                ),
                [node],
            )
        return node

    raise TypeError(f"cannot translate datatype of kind {type(dt).__name__}")
