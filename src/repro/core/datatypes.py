"""MPI-like derived datatype descriptions (paper §2).

This module provides the *user-facing* description language for
non-contiguous data layouts, mirroring the subset of MPI derived
datatypes the paper considers:

* ``Named``      — predefined base types (MPI_BYTE, MPI_FLOAT, ...)
* ``Contiguous`` — ``MPI_Type_contiguous``
* ``Vector``     — ``MPI_Type_vector`` (stride in elements of oldtype)
* ``Hvector``    — ``MPI_Type_create_hvector`` (stride in bytes)
* ``Subarray``   — ``MPI_Type_create_subarray``

Datatypes are immutable and hash-consable so they can key commit caches
(paper §4 "caching layer").  ``extent`` follows MPI semantics (distance
between lower and upper bound, i.e. the stride implied when the type is
repeated), while ``size`` is the number of bytes of actual data.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Tuple

__all__ = [
    "Datatype",
    "Named",
    "Contiguous",
    "Vector",
    "Hvector",
    "Subarray",
    "BYTE",
    "CHAR",
    "INT8",
    "INT16",
    "INT32",
    "INT64",
    "FLOAT16",
    "BFLOAT16",
    "FLOAT",
    "DOUBLE",
    "make_cuboid_subarray",
    "make_cuboid_hvector",
    "make_cuboid_vector_of_hvector",
]


@dataclass(frozen=True)
class Datatype:
    """Base class for all datatype descriptions."""

    @property
    def extent(self) -> int:
        """MPI extent in bytes: lower bound to upper bound."""
        raise NotImplementedError

    @property
    def size(self) -> int:
        """Number of bytes of real data described by one instance."""
        raise NotImplementedError

    # -- composition helpers (fluent construction used in tests/examples) --
    def contiguous(self, count: int) -> "Contiguous":
        return Contiguous(count, self)

    def vector(self, count: int, blocklength: int, stride: int) -> "Vector":
        return Vector(count, blocklength, stride, self)

    def hvector(self, count: int, blocklength: int, stride_bytes: int) -> "Hvector":
        return Hvector(count, blocklength, stride_bytes, self)


@dataclass(frozen=True)
class Named(Datatype):
    """A predefined ("named") MPI type, e.g. MPI_FLOAT (paper §2).

    ``width`` is the byte width of the underlying machine type.
    """

    name: str
    width: int

    def __post_init__(self):
        if self.width <= 0:
            raise ValueError(f"named type width must be positive: {self.width}")

    @property
    def extent(self) -> int:
        return self.width

    @property
    def size(self) -> int:
        return self.width


# Predefined named types (the ones used throughout the paper + bf16 for TPU).
BYTE = Named("MPI_BYTE", 1)
CHAR = Named("MPI_CHAR", 1)
INT8 = Named("MPI_INT8_T", 1)
INT16 = Named("MPI_INT16_T", 2)
INT32 = Named("MPI_INT32_T", 4)
INT64 = Named("MPI_INT64_T", 8)
FLOAT16 = Named("MPI_FLOAT16", 2)
BFLOAT16 = Named("MPI_BFLOAT16", 2)
FLOAT = Named("MPI_FLOAT", 4)
DOUBLE = Named("MPI_DOUBLE", 8)


@dataclass(frozen=True)
class Contiguous(Datatype):
    """``count`` contiguous repetitions of ``oldtype`` (MPI_Type_contiguous)."""

    count: int
    oldtype: Datatype

    def __post_init__(self):
        if self.count <= 0:
            raise ValueError(f"contiguous count must be positive: {self.count}")

    @property
    def extent(self) -> int:
        return self.count * self.oldtype.extent

    @property
    def size(self) -> int:
        return self.count * self.oldtype.size


@dataclass(frozen=True)
class Vector(Datatype):
    """``count`` blocks of ``blocklength`` oldtypes, block starts separated by
    ``stride`` oldtypes (MPI_Type_vector).
    """

    count: int
    blocklength: int
    stride: int
    oldtype: Datatype

    def __post_init__(self):
        if self.count <= 0 or self.blocklength <= 0:
            raise ValueError("vector count/blocklength must be positive")
        if self.stride < self.blocklength:
            # Overlapping blocks are legal MPI but never useful for packing;
            # the paper's subset excludes them.
            raise ValueError("vector stride must be >= blocklength")

    @property
    def extent(self) -> int:
        e = self.oldtype.extent
        return ((self.count - 1) * self.stride + self.blocklength) * e

    @property
    def size(self) -> int:
        return self.count * self.blocklength * self.oldtype.size


@dataclass(frozen=True)
class Hvector(Datatype):
    """Like Vector but ``stride_bytes`` is given directly in bytes
    (MPI_Type_create_hvector)."""

    count: int
    blocklength: int
    stride_bytes: int
    oldtype: Datatype

    def __post_init__(self):
        if self.count <= 0 or self.blocklength <= 0:
            raise ValueError("hvector count/blocklength must be positive")
        if self.stride_bytes < self.blocklength * self.oldtype.extent:
            raise ValueError("hvector stride_bytes must cover the block")

    @property
    def extent(self) -> int:
        return (self.count - 1) * self.stride_bytes + (
            self.blocklength * self.oldtype.extent
        )

    @property
    def size(self) -> int:
        return self.count * self.blocklength * self.oldtype.size


@dataclass(frozen=True)
class Subarray(Datatype):
    """n-dimensional subarray of an n-dimensional array
    (MPI_Type_create_subarray).

    Following the paper's Fig. 1/2 convention, index 0 of
    ``sizes``/``subsizes``/``starts`` is the *innermost* (fastest-varying,
    contiguous) dimension.  Pass ``order="C"`` to supply outermost-first
    arrays in NumPy/C convention instead; they are normalized on
    construction.
    """

    sizes: Tuple[int, ...]
    subsizes: Tuple[int, ...]
    starts: Tuple[int, ...]
    oldtype: Datatype
    order: str = "paper"

    def __post_init__(self):
        sizes = tuple(self.sizes)
        subsizes = tuple(self.subsizes)
        starts = tuple(self.starts)
        if self.order == "C":
            sizes, subsizes, starts = sizes[::-1], subsizes[::-1], starts[::-1]
        elif self.order != "paper":
            raise ValueError(f"unknown order {self.order!r}")
        object.__setattr__(self, "sizes", sizes)
        object.__setattr__(self, "subsizes", subsizes)
        object.__setattr__(self, "starts", starts)
        object.__setattr__(self, "order", "paper")
        n = len(sizes)
        if not (n == len(subsizes) == len(starts)) or n == 0:
            raise ValueError("sizes/subsizes/starts must have equal nonzero rank")
        for d in range(n):
            if not (0 < subsizes[d] <= sizes[d]):
                raise ValueError(f"subsize out of range in dim {d}")
            if not (0 <= starts[d] <= sizes[d] - subsizes[d]):
                raise ValueError(f"start out of range in dim {d}")

    @property
    def extent(self) -> int:
        # MPI: extent of a subarray type is the extent of the full array.
        return math.prod(self.sizes) * self.oldtype.extent

    @property
    def size(self) -> int:
        return math.prod(self.subsizes) * self.oldtype.size


# ---------------------------------------------------------------------------
# Convenience constructors for the paper's running 3D-object example (Fig. 1)
# ---------------------------------------------------------------------------

def make_cuboid_subarray(
    alloc: Tuple[int, int, int],
    ext: Tuple[int, int, int],
    starts: Tuple[int, int, int] = (0, 0, 0),
    oldtype: Datatype = BYTE,
) -> Subarray:
    """The 3D object of Fig. 1 described as a single 3D subarray of bytes."""
    return Subarray(alloc, ext, starts, oldtype)


def make_cuboid_hvector(
    alloc: Tuple[int, int, int],
    ext: Tuple[int, int, int],
    oldtype: Datatype = BYTE,
) -> Hvector:
    """Fig. 2 middle: hvector of hvector of vector."""
    e = oldtype.extent
    row = Vector(ext[0], 1, 1, oldtype)
    plane = Hvector(ext[1], 1, alloc[0] * e, row)
    return Hvector(ext[2], 1, alloc[0] * alloc[1] * e, plane)


def make_cuboid_vector_of_hvector(
    alloc: Tuple[int, int, int],
    ext: Tuple[int, int, int],
    oldtype: Datatype = BYTE,
) -> Vector:
    """Fig. 2 top: subarray-plane wrapped in a vector (paper's first snippet
    uses a 2D subarray plane and a vector of planes)."""
    plane = Subarray(alloc[:2], ext[:2], (0, 0), oldtype)
    return Vector(ext[2], 1, 1, plane)
