"""Type commit: translation + canonicalization + kernel selection + cache
(paper §3 intro, §3.3, §4 "caching layer").

``MPI_Type_commit`` is the boundary between datatype *construction* and
*use*.  Committing a datatype here runs the three phases once and caches
the result, so every later Pack/Unpack/Send on the type is a dictionary
lookup (amortized "tens of nanoseconds" in the paper):

    1. translate   -> Type IR            (repro.core.ir)
    2. simplify    -> canonical tree     (repro.core.canonicalize)
    3. kernel sel. -> StridedBlock + KernelKind + word width
"""

from __future__ import annotations

import enum
import hashlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.canonicalize import simplify
from repro.core.datatypes import Datatype
from repro.core.ir import DenseData, StreamData, Type, translate
from repro.core.strided_block import StridedBlock, strided_block

__all__ = [
    "KernelKind",
    "CommittedType",
    "TypeRegistry",
    "WireSegment",
    "commit",
    "registry",
]

#: bump when the structural description below changes shape, so stale
#: persisted selection caches keyed on old fingerprints never collide
_FINGERPRINT_VERSION = "ct.v1"


def _tree_key(ty: Type) -> Tuple:
    """Pure-data description of a canonical IR tree (GENERIC types have
    no StridedBlock, so the tree itself is the structure)."""
    d = ty.data
    if isinstance(d, DenseData):
        head: Tuple = ("dense", d.offset, d.extent)
    else:
        head = ("stream", d.offset, d.stride, d.count)
    return head + tuple(_tree_key(c) for c in ty.children)


@dataclass(frozen=True)
class WireSegment:
    """One committed type's slot in a flat wire buffer: the *exact*
    packed extent the type occupies on the wire, at a byte offset — no
    class padding, no row equalization.  This is the canonical
    representation's answer to "how many bytes does this object really
    put on the link": a per-peer wire layout is a sequence of these
    (see ``repro.comm.wireplan.WirePlan``).

    ``nbytes`` defaults to the packed member bytes; strategies whose
    wire format differs (a bounding window, a compressed payload) supply
    their own count — the descriptor carries whatever truly crosses the
    wire.
    """

    fingerprint: str   # content hash of the committed type it carries
    offset: int        # byte offset in the flat wire buffer
    nbytes: int        # exact wire extent of this segment

    @property
    def end(self) -> int:
        return self.offset + self.nbytes


class KernelKind(enum.Enum):
    """Which implementation handles the committed type (paper §3.3)."""

    CONTIG = "contig"      # 1D: single contiguous copy (memcpy analogue)
    KERNEL_2D = "kernel2d"  # 2D strided block -> Pallas pack kernel
    KERNEL_3D = "kernel3d"  # 3D strided block -> Pallas pack kernel
    KERNEL_ND = "kernelnd"  # >3D: outer loops around the 3D kernel
    GENERIC = "generic"     # not strided: offset/length list fallback


@dataclass(frozen=True)
class CommittedType:
    """Everything the runtime needs to operate on a datatype, computed
    once at commit time.  All fields are host scalars/tuples — nothing is
    stored in device memory (paper: "No object metadata is stored on the
    GPU").
    """

    datatype: Datatype
    tree: Type                      # canonical IR (for inspection/tests)
    block: Optional[StridedBlock]   # None iff kernel is GENERIC
    kernel: KernelKind
    word_bytes: int                 # W specialization (paper §3.3)

    @property
    def size(self) -> int:
        return self.datatype.size

    @property
    def extent(self) -> int:
        return self.datatype.extent

    @property
    def contiguous(self) -> bool:
        return self.kernel is KernelKind.CONTIG

    def structure_key(self) -> Tuple:
        """Canonical structural description of the committed type: what
        the runtime *does* with it, independent of how it was constructed
        or which registry committed it.  Equal canonical forms (paper
        Fig. 2: different construction, same object) share a key."""
        b = self.block
        blk = None if b is None else (b.start, b.counts, b.strides)
        return (
            _FINGERPRINT_VERSION,
            self.kernel.value,
            self.word_bytes,
            self.size,
            self.extent,
            blk if blk is not None else _tree_key(self.tree),
        )

    def packed_extent(self, incount: int = 1) -> int:
        """Exact bytes of real data ``incount`` repetitions of this type
        pack to — the wire extent of a pack-based transfer.  Never
        includes stride gaps or any per-class padding."""
        return self.size * incount

    def wire_segment(
        self, offset: int = 0, incount: int = 1, nbytes: Optional[int] = None
    ) -> "WireSegment":
        """The :class:`WireSegment` this type occupies in a flat wire
        buffer (``nbytes`` overrides the packed extent for strategies
        with a different wire format)."""
        return WireSegment(
            fingerprint=self.fingerprint,
            offset=offset,
            nbytes=self.packed_extent(incount) if nbytes is None else nbytes,
        )

    @property
    def fingerprint(self) -> str:
        """Stable content hash of :meth:`structure_key` — identical
        across registry re-commits and across processes, so it can key
        persistent caches (``repro.measure``).  ``id(ct)`` cannot."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            digest = hashlib.sha256(
                repr(self.structure_key()).encode()
            ).hexdigest()[:16]
            object.__setattr__(self, "_fingerprint", digest)
            fp = digest
        return fp


def _select_kernel(block: Optional[StridedBlock]) -> KernelKind:
    if block is None:
        return KernelKind.GENERIC
    if block.ndims == 1:
        return KernelKind.CONTIG
    if block.ndims == 2:
        return KernelKind.KERNEL_2D
    if block.ndims == 3:
        return KernelKind.KERNEL_3D
    return KernelKind.KERNEL_ND


class TypeRegistry:
    """Commit cache keyed by the (hashable, frozen) datatype description.

    Mirrors TEMPI's cache of per-committed-type packing strategies; the
    registry also memoizes the IR so benchmarks can separate "create"
    from "commit" cost (Fig. 6).
    """

    def __init__(self) -> None:
        self._cache: Dict[Datatype, CommittedType] = {}
        self.hits = 0
        self.misses = 0

    def commit(self, dt: Datatype) -> CommittedType:
        hit = self._cache.get(dt)
        if hit is not None:
            self.hits += 1
            return hit
        self.misses += 1
        tree = simplify(translate(dt))
        block = strided_block(tree)
        kind = _select_kernel(block)
        word = block.word_bytes() if block is not None else 1
        committed = CommittedType(
            datatype=dt, tree=tree, block=block, kernel=kind, word_bytes=word
        )
        self._cache[dt] = committed
        return committed

    def free(self, dt: Datatype) -> None:
        """MPI_Type_free analogue."""
        self._cache.pop(dt, None)

    def clear(self) -> None:
        self._cache.clear()
        self.hits = self.misses = 0

    def __len__(self) -> int:
        return len(self._cache)


#: Process-global registry, like TEMPI's interposer-internal state.
registry = TypeRegistry()


def commit(dt: Datatype) -> CommittedType:
    """Commit ``dt`` against the global registry."""
    return registry.commit(dt)
