"""Canonicalization of the Type IR (paper §3.2, Algorithms 1-3).

Two rewrites are iterated to a fixpoint:

* **Dense folding** (Alg. 2) — a ``StreamData`` whose stride equals the
  extent of its ``DenseData`` child describes one big contiguous run; the
  pair collapses into a single larger ``DenseData``.
* **Stream elision** (Alg. 3) — a child ``StreamData`` with ``count == 1``
  contributes nothing but an offset and is removed.

After the fixpoint, equivalent datatype constructions (Fig. 2) have
identical trees, which is what makes the compact ``StridedBlock``
representation (``repro.core.strided_block``) and the small generic
kernel family possible.

Deviations from the paper's pseudocode (documented, both strictly more
correct): (1) when a count-1 stream child is elided, its ``offset`` is
absorbed into the parent rather than dropped; (2) a count-1 *root* stream
is also elided (the paper's Alg. 3 only ever deletes child nodes, leaving
e.g. ``Vector(1, ...)`` roots uncanonical).
"""

from __future__ import annotations

from repro.core.ir import DenseData, StreamData, Type

__all__ = ["dense_folding", "stream_elision", "simplify"]


def dense_folding(ty: Type) -> bool:
    """Alg. 2.  Applied depth-first (fold from the bottom up).  Returns
    True iff the tree was modified.  Mutates ``ty`` in place."""
    changed = False
    for child in ty.children:
        changed = dense_folding(child) or changed

    if not isinstance(ty.data, StreamData):
        return changed
    if not ty.children:
        return changed
    child = ty.children[0]
    if not isinstance(child.data, DenseData):
        return changed

    c_data = child.data
    p_data = ty.data
    if c_data.extent == p_data.stride:
        # Replace the (stream over dense) pair with one large DenseData.
        ty.data = DenseData(
            offset=c_data.offset + p_data.offset,
            extent=p_data.count * p_data.stride,
        )
        ty.children = list(child.children)  # DenseData has none; keep shape
        changed = True
    return changed


def stream_elision(ty: Type) -> bool:
    """Alg. 3.  Applied depth-first.  Returns True iff modified.  Mutates
    ``ty`` in place."""
    changed = False
    for child in ty.children:
        changed = stream_elision(child) or changed

    if not isinstance(ty.data, StreamData):
        return changed
    if not ty.children:
        return changed
    child = ty.children[0]
    if not isinstance(child.data, StreamData):
        return changed

    c_data = child.data
    if c_data.count == 1:
        # The child is a single element: splice it out, keeping its offset.
        ty.data.offset += c_data.offset
        ty.children = list(child.children)
        changed = True
    return changed


def _elide_root(ty: Type) -> bool:
    """Elide a count-1 StreamData at the *root* (see module docstring)."""
    if (
        isinstance(ty.data, StreamData)
        and ty.data.count == 1
        and ty.children
    ):
        child = ty.children[0]
        child.data.offset += ty.data.offset
        ty.data = child.data
        ty.children = child.children
        return True
    return False


def simplify(ty: Type) -> Type:
    """Alg. 1: iterate the rewrites until neither changes the tree.

    Mutates and returns ``ty``.
    """
    changed = True
    while changed:
        changed = False
        changed = dense_folding(ty) or changed
        changed = stream_elision(ty) or changed
        changed = _elide_root(ty) or changed
    return ty
