"""StridedBlock: the compact canonical representation (paper §3.3, Alg. 4).

A ``StridedBlock`` is semantically a subarray: a byte ``start`` plus
per-dimension ``counts`` and ``strides`` (bytes).  Dimension 0 is the
innermost, contiguous run (stride 1, count = bytes per block); dimension
``k`` repeats dimension ``k-1`` ``counts[k]`` times at ``strides[k]``
bytes apart.

Crucially this is a *scalar* description — the paper's point is that no
per-type metadata need live in device memory; the pack/unpack kernels are
parameterized entirely by these scalars (``repro.kernels``).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

from repro.core.canonicalize import simplify
from repro.core.datatypes import Datatype
from repro.core.ir import DenseData, StreamData, Type, translate

__all__ = ["StridedBlock", "strided_block", "strided_block_of", "block_offsets"]


@dataclass(frozen=True)
class StridedBlock:
    start: int                     # byte offset of the first element
    counts: Tuple[int, ...]        # counts[0] = contiguous bytes per block
    strides: Tuple[int, ...]       # strides[0] == 1 (bytes)

    @property
    def ndims(self) -> int:
        return len(self.counts)

    @property
    def size(self) -> int:
        """Total bytes of real data."""
        return math.prod(self.counts)

    @property
    def extent(self) -> int:
        """Bytes from ``start`` to one past the last byte touched."""
        return sum((c - 1) * s for c, s in zip(self.counts, self.strides)) + 1

    @property
    def contig_bytes(self) -> int:
        """Bytes per contiguous block (the paper's 'contiguous block size')."""
        return self.counts[0]

    @property
    def num_blocks(self) -> int:
        return math.prod(self.counts[1:]) if self.ndims > 1 else 1

    def packed_bytes(self, incount: int = 1) -> int:
        """Exact packed wire extent of ``incount`` repetitions: the real
        data bytes only — the ragged wire layouts in ``repro.comm`` are
        built from this, never from the padded ``extent``."""
        return self.size * incount

    def word_bytes(self, max_word: int = 8) -> int:
        """Largest machine word width W that is aligned to the object and a
        factor of the contiguous block (paper §3.3's W specialization,
        adapted: on TPU we re-view the byte buffer at width W so the
        128-lane axis moves W-byte elements)."""
        g = self.counts[0]
        g = math.gcd(g, self.start)
        for s in self.strides[1:]:
            g = math.gcd(g, s)
        w = 1
        for cand in (2, 4, 8):
            if cand <= max_word and g % cand == 0:
                w = cand
        return w


def strided_block(ty: Type) -> Optional[StridedBlock]:
    """Alg. 4: convert a *canonicalized* Type tree into a StridedBlock.

    Returns None if the tree is not a pure stream-chain over a dense leaf
    (""Not strided"" in the paper) — callers then fall back to the generic
    block-list path.
    """
    # Walk the chain root -> leaf.
    datas = []
    cur: Optional[Type] = ty
    while cur is not None:
        datas.append(cur.data)
        if len(cur.children) > 1:
            return None  # not a chain (future: struct types)
        cur = cur.child

    # The chain is outermost-first; the leaf must be dense, everything
    # above a stream.
    leaf, streams = datas[-1], datas[:-1]
    if not isinstance(leaf, DenseData):
        return None
    start = leaf.offset
    counts: List[int] = [leaf.extent]
    strides: List[int] = [1]
    for d in reversed(streams):  # inner -> outer
        if not isinstance(d, StreamData):
            return None
        start += d.offset
        counts.append(d.count)
        strides.append(d.stride)
    return StridedBlock(start, tuple(counts), tuple(strides))


def strided_block_of(dt: Datatype) -> Optional[StridedBlock]:
    """Translate + canonicalize + convert in one call."""
    return strided_block(simplify(translate(dt)))


def block_offsets(sb: StridedBlock, incount: int = 1, extent: int = 0) -> Iterator[int]:
    """Yield the byte offset of every contiguous block, innermost-last
    ordering (i.e. the order in which bytes appear in the packed buffer).

    ``incount``/``extent`` implement the Pack/Unpack repetition: the
    datatype repeated ``incount`` times, ``extent`` bytes apart (paper
    §3.3: an extra outer dimension known only at the call).
    Used by the pure-python oracle and the generic fallback; the real
    kernels never materialize this list (that is the point of the paper).
    """
    outer = sb.counts[1:]
    ostr = sb.strides[1:]
    for rep in range(incount):
        base = sb.start + rep * extent
        idx = [0] * len(outer)
        while True:
            off = base
            for i, s in zip(idx, ostr):
                off += i * s
            yield off
            # odometer increment, dimension 0 of `outer` fastest
            d = 0
            while d < len(outer):
                idx[d] += 1
                if idx[d] < outer[d]:
                    break
                idx[d] = 0
                d += 1
            if d == len(outer):
                break
            if not outer:
                break
