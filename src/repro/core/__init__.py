"""repro.core — TEMPI's canonical datatype engine (paper §2-3).

Public API:

    from repro.core import (
        BYTE, FLOAT, Vector, Subarray, ...   # datatype constructors
        commit, registry,                    # MPI_Type_commit analogue
        StridedBlock, strided_block_of,      # canonical representation
    )
"""

from repro.core.canonicalize import dense_folding, simplify, stream_elision
from repro.core.commit import (
    CommittedType,
    KernelKind,
    TypeRegistry,
    WireSegment,
    commit,
    registry,
)
from repro.core.datatypes import (
    BFLOAT16,
    BYTE,
    CHAR,
    DOUBLE,
    FLOAT,
    FLOAT16,
    INT8,
    INT16,
    INT32,
    INT64,
    Contiguous,
    Datatype,
    Hvector,
    Named,
    Subarray,
    Vector,
    make_cuboid_hvector,
    make_cuboid_subarray,
    make_cuboid_vector_of_hvector,
)
from repro.core.ir import DenseData, StreamData, Type, translate
from repro.core.strided_block import (
    StridedBlock,
    block_offsets,
    strided_block,
    strided_block_of,
)

__all__ = [
    "BFLOAT16", "BYTE", "CHAR", "DOUBLE", "FLOAT", "FLOAT16",
    "INT8", "INT16", "INT32", "INT64",
    "Contiguous", "Datatype", "Hvector", "Named", "Subarray", "Vector",
    "make_cuboid_hvector", "make_cuboid_subarray",
    "make_cuboid_vector_of_hvector",
    "DenseData", "StreamData", "Type", "translate",
    "dense_folding", "simplify", "stream_elision",
    "CommittedType", "KernelKind", "TypeRegistry", "WireSegment",
    "commit", "registry",
    "StridedBlock", "block_offsets", "strided_block", "strided_block_of",
]
