"""Version compatibility shims for the pinned JAX (0.4.37).

The repo targets the modern JAX surface (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``); the pinned
release still spells those ``jax.experimental.shard_map.shard_map`` with
``check_rep`` and has no ``axis_types`` / ``jax.sharding.AxisType``.
Everything in the repo goes through these wrappers so the call sites
stay written against the modern API.
"""

from __future__ import annotations

import inspect
from typing import Any, Sequence

import jax

__all__ = ["shard_map", "make_mesh", "has_ragged_all_to_all",
           "ragged_all_to_all"]


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn, True
    from jax.experimental.shard_map import shard_map as legacy

    return legacy, False


_SHARD_MAP, _SHARD_MAP_IS_MODERN = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
              **kwargs):
    """``jax.shard_map`` with the ``check_vma`` spelling on every version.

    Older releases call the flag ``check_rep`` (same meaning: verify the
    claimed replication/varying-axes of outputs).
    """
    if _SHARD_MAP_IS_MODERN:
        return _SHARD_MAP(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kwargs,
        )
    return _SHARD_MAP(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, **kwargs,
    )


def has_ragged_all_to_all() -> bool:
    """Whether the running JAX exposes ``lax.ragged_all_to_all`` (the
    XLA ragged collective; added well after the pinned 0.4.37).  The
    wire planner (``repro.comm.wireplan``) consults this to decide
    whether a ragged neighborhood exchange can be a single collective
    or must lower to the grouped per-class ``ppermute`` schedule."""
    return hasattr(jax.lax, "ragged_all_to_all")


def ragged_all_to_all(operand, output, input_offsets, send_sizes,
                      output_offsets, recv_sizes, *, axis_name):
    """``lax.ragged_all_to_all`` passthrough.

    Callers must gate on :func:`has_ragged_all_to_all`; there is no
    emulation here on purpose — the byte-exact fallback (one ppermute
    per delta class) lives in the wire planner, where the payload
    accounting stays honest.
    """
    if not has_ragged_all_to_all():  # pragma: no cover - guarded upstream
        raise NotImplementedError(
            "lax.ragged_all_to_all is unavailable on this JAX; the wire "
            "planner should have selected the grouped schedule"
        )
    return jax.lax.ragged_all_to_all(  # pragma: no cover - needs new JAX
        operand, output, input_offsets, send_sizes, output_offsets,
        recv_sizes, axis_name=axis_name,
    )


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None, axis_types: Any = None):
    """``jax.make_mesh`` that tolerates missing ``axis_types`` support.

    ``axis_types`` defaults to all-Auto when the running JAX understands
    it, and is dropped entirely when it doesn't (the legacy behaviour is
    equivalent to Auto for every use in this repo).
    """
    params = inspect.signature(jax.make_mesh).parameters
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if "axis_types" in params:
        if axis_types is None:
            axis_type = getattr(jax.sharding, "AxisType", None)
            if axis_type is not None:
                axis_types = (axis_type.Auto,) * len(tuple(axis_names))
        if axis_types is not None:
            kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
