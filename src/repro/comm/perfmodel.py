"""Runtime performance model for datatype transfer strategies (paper §5).

The paper models three ways to move a non-contiguous GPU object between
ranks — "device" (Eq. 1), "one-shot" (Eq. 2), "staged" (Eq. 3) — from
once-measured system parameters, then picks the cheapest per call site
(§6.3: the model query is pure, interpolated, and cached; measured
selection overhead 277 ns).

TPU adaptation (DESIGN.md §2): there is no host-mapped zero-copy path,
so the strategy menu becomes

    rows      pack with the pitched row kernel, then one contiguous
              collective                                ≙ "device"
    dma       pack with the strided-descriptor kernel, then collective
                                                        ≙ "staged"
    xla       per-block XLA copies into a contiguous buffer (the naive
              CUDA-aware-MPI baseline all impls share)  ≙ baseline
    bounding  send the *contiguous bounding extent* of the object with
              no pack at all; receiver slices.  Wins when the object is
              dense in its extent                       ≙ "one-shot"
              (zero explicit staging, pays over-transfer instead of
              pack cost — the same trade the paper's one-shot makes)

Each strategy time decomposes as  T = T_pack + T_link(bytes) + T_unpack,
mirroring Eqs. 1–3, with terms read from a :class:`SystemParams` table —
either analytic TPU v5e constants or a table produced by
``repro.comm.calibrate`` (the paper's "binary that records system
performance parameters").
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.commit import CommittedType
from repro.kernels.geometry import plan_geometry

__all__ = ["SystemParams", "StrategyEstimate", "PerfModel", "TPU_V5E"]


@dataclass(frozen=True)
class SystemParams:
    """Measured or analytic system parameters (paper Fig. 9/10 tables)."""

    name: str
    hbm_bw: float = 819e9          # bytes/s per chip
    ici_bw: float = 45e9           # effective bytes/s per link (50 GB/s raw)
    ici_latency: float = 1.0e-6    # per-hop collective latency floor
    kernel_launch: float = 1.5e-6  # pallas_call fixed cost
    dma_setup: float = 4.0e-7      # per strided-DMA-descriptor cost
    xla_copy_overhead: float = 8.0e-7  # per dynamic-slice copy op
    # optional measured pack tables: {strategy: [[log2_block, log2_total,
    # seconds], ...]} — sparse grid, bilinear-interpolated in log space
    pack_table: Optional[Dict[str, Tuple[Tuple[float, float, float], ...]]] = None

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "SystemParams":
        d = json.loads(s)
        if d.get("pack_table"):
            d["pack_table"] = {
                k: tuple(tuple(row) for row in v)
                for k, v in d["pack_table"].items()
            }
        return SystemParams(**d)


#: Analytic TPU v5e table (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
#: ICI) — shipped for dry-run containers with no TPU to calibrate on.
TPU_V5E = SystemParams(name="tpu_v5e_analytic")


@dataclass(frozen=True)
class StrategyEstimate:
    strategy: str
    t_pack: float
    t_link: float
    t_unpack: float

    @property
    def total(self) -> float:
        return self.t_pack + self.t_link + self.t_unpack


def _interp2d(table, x, y) -> Optional[float]:
    """Bilinear interpolation on a sparse (log2 block, log2 total) grid.

    The paper interpolates pack cost from the stride and block length of
    the datatype (§6.3); we key on (contiguous block bytes, total bytes).
    """
    if not table:
        return None
    import numpy as np

    pts = np.asarray(table, dtype=float)
    xs = np.unique(pts[:, 0])
    ys = np.unique(pts[:, 1])
    if len(xs) < 2 or len(ys) < 2:
        return None
    grid = {(a, b): v for a, b, v in pts}
    x = min(max(x, xs[0]), xs[-1])
    y = min(max(y, ys[0]), ys[-1])
    i = int(np.searchsorted(xs, x, side="right") - 1)
    j = int(np.searchsorted(ys, y, side="right") - 1)
    i = min(i, len(xs) - 2)
    j = min(j, len(ys) - 2)
    x0, x1 = xs[i], xs[i + 1]
    y0, y1 = ys[j], ys[j + 1]
    try:
        q00 = grid[(x0, y0)]
        q01 = grid[(x0, y1)]
        q10 = grid[(x1, y0)]
        q11 = grid[(x1, y1)]
    except KeyError:
        return None
    tx = (x - x0) / (x1 - x0)
    ty = (y - y0) / (y1 - y0)
    return float(
        q00 * (1 - tx) * (1 - ty)
        + q10 * tx * (1 - ty)
        + q01 * (1 - tx) * ty
        + q11 * tx * ty
    )


class PerfModel:
    """Strategy selection per (committed type, incount, hop count).

    Queries are pure functions of their arguments, so results are cached
    (paper §4/§6.3) — after the first call for a given type the decision
    is a dict lookup.
    """

    def __init__(self, params: SystemParams = TPU_V5E):
        self.params = params
        self._cache: Dict[Tuple[int, int, int], StrategyEstimate] = {}
        self.lookups = 0
        self.hits = 0

    # -- pack-side term -----------------------------------------------------
    def _measured(self, strategy: str, contig: int, total: int) -> Optional[float]:
        t = self.params.pack_table
        if not t or strategy not in t:
            return None
        return _interp2d(
            t[strategy], math.log2(max(contig, 1)), math.log2(max(total, 1))
        )

    def t_pack(self, ct: CommittedType, incount: int, strategy: str) -> float:
        p = self.params
        size = ct.size * incount
        sb = ct.block
        if sb is None:
            return p.kernel_launch + 2 * size / p.hbm_bw
        contig = sb.counts[0]
        m = self._measured(strategy, contig, size)
        if m is not None:
            return m
        geom = plan_geometry(sb)
        nblocks = sb.num_blocks * incount
        if strategy == "rows":
            over = geom.overfetch if geom else 1.0
            touched = size * over + size  # pitched read + contiguous write
            return p.kernel_launch + touched / p.hbm_bw
        if strategy == "dma":
            chunks = max(nblocks // 128, 1)  # descriptors per ~128-row chunk
            return p.kernel_launch + chunks * p.dma_setup + 2 * size / p.hbm_bw
        if strategy == "xla":
            return nblocks * p.xla_copy_overhead + 2 * size / p.hbm_bw
        if strategy == "bounding":
            return 0.0  # no pack at all
        raise ValueError(strategy)

    def t_unpack(self, ct: CommittedType, incount: int, strategy: str) -> float:
        # unpack is slower: strided writes; rows strategy pays pitch
        # read+write (paper §6.3 observes the same pack/unpack asymmetry)
        base = self.t_pack(ct, incount, strategy)
        return base * 1.5 if strategy != "bounding" else 0.0

    # -- link term ------------------------------------------------------
    def t_link(self, nbytes: int, hops: int = 1) -> float:
        p = self.params
        return hops * p.ici_latency + nbytes / p.ici_bw

    # -- full strategy estimates (Eqs. 1-3 analogue) ----------------------
    def estimate(
        self, ct: CommittedType, incount: int, strategy: str, hops: int = 1
    ) -> StrategyEstimate:
        size = ct.size * incount
        if strategy == "bounding":
            sb = ct.block
            wire = (sb.extent if sb is not None else ct.extent) * incount
            if sb is not None and sb.size == sb.extent:
                t_extract = 0.0  # fully dense: the wire bytes ARE the data
            else:
                # receiver must extract the member bytes from the bounding
                # window and splice them into the destination (two kernels)
                t_extract = self.t_pack(ct, incount, "rows") + self.t_unpack(
                    ct, incount, "rows"
                )
            return StrategyEstimate(
                "bounding", 0.0, self.t_link(wire, hops), t_extract
            )
        return StrategyEstimate(
            strategy,
            self.t_pack(ct, incount, strategy),
            self.t_link(size, hops),
            self.t_unpack(ct, incount, strategy),
        )

    def select(
        self,
        ct: CommittedType,
        incount: int = 1,
        hops: int = 1,
        allow_bounding: bool = True,
    ) -> StrategyEstimate:
        """Pick the cheapest strategy (cached per call signature)."""
        key = (id(ct), incount, hops, allow_bounding)
        self.lookups += 1
        hit = self._cache.get(key)
        if hit is not None:
            self.hits += 1
            return hit
        cands = ["xla", "bounding"] if allow_bounding else ["xla"]
        if ct.block is not None and plan_geometry(ct.block) is not None:
            cands += ["rows", "dma"]
        best = min(
            (self.estimate(ct, incount, s, hops) for s in cands),
            key=lambda e: e.total,
        )
        self._cache[key] = best
        return best
