"""Runtime performance model for datatype transfer strategies (paper §5).

The paper models three ways to move a non-contiguous GPU object between
ranks — "device" (Eq. 1), "one-shot" (Eq. 2), "staged" (Eq. 3) — from
once-measured system parameters, then picks the cheapest per call site
(§6.3: the model query is pure, interpolated, and cached; measured
selection overhead 277 ns).

TPU adaptation (DESIGN.md §2): there is no host-mapped zero-copy path,
so the strategy menu becomes

    rows      pack with the pitched row kernel, then one contiguous
              collective                                ≙ "device"
    dma       pack with the strided-descriptor kernel, then collective
                                                        ≙ "staged"
    xla       per-block XLA copies into a contiguous buffer (the naive
              CUDA-aware-MPI baseline all impls share)  ≙ baseline
    bounding  send the *contiguous bounding extent* of the object with
              no pack at all; receiver slices.  Wins when the object is
              dense in its extent                       ≙ "one-shot"
              (zero explicit staging, pays over-transfer instead of
              pack cost — the same trade the paper's one-shot makes)

Each strategy time decomposes as  T = T_pack + T_link(bytes) + T_unpack,
mirroring Eqs. 1–3, with terms read from a :class:`SystemParams` table —
either analytic TPU v5e constants or a table produced by
``repro.comm.calibrate`` (the paper's "binary that records system
performance parameters").
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.commit import CommittedType

__all__ = ["SystemParams", "StrategyEstimate", "PerfModel", "TPU_V5E"]


@dataclass(frozen=True)
class SystemParams:
    """Measured or analytic system parameters (paper Fig. 9/10 tables)."""

    name: str
    hbm_bw: float = 819e9          # bytes/s per chip
    ici_bw: float = 45e9           # effective bytes/s per link (50 GB/s raw)
    ici_latency: float = 1.0e-6    # per-hop collective latency floor
    kernel_launch: float = 1.5e-6  # pallas_call fixed cost
    dma_setup: float = 4.0e-7      # per strided-DMA-descriptor cost
    xla_copy_overhead: float = 8.0e-7  # per dynamic-slice copy op
    # optional measured pack tables: {strategy: [[log2_block, log2_total,
    # seconds], ...]} — sparse grid, bilinear-interpolated in log space
    pack_table: Optional[Dict[str, Tuple[Tuple[float, float, float], ...]]] = None

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "SystemParams":
        d = json.loads(s)
        if d.get("pack_table"):
            d["pack_table"] = {
                k: tuple(tuple(row) for row in v)
                for k, v in d["pack_table"].items()
            }
        return SystemParams(**d)


#: Analytic TPU v5e table (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
#: ICI) — shipped for dry-run containers with no TPU to calibrate on.
TPU_V5E = SystemParams(name="tpu_v5e_analytic")


@dataclass(frozen=True)
class StrategyEstimate:
    strategy: str
    t_pack: float
    t_link: float
    t_unpack: float

    @property
    def total(self) -> float:
        return self.t_pack + self.t_link + self.t_unpack


def _interp2d(table, x, y) -> Optional[float]:
    """Bilinear interpolation on a sparse (log2 block, log2 total) grid.

    The paper interpolates pack cost from the stride and block length of
    the datatype (§6.3); we key on (contiguous block bytes, total bytes).
    """
    if not table:
        return None
    import numpy as np

    pts = np.asarray(table, dtype=float)
    xs = np.unique(pts[:, 0])
    ys = np.unique(pts[:, 1])
    if len(xs) < 2 or len(ys) < 2:
        return None
    grid = {(a, b): v for a, b, v in pts}
    x = min(max(x, xs[0]), xs[-1])
    y = min(max(y, ys[0]), ys[-1])
    i = int(np.searchsorted(xs, x, side="right") - 1)
    j = int(np.searchsorted(ys, y, side="right") - 1)
    i = min(i, len(xs) - 2)
    j = min(j, len(ys) - 2)
    x0, x1 = xs[i], xs[i + 1]
    y0, y1 = ys[j], ys[j + 1]
    try:
        q00 = grid[(x0, y0)]
        q01 = grid[(x0, y1)]
        q10 = grid[(x1, y0)]
        q11 = grid[(x1, y1)]
    except KeyError:
        return None
    tx = (x - x0) / (x1 - x0)
    ty = (y - y0) / (y1 - y0)
    return float(
        q00 * (1 - tx) * (1 - ty)
        + q10 * tx * (1 - ty)
        + q01 * (1 - tx) * ty
        + q11 * tx * ty
    )


class PerfModel:
    """Strategy selection per (committed type, incount, hop count).

    The per-strategy cost formulas live on the
    :class:`~repro.comm.api.Strategy` plugins themselves; this model
    supplies the shared terms (link time, measured pack tables, system
    parameters) and picks the cheapest among whatever strategies are
    registered.  Queries are pure functions of their arguments, so
    results are cached (paper §4/§6.3) — after the first call for a
    given type the decision is a dict lookup.
    """

    def __init__(self, params: SystemParams = TPU_V5E):
        self.params = params
        self._cache: Dict[Tuple, StrategyEstimate] = {}
        self.lookups = 0
        self.hits = 0

    @staticmethod
    def _resolve(strategy, registry=None):
        from repro.comm.api import resolve_strategy

        return resolve_strategy(strategy, registry)

    # -- measured pack tables -------------------------------------------
    def measured(self, strategy: str, contig: int, total: int) -> Optional[float]:
        """Interpolated measured pack time for a named strategy, or None
        when no calibration table covers it."""
        t = self.params.pack_table
        if not t or strategy not in t:
            return None
        return _interp2d(
            t[strategy], math.log2(max(contig, 1)), math.log2(max(total, 1))
        )

    # -- per-strategy terms (delegate to the registered plugin) ---------
    def t_pack(self, ct: CommittedType, incount: int, strategy) -> float:
        return self._resolve(strategy).model_pack(self, ct, incount)

    def t_unpack(self, ct: CommittedType, incount: int, strategy) -> float:
        return self._resolve(strategy).model_unpack(self, ct, incount)

    # -- link term ------------------------------------------------------
    def t_link(self, nbytes: int, hops: int = 1) -> float:
        p = self.params
        return hops * p.ici_latency + nbytes / p.ici_bw

    # -- full strategy estimates (Eqs. 1-3 analogue) ----------------------
    def estimate(
        self, ct: CommittedType, incount: int, strategy, hops: int = 1
    ) -> StrategyEstimate:
        return self._resolve(strategy).plan(self, ct, incount, hops)

    def select(
        self,
        ct: CommittedType,
        incount: int = 1,
        hops: int = 1,
        allow_bounding: bool = True,
        registry=None,
    ) -> StrategyEstimate:
        """Pick the cheapest applicable registered strategy (cached per
        call signature).  ``allow_bounding`` admits wire-only strategies
        (data actually crosses a link, so shipping the bounding window
        is meaningful)."""
        if registry is None:
            from repro.comm.api import default_registry

            registry = default_registry()
        # keyed on the registry's mutation counter so a newly registered
        # plugin invalidates prior selections
        key = (id(ct), incount, hops, allow_bounding, id(registry),
               registry.version)
        self.lookups += 1
        hit = self._cache.get(key)
        if hit is not None:
            self.hits += 1
            return hit
        cands = [
            s
            for s in registry.selectable()
            if (allow_bounding or not s.wire_only) and s.applicable(ct)
        ]
        if not cands:
            raise ValueError(f"no applicable strategy registered for {ct!r}")
        best = min(
            (s.plan(self, ct, incount, hops) for s in cands),
            key=lambda e: e.total,
        )
        self._cache[key] = best
        return best
