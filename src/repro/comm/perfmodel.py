"""Runtime performance model for datatype transfer strategies (paper §5).

The paper models three ways to move a non-contiguous GPU object between
ranks — "device" (Eq. 1), "one-shot" (Eq. 2), "staged" (Eq. 3) — from
once-measured system parameters, then picks the cheapest per call site
(§6.3: the model query is pure, interpolated, and cached; measured
selection overhead 277 ns).

TPU adaptation (DESIGN.md §2): there is no host-mapped zero-copy path,
so the strategy menu becomes

    rows      pack with the pitched row kernel, then one contiguous
              collective                                ≙ "device"
    dma       pack with the strided-descriptor kernel, then collective
                                                        ≙ "staged"
    xla       per-block XLA copies into a contiguous buffer (the naive
              CUDA-aware-MPI baseline all impls share)  ≙ baseline
    bounding  send the *contiguous bounding extent* of the object with
              no pack at all; receiver slices.  Wins when the object is
              dense in its extent                       ≙ "one-shot"
              (zero explicit staging, pays over-transfer instead of
              pack cost — the same trade the paper's one-shot makes)

Each strategy time decomposes as  T = T_pack + T_link(bytes) + T_unpack,
mirroring Eqs. 1–3, with terms read from a :class:`SystemParams` table —
either analytic TPU v5e constants or the measured full-term tables
produced by ``repro.measure`` (the paper's "binary that records system
performance parameters"); see ``docs/measure.md``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro.core.commit import CommittedType
from repro.comm.topology import Topology

__all__ = [
    "SystemParams",
    "StrategyEstimate",
    "ProgramEstimate",
    "OverlapEstimate",
    "PerfModel",
    "TPU_V5E",
    "synthetic_two_tier",
]


#: 2D measured table rows: (log2_contig_block_bytes, log2_total_bytes, sec)
Table2D = Tuple[Tuple[float, float, float], ...]
#: 1D measured table rows: (log2_total_bytes, sec)
Table1D = Tuple[Tuple[float, float], ...]


def _freeze2d(v) -> Optional[Dict[str, Table2D]]:
    if not v:
        return None
    return {k: tuple(tuple(row) for row in rows) for k, rows in v.items()}


def _freeze1d(v) -> Optional[Table1D]:
    if not v:
        return None
    return tuple(tuple(row) for row in v)


def _freeze_axis_tables(v) -> Optional[Dict[str, Table1D]]:
    if not v:
        return None
    return {k: tuple(tuple(row) for row in rows) for k, rows in v.items()}


def _freeze_axis_fits(v) -> Optional[Dict[str, Tuple]]:
    if not v:
        return None
    return {k: tuple(fit) for k, fit in v.items()}


@dataclass(frozen=True)
class SystemParams:
    """Measured or analytic system parameters (paper Fig. 9/10 tables).

    The analytic constants are the fallback; a full-term calibration
    (``repro.measure``) fills the optional measured tables and the model
    then consults them for *every* term of T = T_pack + T_link +
    T_unpack, as the paper's once-recorded filesystem measurements do.
    """

    name: str
    hbm_bw: float = 819e9          # bytes/s per chip
    ici_bw: float = 45e9           # effective bytes/s per link (50 GB/s raw)
    ici_latency: float = 1.0e-6    # per-hop collective latency floor
    kernel_launch: float = 1.5e-6  # pallas_call fixed cost
    dma_setup: float = 4.0e-7      # per strided-DMA-descriptor cost
    xla_copy_overhead: float = 8.0e-7  # per dynamic-slice copy op
    # measured tables ({strategy: rows} / rows) — sparse grids in log2
    # space, interpolated at query time (nearest-neighbor off-grid)
    pack_table: Optional[Dict[str, Table2D]] = None
    unpack_table: Optional[Dict[str, Table2D]] = None
    wire_table: Optional[Table1D] = None   # one-hop collective time
    copy_table: Optional[Table1D] = None   # contiguous device copy time
    # least-squares (latency, bandwidth) fit of wire_table; used for the
    # per-extra-hop latency term when the table drives t_link
    wire_latency: Optional[float] = None
    wire_bw: Optional[float] = None
    # per-mesh-axis wire measurements: a multi-axis mesh (e.g. a fast
    # ICI axis and a slow DCN axis) has genuinely different link terms
    # per axis, so the calibration sweeps each axis's ring separately
    # and t_link(axis=...) consults the matching table; the flat
    # wire_table remains the axis-agnostic fallback
    wire_tables: Optional[Dict[str, Table1D]] = None
    wire_fits: Optional[Dict[str, Tuple]] = None  # axis -> (latency, bw)
    # per-LINK-CLASS wire measurements (STORE_FORMAT 5): a two-level
    # machine has a fast intra-node tier and a slow inter-node tier, and
    # t_link(link_class=...) consults these before the per-axis/flat
    # tables.  Keys are "<class>" or "<axis>/<class>" for
    # class in repro.comm.topology.LINK_CLASSES; pre-format-5 envelopes
    # load with these None — the flat table then prices every class,
    # i.e. everything is treated as ``intra``
    link_tables: Optional[Dict[str, Table1D]] = None
    link_fits: Optional[Dict[str, Tuple]] = None  # key -> (latency, bw)
    # measured stencil-application sweep: rows (log2_neighbors,
    # log2_window_bytes, sec) — prices the deep-halo redundant-compute
    # term from a real sweep instead of the contiguous-copy proxy
    stencil_table: Optional[Table2D] = None
    # measured compress/decompress sweep (STORE_FORMAT 6): per wire
    # compressor, rows (log2_total_bytes, compress_sec, decompress_sec,
    # achieved_ratio_sample) — prices the pack-side cost of a compressed
    # schedule from a real sweep instead of the 2x-HBM-sweep analytic
    # proxy.  The ratio column is a *sample* on the sweep's synthetic
    # payload, recorded for reference; the ratio the model prices a
    # schedule at always comes from a probe of the actual payload.
    compress_table: Optional[Dict[str, Table2D]] = None

    def __post_init__(self):
        # normalize list-of-lists (JSON) into hashable tuple tables
        object.__setattr__(self, "pack_table", _freeze2d(self.pack_table))
        object.__setattr__(self, "unpack_table", _freeze2d(self.unpack_table))
        object.__setattr__(self, "wire_table", _freeze1d(self.wire_table))
        object.__setattr__(self, "copy_table", _freeze1d(self.copy_table))
        object.__setattr__(
            self, "wire_tables", _freeze_axis_tables(self.wire_tables)
        )
        object.__setattr__(self, "wire_fits", _freeze_axis_fits(self.wire_fits))
        object.__setattr__(
            self, "link_tables", _freeze_axis_tables(self.link_tables)
        )
        object.__setattr__(self, "link_fits", _freeze_axis_fits(self.link_fits))
        object.__setattr__(self, "stencil_table", _freeze1d(self.stencil_table))
        object.__setattr__(
            self, "compress_table", _freeze2d(self.compress_table)
        )

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "SystemParams":
        d = json.loads(s)
        known = {f.name for f in dataclasses.fields(SystemParams)}
        d = {k: v for k, v in d.items() if k in known}
        return SystemParams(**d)


#: Analytic TPU v5e table (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
#: ICI) — shipped for dry-run containers with no TPU to calibrate on.
TPU_V5E = SystemParams(name="tpu_v5e_analytic")


def synthetic_two_tier(
    params: SystemParams,
    latency_factor: float = 20.0,
    bandwidth_factor: float = 4.0,
) -> SystemParams:
    """Derive a two-tier parameter set from single-tier measurements.

    CI has no multi-node hardware, but the simulated-scale gate still
    needs an ``inter`` tier to price.  This takes the params' flat (or
    axis-default) wire sweep as the ``intra`` table and synthesizes the
    ``inter`` table by degrading it — each row's time becomes
    ``t * bandwidth_factor + (latency_factor - 1) * lat0`` with ``lat0``
    the fitted (or analytic) one-hop latency, i.e. a link that is
    ``bandwidth_factor`` x thinner and ``latency_factor`` x laggier, the
    usual DCN-vs-ICI shape.  ``latency_factor = bandwidth_factor = 1``
    gives ``inter == intra`` exactly — the oracle configuration under
    which tier-aware pricing must reproduce flat pricing bit-for-bit.
    """
    table = params.wire_table
    lat0 = params.wire_latency
    bw0 = params.wire_bw
    if not table:
        # no sweep calibrated: build a two-point analytic table so the
        # tiers are still priceable (dry-run containers)
        lat0 = params.ici_latency
        bw0 = params.ici_bw
        table = tuple(
            (float(x), lat0 + (2.0 ** x) / bw0) for x in (10.0, 22.0)
        )
    if lat0 is None:
        lat0 = params.ici_latency
    extra_lat = (latency_factor - 1.0) * lat0
    inter = tuple(
        (x, t * bandwidth_factor + extra_lat) for x, t in table
    )
    link_fits = {}
    if lat0 is not None and bw0 is not None:
        link_fits["intra"] = (lat0, bw0)
        link_fits["inter"] = (lat0 * latency_factor, bw0 / bandwidth_factor)
    return dataclasses.replace(
        params,
        link_tables={"intra": table, "inter": inter},
        link_fits=link_fits or None,
    )


@dataclass(frozen=True)
class StrategyEstimate:
    strategy: str
    t_pack: float
    t_link: float
    t_unpack: float
    #: exact bytes this strategy puts on the wire (0 when the estimate
    #: predates wire accounting, e.g. hand-built test fixtures)
    wire_bytes: int = 0

    @property
    def total(self) -> float:
        return self.t_pack + self.t_link + self.t_unpack


@dataclass(frozen=True)
class ProgramEstimate:
    """Predicted cost of one deep-halo iteration: a single exchange at
    halo depth ``steps * cycle_radii`` amortized over ``steps`` repeats
    of a (possibly heterogeneous) op cycle, plus the redundant
    ghost-shell re-evaluation the shrinking-region schedule pays instead
    of the saved exchanges.

    ``steps`` counts cycle repeats; :attr:`applications` counts the
    individual stencil applications (``steps * cycle_len``; equal to
    ``steps`` for the single-op cycle).  :attr:`op_redundant` splits
    :attr:`t_redundant` per op *position in the cycle* (summed over the
    repeats), so the audit shows which op of a predictor/corrector pair
    is buying the ghost shells.

    The figure of merit is :attr:`per_step` — seconds per stencil
    application — which is what :func:`PerfModel.price_program`
    minimizes when ``--halo-steps auto`` picks the fusion depth.
    """

    steps: int
    t_exchange: float   # one deep exchange: member pack/unpack + wire
    t_redundant: float  # ghost-region re-evaluation across the fused steps
    wire_bytes: int     # bytes that one exchange puts on the wire
    cycle_len: int = 1  # ops per cycle pass (1 = the single-op program)
    #: redundant seconds per cycle position, summed over the repeats
    #: (empty for estimates built before cycles existed)
    op_redundant: Tuple[float, ...] = ()

    @property
    def applications(self) -> int:
        """Stencil applications one iteration performs."""
        return self.steps * max(self.cycle_len, 1)

    @property
    def total(self) -> float:
        return self.t_exchange + self.t_redundant

    @property
    def per_step(self) -> float:
        """Seconds per stencil application (the argmin of the auto
        chooser)."""
        return self.total / max(self.applications, 1)

    @property
    def per_cycle(self) -> float:
        """Seconds per cycle repeat."""
        return self.total / max(self.steps, 1)


@dataclass(frozen=True)
class OverlapEstimate:
    """Predicted cost of hiding one halo exchange behind compute, for
    one overlap mode.

    ``monolithic`` waits for the fused collective then applies every
    rim region: ``max(wire, core) + sum(rims)``.  ``region`` drains
    delta classes as they complete and computes each rim region as soon
    as its dependency classes have landed, on a single compute
    resource: the core runs first, then rims in ready order, each
    starting at ``max(busy, ready)``.  ``class_completions`` is the
    per-class wire completion profile the region simulation consumed
    (:meth:`PerfModel.price_class_completions`)."""

    mode: str
    t_total: float
    t_core: float
    t_wire: float
    t_rims: Tuple[float, ...] = ()
    class_completions: Tuple[float, ...] = ()


class _Interp2D:
    """Bilinear interpolation on a sparse (log2 block, log2 total) grid.

    The paper interpolates pack cost from the stride and block length of
    the datatype (§6.3); we key on (contiguous block bytes, total bytes).
    The axis vectors, the dense grid (NaN holes), and the raw point list
    are built ONCE per table; queries are a couple of searchsorteds.
    Cells with missing corners — and degenerate single-row/column grids —
    fall back to the nearest measured point rather than "no answer".
    """

    def __init__(self, table: Table2D):
        import numpy as np

        self._np = np
        pts = np.asarray(table, dtype=float)
        self.pts = pts
        self.xs = np.unique(pts[:, 0])
        self.ys = np.unique(pts[:, 1])
        grid = np.full((len(self.xs), len(self.ys)), np.nan)
        xi = np.searchsorted(self.xs, pts[:, 0])
        yi = np.searchsorted(self.ys, pts[:, 1])
        grid[xi, yi] = pts[:, 2]
        self.grid = grid

    def _nearest(self, x: float, y: float) -> float:
        np = self._np
        d = (self.pts[:, 0] - x) ** 2 + (self.pts[:, 1] - y) ** 2
        return float(self.pts[int(np.argmin(d)), 2])

    def __call__(self, x: float, y: float) -> float:
        np = self._np
        xs, ys = self.xs, self.ys
        if len(xs) < 2 or len(ys) < 2:
            return self._nearest(x, y)
        x = min(max(x, xs[0]), xs[-1])
        y = min(max(y, ys[0]), ys[-1])
        i = min(int(np.searchsorted(xs, x, side="right") - 1), len(xs) - 2)
        j = min(int(np.searchsorted(ys, y, side="right") - 1), len(ys) - 2)
        q = self.grid[i : i + 2, j : j + 2]
        if np.isnan(q).any():
            return self._nearest(x, y)
        tx = (x - xs[i]) / (xs[i + 1] - xs[i])
        ty = (y - ys[j]) / (ys[j + 1] - ys[j])
        return float(
            q[0, 0] * (1 - tx) * (1 - ty)
            + q[1, 0] * tx * (1 - ty)
            + q[0, 1] * (1 - tx) * ty
            + q[1, 1] * tx * ty
        )


class _Interp1D:
    """Piecewise-linear interpolation on a (log2 total) -> seconds table,
    clamped at the ends (same precompute-once contract as _Interp2D)."""

    def __init__(self, table: Table1D):
        import numpy as np

        self._np = np
        pts = np.asarray(sorted(table), dtype=float)
        self.xs = pts[:, 0]
        self.vs = pts[:, 1]

    def __call__(self, x: float) -> float:
        return float(self._np.interp(x, self.xs, self.vs))


def _interp2d(table, x, y) -> Optional[float]:
    """Interpolated lookup on a measured 2D table (None iff empty).
    Builds the interpolator fresh — model queries go through the
    per-:class:`PerfModel` cache instead."""
    if not table:
        return None
    return _Interp2D(tuple(tuple(r) for r in table))(x, y)


class PerfModel:
    """Strategy selection per (committed type, incount, hop count).

    The per-strategy cost formulas live on the
    :class:`~repro.comm.api.Strategy` plugins themselves; this model
    supplies the shared terms (link time, measured pack tables, system
    parameters) and picks the cheapest among whatever strategies are
    registered.  Queries are pure functions of their arguments, so
    results are cached (paper §4/§6.3) — after the first call for a
    given type the decision is a dict lookup.
    """

    def __init__(self, params: SystemParams = TPU_V5E, decisions=None,
                 axis: Optional[str] = None,
                 topology: Optional[Topology] = None):
        self.params = params
        #: optional repro.measure.decisions.DecisionCache — pins choices
        #: across processes and records the audit log
        self.decisions = decisions
        #: default mesh axis whose wire table prices t_link (a model
        #: bound to a multi-axis mesh's DCN axis must not price its
        #: links with the ICI sweep); per-call override on t_link
        self.axis = axis
        #: optional rank->node map: annotated plans price each delta
        #: class by the slowest link tier it crosses and the planner may
        #: coalesce inter-tier classes (``tiered``); rebound by
        #: ``train.elastic.replan_on_remesh`` when the machine reshapes
        self.topology = topology
        self._cache: Dict[Tuple, StrategyEstimate] = {}
        # interpolators precomputed once per measured table, keyed by the
        # (frozen, hashable) table itself so their lifetime is tied to
        # this model — a process-global cache would pin every table ever
        # queried (tests, re-calibrations) for the life of the process
        self._interp: Dict[Tuple, object] = {}
        self.lookups = 0
        self.hits = 0

    @staticmethod
    def _resolve(strategy, registry=None):
        from repro.comm.api import resolve_strategy

        return resolve_strategy(strategy, registry)

    # -- measured tables ------------------------------------------------
    def _interp_for(self, table, cls):
        it = self._interp.get(table)
        if it is None:
            it = cls(table)
            self._interp[table] = it
        return it

    def _lookup2d(
        self,
        tables: Optional[Dict[str, Table2D]],
        strategy: str,
        contig: int,
        total: int,
    ) -> Optional[float]:
        if not tables or strategy not in tables or not tables[strategy]:
            return None
        return self._interp_for(tables[strategy], _Interp2D)(
            math.log2(max(contig, 1)), math.log2(max(total, 1))
        )

    def measured(self, strategy: str, contig: int, total: int) -> Optional[float]:
        """Interpolated measured pack time for a named strategy, or None
        when no calibration table covers it."""
        return self._lookup2d(self.params.pack_table, strategy, contig, total)

    def measured_unpack(
        self, strategy: str, contig: int, total: int
    ) -> Optional[float]:
        """Interpolated measured unpack time, or None when uncovered."""
        return self._lookup2d(self.params.unpack_table, strategy, contig, total)

    def measured_copy(self, nbytes: int) -> Optional[float]:
        """Interpolated measured contiguous-copy time, or None."""
        t = self.params.copy_table
        if not t:
            return None
        return self._interp_for(t, _Interp1D)(math.log2(max(nbytes, 1)))

    def measured_compress(
        self, strategy: str, nbytes: int
    ) -> Optional[Tuple[float, float]]:
        """Interpolated measured ``(compress_sec, decompress_sec)`` for
        ``nbytes`` of payload under the named wire compressor, or None
        when no compress sweep was calibrated (the compressors then
        price their codec sweep with the 2x-HBM analytic proxy).  Rows
        are (log2_total, compress_sec, decompress_sec, ratio_sample);
        the ratio column is informational — pricing ratios always come
        from a payload probe."""
        tables = self.params.compress_table
        if not tables or strategy not in tables or not tables[strategy]:
            return None
        rows = tables[strategy]
        x = math.log2(max(nbytes, 1))
        comp = self._interp_for(
            tuple((r[0], r[1]) for r in rows), _Interp1D
        )(x)
        decomp = self._interp_for(
            tuple((r[0], r[2]) for r in rows), _Interp1D
        )(x)
        return comp, decomp

    def measured_stencil(self, n_neighbors: int, nbytes: int) -> Optional[float]:
        """Interpolated measured time of one stencil application with
        ``n_neighbors`` neighbor reads over a window of ``nbytes``, or
        None when no stencil sweep was calibrated (the redundant-compute
        term then falls back to the contiguous-copy proxy)."""
        t = self.params.stencil_table
        if not t:
            return None
        return self._interp_for(t, _Interp2D)(
            math.log2(max(n_neighbors, 1)), math.log2(max(nbytes, 1))
        )

    # -- per-strategy terms (delegate to the registered plugin) ---------
    def t_pack(self, ct: CommittedType, incount: int, strategy) -> float:
        return self._resolve(strategy).model_pack(self, ct, incount)

    def t_unpack(self, ct: CommittedType, incount: int, strategy) -> float:
        return self._resolve(strategy).model_unpack(self, ct, incount)

    # -- link term ------------------------------------------------------
    def _axis_wire(self, axis: Optional[str]):
        """(table, fitted latency, fitted bw) pricing one link on
        ``axis`` (default: the model's bound axis): the per-axis sweep
        when one covers the axis, else the flat axis-agnostic table."""
        p = self.params
        axis = axis if axis is not None else self.axis
        if axis is not None and p.wire_tables and axis in p.wire_tables:
            fit = (p.wire_fits or {}).get(axis) or (None, None)
            return p.wire_tables[axis], fit[0], fit[1]
        return p.wire_table, p.wire_latency, p.wire_bw

    def _class_wire(self, axis: Optional[str], link_class: Optional[str]):
        """(table, fitted latency, fitted bw) for one link CLASS of the
        two-level hierarchy: the ``"<axis>/<class>"`` sweep when one
        covers it, else the class-wide ``"<class>"`` sweep, else the
        per-axis/flat fallback — so a flat calibration prices every
        class as ``intra`` and ``link_class=None`` is bit-identical to
        the pre-hierarchy model."""
        p = self.params
        if link_class is not None and p.link_tables:
            a = axis if axis is not None else self.axis
            keys = ((f"{a}/{link_class}",) if a is not None else ())
            for key in keys + (link_class,):
                if p.link_tables.get(key):
                    fit = (p.link_fits or {}).get(key) or (None, None)
                    return p.link_tables[key], fit[0], fit[1]
        return self._axis_wire(axis)

    def _hop_latency(self, axis: Optional[str] = None) -> float:
        _, lat, _ = self._axis_wire(axis)
        return lat if lat is not None else self.params.ici_latency

    def t_link(self, nbytes: int, hops: int = 1,
               axis: Optional[str] = None,
               link_class: Optional[str] = None) -> float:
        p = self.params
        table, wire_lat, wire_bw = self._class_wire(axis, link_class)
        if table:
            # measured one-hop collective time; extra hops add the fitted
            # (or analytic) latency floor, not another bandwidth term
            interp = self._interp_for(table, _Interp1D)
            x = math.log2(max(nbytes, 1))
            t = interp(x)
            end = float(interp.xs[-1])
            if x > end:
                # past the measured grid: charge the fitted (or analytic)
                # bandwidth for the excess bytes instead of flat-clamping
                # — a 64 MiB transfer must not price like the 4 MiB grid
                # ceiling (it would hand every large object to bounding)
                bw = wire_bw if wire_bw else p.ici_bw
                t += (nbytes - 2.0 ** end) / bw
            lat = wire_lat if wire_lat is not None else p.ici_latency
            return t + (hops - 1) * lat
        return hops * p.ici_latency + nbytes / p.ici_bw

    # -- exchange pricing (exact-byte wire plans) -----------------------
    def _tier_surcharge(self, nbytes: int, axis: Optional[str]) -> float:
        """Extra seconds ``nbytes`` cost for crossing the slow tier
        instead of the fast one — exactly 0.0 when the tiers price
        equally (the inter == intra oracle), clamped at 0 so a noisy
        calibration never pays agents to cross nodes."""
        return max(
            0.0,
            self.t_link(nbytes, 1, axis, link_class="inter")
            - self.t_link(nbytes, 1, axis, link_class="intra"),
        )

    def _price_schedule(self, plan, schedule: str,
                        axis: Optional[str] = None) -> float:
        """Predicted seconds of ``plan``'s layout under ``schedule``.

        Flat plans (no ``link_classes`` annotation) price exactly as the
        pre-hierarchy model: the link term on the bytes the schedule
        issues plus one launch latency per extra collective.  Annotated
        plans price each delta class by the slowest tier it crosses —
        the base stays on the fast (``intra``) tier and every
        inter-crossing class (grouped), coalesced bundle (tiered), or
        whole fused collective touching any inter edge (uniform/ragged)
        adds the tier *surcharge* for its bytes.  The formulation makes
        the oracle exact: with ``inter == intra`` tables every surcharge
        is 0.0 and the annotated prices equal the flat ones bit-for-bit.
        """
        lat = self._hop_latency(axis)
        lc = getattr(plan, "link_classes", None)
        base_class = "intra" if lc else None
        if schedule == "grouped":
            t = self.t_link(plan.wire_bytes, 1, axis, link_class=base_class)
            t += (plan.ngroups - 1) * lat
            if lc:
                for g, c in enumerate(lc):
                    if c == "inter":
                        t += self._tier_surcharge(plan.groups[g].nbytes, axis)
            return t
        if schedule == "tiered":
            if not lc:
                raise ValueError(
                    "schedule 'tiered' needs a topology-annotated plan"
                )
            # grouped-relative: swap the per-class slow-tier surcharges
            # for per-BUNDLE ones (one slow message per peer node — the
            # coalescing win is one slow latency per merged class), and
            # pay the fast tier for the correction bytes every
            # non-representative bundle member re-transmits on-node
            t = self._price_schedule(plan, "grouped", axis)
            for g, c in enumerate(lc):
                if c == "inter":
                    t -= self._tier_surcharge(plan.groups[g].nbytes, axis)
            for b in plan.tier_bundles:
                t += self._tier_surcharge(
                    sum(plan.groups[g].nbytes for g in b), axis
                )
            t += max(
                0.0,
                self.t_link(plan.wire_bytes + plan.correction_bytes, 1,
                            axis, link_class="intra")
                - self.t_link(plan.wire_bytes, 1, axis, link_class="intra"),
            )
            return t
        if schedule == "varlen":
            # the grouped transport with each class truncated at its
            # probed stream length: the link term runs on the EFFECTIVE
            # bytes (the compressed wire-byte saving), the per-class
            # launch latencies stay — the pack-side compress cost rides
            # the strategy estimates (PerfModel.select with a probe),
            # not the schedule, exactly as pack costs do for every
            # other schedule
            stream = getattr(plan, "stream_bytes", ())
            if len(stream) != plan.ngroups:
                raise ValueError(
                    "schedule 'varlen' needs a stream-annotated plan"
                )
            t = self.t_link(sum(stream), 1, axis, link_class=base_class)
            t += (plan.ngroups - 1) * lat
            if lc:
                for g, c in enumerate(lc):
                    if c == "inter":
                        t += self._tier_surcharge(stream[g], axis)
            return t
        if schedule == "uniform":
            issued = plan.nranks * plan.seg_bytes
        elif schedule == "ragged":
            issued = plan.wire_bytes
        else:
            raise ValueError(f"unknown wire schedule {schedule!r}")
        t = self.t_link(issued, 1, axis, link_class=base_class)
        if lc and any(c == "inter" for c in lc):
            # one fused collective: its slowest edge crosses nodes, so
            # the whole issued payload pays the slow tier
            t += self._tier_surcharge(issued, axis)
        return t

    def price_exchange(self, plan, axis: Optional[str] = None,
                       note: str = "") -> StrategyEstimate:
        """Price a :class:`~repro.comm.wireplan.WirePlan`: the link term
        for the bytes its schedule actually issues, plus the per-extra-
        collective latency of the grouped schedule (plus the slow-tier
        surcharges when the plan carries a topology annotation).  The
        estimate (byte count included) is recorded once per plan
        fingerprint in the attached decision cache, so audits show the
        true transfer size of every fused exchange; ``note`` is appended
        to the audit signature (the schedule chooser records the prices
        of the alternatives it rejected)."""
        t = self._price_schedule(plan, plan.schedule, axis)
        est = StrategyEstimate(
            f"wire/{plan.schedule}", 0.0, t, 0.0, wire_bytes=plan.issued_bytes
        )
        if self.decisions is not None:
            key = (plan.fingerprint, plan.ngroups, plan.wire_ops, True)
            if self.decisions.lookup(*key) is None:
                topo = getattr(plan, "topology", None)
                topo_tag = (
                    f" topo={topo.fingerprint}" if topo is not None else ""
                )
                stream_tag = ""
                if plan.schedule == "varlen":
                    # pin the probed compression alongside the topology:
                    # the drift audit re-reads this ratio from the
                    # signature and compares it to the achieved-ratio
                    # telemetry ring
                    stream_tag = (
                        f" stream_bytes={plan.effective_wire_bytes}"
                        f" ratio={plan.stream_ratio:.4f}"
                    )
                self.decisions.record(
                    *key,
                    est,
                    signature=(
                        f"exchange schedule={plan.schedule}"
                        f" groups={plan.ngroups} ranks={plan.nranks}"
                        f" ragged_bytes={plan.wire_bytes}"
                        f"{stream_tag}{topo_tag}{note}"
                    ),
                )
        return est

    def price_wire_schedules(
        self, plan, axis: Optional[str] = None, native: Optional[bool] = None
    ) -> Dict[str, float]:
        """Predicted seconds for every wire schedule that could carry the
        plan's layout (ROADMAP: model-priced ``uniform`` vs ``grouped``).

        ``grouped`` pays one collective launch per delta class on the
        exact ragged bytes; ``uniform`` pays a single launch on the
        row-equalized (padded) bytes; ``ragged`` — when the running JAX
        has the native collective — pays one launch on the exact bytes.
        The byte terms come from the measured per-axis wire tables when
        calibration filled them, so the trade is priced on the system
        actually running, not on a byte-exactness rule.

        The large-grid threshold still applies: past
        ``GROUPED_FALLBACK_RANK_FACTOR x ngroups`` ranks the fused
        layouts are mostly zero rows / dead per-peer metadata — a cost
        the per-byte link model cannot see — so only ``grouped`` (and,
        on a topology-annotated plan, ``tiered``) is a candidate there,
        exactly as in the exact ladder.

        Topology-annotated plans with at least one inter-crossing class
        additionally price ``tiered`` — the per-peer-node coalesced
        schedule.  Candidate order puts ``grouped`` first so exact price
        ties resolve to it (coalescing must *win*, not draw, to buy its
        correction hops), which is also what keeps the inter == intra
        oracle bit-for-bit.
        """
        if native is None:
            from repro.compat import has_ragged_all_to_all

            native = has_ragged_all_to_all()
        from repro.comm.wireplan import GROUPED_FALLBACK_RANK_FACTOR

        costs = {"grouped": self._price_schedule(plan, "grouped", axis)}
        stream = getattr(plan, "stream_bytes", ())
        if len(stream) == plan.ngroups and sum(stream) < plan.wire_bytes:
            # the length-aware grouped transport: available whenever a
            # payload probe annotated the plan with a genuinely shorter
            # stream (it is per-class sends, so the large-grid fallback
            # does not exclude it); grouped stays first so a zero-saving
            # tie resolves to the plain transport
            costs["varlen"] = self._price_schedule(plan, "varlen", axis)
        lc = getattr(plan, "link_classes", None)
        if lc and plan.tier_bundles:
            costs["tiered"] = self._price_schedule(plan, "tiered", axis)
        oversize = (
            plan.ngroups
            and plan.nranks > GROUPED_FALLBACK_RANK_FACTOR * plan.ngroups
        )
        if plan.fused and not oversize:
            costs["uniform"] = self._price_schedule(plan, "uniform", axis)
            if native:
                costs["ragged"] = self._price_schedule(plan, "ragged", axis)
        return costs

    def choose_wire_schedule(
        self, plan, axis: Optional[str] = None, native: Optional[bool] = None
    ):
        """Re-schedule a plan onto the model-cheapest feasible wire
        schedule.  Returns ``(plan, costs)`` — the (possibly rescheduled)
        plan plus the per-schedule price table that justified it."""
        from repro.comm.wireplan import reschedule

        costs = self.price_wire_schedules(plan, axis, native)
        best = min(costs, key=costs.get)
        return reschedule(plan, best), costs

    # -- simulated-scale pricing (the 3072-process regime, no hardware) -
    def at_scale(
        self,
        ranks: int,
        nodes: Optional[int] = None,
        *,
        ranks_per_node: Optional[int] = None,
        interior: Tuple[int, int, int] = (8, 8, 8),
        radius: int = 1,
        element_bytes: int = 4,
        axis: Optional[str] = None,
        native: Optional[bool] = None,
        pin: bool = True,
    ):
        """Price the halo exchange the paper's scaling study runs — a 3D
        periodic stencil on a ``ranks``-process grid — *from the
        measured tables alone*, no devices.  ``nodes`` (or
        ``ranks_per_node``) shapes the two-level topology; the process
        grid is the pencil decomposition ``(nodes, fy, fx)`` with one
        leading-axis slab per node, so leading-axis classes cross the
        slow tier and everything else stays on-node (see
        ``repro.comm.scale``).  Sweeping ``ranks`` gives the predicted
        schedule *ladder* per scale — the CI artifact that lets a
        single-host container assert "at 3072 ranks the model flips to
        tier-coalesced".

        The winning schedule is pinned as a ``wire/<schedule>`` decision
        keyed by a fingerprint that includes the topology fingerprint —
        an existing pin short-circuits the choice (``pinned=True``), so
        a reshape-then-replay is detectable and an elastic replan
        (``train.elastic.replan_on_remesh``) provably re-prices.
        Returns a :class:`repro.comm.scale.ScaleEstimate`.
        """
        from repro.comm.scale import ScaleEstimate, build_scale_plan

        ranks = int(ranks)
        if ranks_per_node is None:
            nodes = int(nodes) if nodes else 1
            if ranks % nodes:
                raise ValueError(
                    f"ranks={ranks} does not split over nodes={nodes}"
                )
            ranks_per_node = ranks // nodes
        plan = build_scale_plan(
            ranks,
            ranks_per_node,
            interior=interior,
            radius=radius,
            element_bytes=element_bytes,
        )
        costs = self.price_wire_schedules(plan, axis, native)
        best = min(costs, key=costs.get)
        key_src = (
            "atscale.v1", ranks, plan.topology.nnodes, plan.grid,
            tuple(interior), int(radius), int(element_bytes),
            plan.topology.fingerprint,
        )
        fp = hashlib.sha256(repr(key_src).encode()).hexdigest()[:16]
        pinned = False
        if pin and self.decisions is not None:
            row = self.decisions.lookup(fp, 0, 1, True)
            if row is not None and row.strategy.startswith("wire/"):
                sched = row.strategy.split("/", 1)[1]
                if sched in costs:
                    best, pinned = sched, True
            if not pinned:
                self.decisions.record(
                    fp, 0, 1, True,
                    StrategyEstimate(
                        f"wire/{best}", 0.0, costs[best], 0.0,
                        wire_bytes=plan.wire_bytes,
                    ),
                    signature=(
                        f"atscale ranks={ranks} nodes={plan.topology.nnodes}"
                        f" grid={plan.grid} classes={plan.ngroups}"
                        f" topo={plan.topology.fingerprint} "
                        + " ".join(
                            f"{s}:{c:.3e}" for s, c in sorted(costs.items())
                        )
                    ),
                )
        n_inter = sum(1 for c in plan.link_classes if c == "inter")
        return ScaleEstimate(
            ranks=ranks,
            nodes=plan.topology.nnodes,
            grid=plan.grid,
            schedule=best,
            costs=dict(costs),
            wire_bytes=plan.wire_bytes,
            correction_bytes=plan.correction_bytes,
            inter_messages={
                "grouped": n_inter,
                "tiered": len(plan.tier_bundles),
            },
            fingerprint=fp,
            pinned=pinned,
        )

    # -- region-split overlap pricing -----------------------------------
    def _stencil_seconds(self, n_neighbors: int, nbytes: int) -> float:
        """Seconds of one ``n_neighbors``-point stencil application over
        a window of ``nbytes`` — the measured stencil sweep when
        calibrated, else the same contiguous-copy / HBM proxy the
        redundant-compute term falls back to."""
        if nbytes <= 0:
            return 0.0
        t_app = self.measured_stencil(n_neighbors, nbytes)
        if t_app is not None:
            return t_app
        touches = n_neighbors + 2
        copy = self.measured_copy(nbytes)
        per_touch = (
            copy / 2.0 if copy is not None else nbytes / self.params.hbm_bw
        )
        return touches * per_touch

    def price_class_completions(
        self, plan, axis: Optional[str] = None
    ) -> Tuple[float, ...]:
        """Predicted completion time of each delta class of ``plan``,
        measured from issue.  Under the grouped schedule class ``k``
        rides the ``k``-th per-class collective: it cannot complete
        before every earlier class's bytes are on the link
        (``class_cum_bytes``) plus one launch latency per earlier
        collective — the profile that makes region-split overlap
        worthwhile.  The fused schedules (uniform/ragged) complete every
        class together at the whole-collective time."""
        lat = self._hop_latency(axis)
        if plan.schedule == "grouped":
            return tuple(
                self.t_link(cum, 1, axis) + k * lat
                for k, cum in enumerate(plan.class_cum_bytes)
            )
        t = self._price_schedule(plan, plan.schedule, axis)
        return (t,) * plan.ngroups

    def price_overlap(
        self,
        plan,
        regions: Sequence[Tuple[int, Sequence[int]]],
        core_bytes: int,
        n_neighbors: int,
        axis: Optional[str] = None,
    ) -> Dict[str, OverlapEstimate]:
        """Price both overlap modes for one exchange-hiding stencil
        application.  ``regions`` describes the rim regions as
        ``(window_bytes, dep_class_ids)`` pairs — geometry stays in the
        halo layer; the model only sees bytes and dependencies.
        ``core_bytes`` is the core window (computable with no halo) and
        ``n_neighbors`` the stencil's neighbor count.

        Both modes run compute on a single resource.  ``monolithic``
        blocks on the fused wire: ``max(wire, core) + sum(rims)``.
        ``region`` starts the core at issue and each rim at
        ``max(resource free, its classes' completion)`` — the win is
        bounded by the spread of the per-class completion profile.
        """
        completions = self.price_class_completions(plan, axis)
        t_wire = max(completions) if completions else 0.0
        t_core = self._stencil_seconds(n_neighbors, core_bytes)
        rims = tuple(
            self._stencil_seconds(n_neighbors, rb) for rb, _ in regions
        )

        def ready(i: int) -> float:
            deps = regions[i][1]
            return max((completions[c] for c in deps), default=0.0)

        mono = max(t_wire, t_core) + sum(rims)
        busy = t_core
        for i in sorted(range(len(regions)), key=ready):
            busy = max(busy, ready(i)) + rims[i]
        return {
            "monolithic": OverlapEstimate(
                "monolithic", mono, t_core, t_wire, rims, completions
            ),
            "region": OverlapEstimate(
                "region", max(busy, t_wire), t_core, t_wire, rims,
                completions
            ),
        }

    def choose_overlap_mode(
        self,
        plan,
        regions: Sequence[Tuple[int, Sequence[int]]],
        core_bytes: int,
        n_neighbors: int,
        axis: Optional[str] = None,
    ) -> Tuple[str, Dict[str, OverlapEstimate], bool]:
        """Pick monolithic vs region-split overlap for one exchange,
        pinned as an ``overlap/mode=...`` decision exactly like the
        ``program/s=N`` depth choice: a cache hit with that strategy
        prefix short-circuits pricing (returns ``pinned=True``); a miss
        prices both modes on the system tables, records the choice with
        the rejected price in the signature, and returns it.  Ties go to
        ``monolithic`` — region-split must *win*, not draw, to buy its
        extra scheduling machinery."""
        regions = tuple(
            (int(rb), tuple(sorted(int(c) for c in deps)))
            for rb, deps in regions
        )
        key_src = (
            "overlap.v1", plan.fingerprint, int(core_bytes),
            int(n_neighbors), regions,
        )
        fp = hashlib.sha256(repr(key_src).encode()).hexdigest()[:16]
        ests = self.price_overlap(
            plan, regions, core_bytes, n_neighbors, axis
        )
        if self.decisions is not None:
            pin = self.decisions.lookup(fp, 0, 1, True)
            if pin is not None and pin.strategy.startswith("overlap/mode="):
                mode = pin.strategy.split("=", 1)[1]
                if mode in ests:
                    return mode, ests, True
        mode = (
            "region"
            if ests["region"].t_total < ests["monolithic"].t_total
            else "monolithic"
        )
        if self.decisions is not None:
            best = ests[mode]
            self.decisions.record(
                fp, 0, 1, True,
                StrategyEstimate(
                    f"overlap/mode={mode}",
                    t_pack=best.t_core + sum(best.t_rims),
                    t_link=best.t_wire,
                    t_unpack=0.0,
                    wire_bytes=plan.issued_bytes,
                ),
                signature=(
                    f"overlap plan={plan.fingerprint}"
                    f" classes={plan.ngroups} regions={len(regions)}"
                    f" core_B={int(core_bytes)} "
                    + " ".join(
                        f"{m}:{e.t_total:.3e}"
                        for m, e in sorted(ests.items())
                    )
                ),
            )
        return mode, ests, False

    # -- deep-halo program pricing (exchange vs redundant compute) ------
    def _redundant_time(
        self, n_neighbors: int, window_bytes: int, red_bytes: int
    ) -> float:
        """Seconds of redundant ghost-shell work inside one application
        whose full window is ``window_bytes`` of which ``red_bytes`` are
        shell cells some neighbor also computes.

        Preferred source: the measured stencil-application sweep
        (``SystemParams.stencil_table``) — the per-byte rate of a real
        ``n_neighbors``-point application at this window size, times the
        redundant bytes.  Fallback (no sweep calibrated): the
        contiguous-copy proxy — ``n_neighbors + 2`` touches per cell, a
        touch being half a measured copy (read + write), else analytic
        HBM bandwidth.
        """
        t_app = self.measured_stencil(n_neighbors, window_bytes)
        if t_app is not None and window_bytes > 0:
            return t_app * (red_bytes / window_bytes)
        touches = n_neighbors + 2
        copy = self.measured_copy(red_bytes)
        per_touch = (
            copy / 2.0 if copy is not None else red_bytes / self.params.hbm_bw
        )
        return touches * per_touch

    def price_program(
        self,
        plan,
        interior: Tuple[int, int, int],
        op_radii,
        n_neighbors,
        steps: int,
        element_bytes: int = 4,
        t_members: float = 0.0,
        axis: Optional[str] = None,
    ) -> ProgramEstimate:
        """Price one deep-halo iteration: ONE exchange at halo depth
        ``steps * cycle_radii`` (wire plan ``plan``, member pack/unpack
        time ``t_members``) amortized over ``steps`` repeats of an op
        cycle, against the redundant ghost-shell re-evaluation the
        shrinking valid region pays.

        ``op_radii`` is one per-dimension radii tuple (the single-op
        program) or a *sequence* of them — the cycle ``[op_1..op_k]`` in
        application order — with ``n_neighbors`` an int or matching
        sequence.  Application ``j`` of the flattened ``steps * k``
        schedule writes interior plus a shell of ``total - cum_j`` per
        dimension (``total`` the full halo depth, ``cum_j`` the radii of
        applications ``1..j`` summed) — every shell cell is a cell some
        neighbor also computes, i.e. pure redundancy bought to skip the
        other exchanges.  Redundant time is priced from the measured
        stencil sweep when calibration filled it, else the contiguous-
        copy proxy (see :meth:`_redundant_time`); per-op splits land in
        :attr:`ProgramEstimate.op_redundant`.  Compare ``per_step``
        across candidate depths to pick ``s`` — ``price_program`` never
        guesses, it prices the same tables every other selection uses.
        """
        if op_radii and isinstance(op_radii[0], (tuple, list)):
            cycle = [tuple(r) for r in op_radii]
        else:
            cycle = [tuple(op_radii)]
        if isinstance(n_neighbors, (tuple, list)):
            neighbors = [int(n) for n in n_neighbors]
        else:
            neighbors = [int(n_neighbors)] * len(cycle)
        if len(neighbors) != len(cycle):
            raise ValueError(
                f"n_neighbors ({len(neighbors)}) must match the cycle "
                f"length ({len(cycle)})"
            )
        wire = self._price_schedule(plan, plan.schedule, axis)
        t_exchange = t_members + wire
        interior_cells = math.prod(interior)
        total = tuple(steps * sum(r[d] for r in cycle) for d in range(3))
        op_red = [0.0] * len(cycle)
        cum = (0, 0, 0)
        for j in range(steps * len(cycle)):
            pos = j % len(cycle)
            cum = tuple(c + r for c, r in zip(cum, cycle[pos]))
            shell = tuple(t - c for t, c in zip(total, cum))
            cells = math.prod(n + 2 * s for n, s in zip(interior, shell))
            red_bytes = (cells - interior_cells) * element_bytes
            if red_bytes <= 0:
                continue
            op_red[pos] += self._redundant_time(
                neighbors[pos], cells * element_bytes, red_bytes
            )
        return ProgramEstimate(
            steps=steps,
            t_exchange=t_exchange,
            t_redundant=sum(op_red),
            wire_bytes=plan.issued_bytes,
            cycle_len=len(cycle),
            op_redundant=tuple(op_red),
        )

    # -- full strategy estimates (Eqs. 1-3 analogue) ----------------------
    def estimate(
        self, ct: CommittedType, incount: int, strategy, hops: int = 1
    ) -> StrategyEstimate:
        return self._resolve(strategy).plan(self, ct, incount, hops)

    def select(
        self,
        ct: CommittedType,
        incount: int = 1,
        hops: int = 1,
        allow_bounding: bool = True,
        registry=None,
        probe=None,
    ) -> StrategyEstimate:
        """Pick the cheapest applicable registered strategy (cached per
        call signature).  ``allow_bounding`` admits wire-only strategies
        (data actually crosses a link, so shipping the bounding window
        is meaningful).

        ``probe`` (a *concrete* payload sample) turns on length-aware
        pricing: every ``supports_varlen`` candidate's link term is
        priced at its probed stream length instead of its capacity —
        the only way a lossless compressor (whose capacity is strictly
        larger than the packed bytes) can ever win a selection.  The
        probed stream lengths key the selection cache, and a probed win
        records its stream bytes + ratio in the decision signature."""
        if registry is None:
            from repro.comm.api import default_registry

            registry = default_registry()
        # keyed on the type's CONTENT fingerprint (not id(ct): equal
        # structures share decisions across registries and processes) and
        # the strategy registry's mutation counter so a newly registered
        # plugin invalidates prior selections
        sig = ct.fingerprint
        streams = {}
        if probe is not None:
            for s in registry.selectable():
                if getattr(s, "supports_varlen", False) and s.applicable(ct):
                    stream = int(s.probe_stream_bytes(ct, incount, probe))
                    if stream < s.wire_bytes(ct, incount):
                        streams[s.name] = stream
        key = (sig, incount, hops, allow_bounding, id(registry),
               registry.version, tuple(sorted(streams.items())))
        self.lookups += 1
        hit = self._cache.get(key)
        if hit is not None:
            self.hits += 1
            return hit
        def plan_est(s):
            e = s.plan(self, ct, incount, hops)
            stream = streams.get(s.name)
            if stream is None:
                return e
            # re-price the link term at the probed stream length:
            # pack-side compress cost stays in t_pack, the wire-byte
            # saving lands in t_link — the honest pack-vs-wire trade
            return StrategyEstimate(
                e.strategy, e.t_pack, self.t_link(stream, hops),
                e.t_unpack, wire_bytes=stream,
            )

        pinned = None
        if self.decisions is not None:
            pinned = self.decisions.lookup(sig, incount, hops, allow_bounding)
        if pinned is not None and pinned.strategy in registry:
            best = plan_est(registry.get(pinned.strategy))
        else:
            cands = [
                s
                for s in registry.selectable()
                if (allow_bounding or not s.wire_only) and s.applicable(ct)
            ]
            if not cands:
                raise ValueError(f"no applicable strategy registered for {ct!r}")
            best = min((plan_est(s) for s in cands), key=lambda e: e.total)
            if self.decisions is not None:
                signature = None
                if best.strategy in streams:
                    from repro.measure.decisions import describe_type

                    ratio = streams[best.strategy] / max(
                        registry.get(best.strategy).wire_bytes(ct, incount), 1
                    )
                    signature = (
                        f"{describe_type(ct)}"
                        f" stream_bytes={streams[best.strategy]}"
                        f" ratio={ratio:.4f}"
                    )
                self.decisions.record(
                    sig, incount, hops, allow_bounding, best, ct=ct,
                    signature=signature,
                )
        self._cache[key] = best
        return best
