"""Compressed-wire strategy plugin: int8 on the wire, upcast on unpack.

The point of the exact-byte :class:`~repro.comm.wireplan.WirePlan`
accounting is that a strategy's wire extent need not equal the packed
member bytes — a bounding window is *larger*, a compressed payload is
*smaller*.  This plugin exercises the smaller side: float32 member
bytes are symmetric-quantized to int8 for the link and dequantized on
the receive side before the scatter.

Quantization is **per 256-element block** by default: each block of the
packed payload carries its own float32 scale (the header grows by 4 B
per block), so one large-magnitude region no longer destroys the
resolution of every other region in the payload — the lossy wire is
usable on far more datatypes than the old per-payload scale allowed.
``Int8Wire(block_elems=None)`` still *produces* the legacy one-scale
format, and the decoder reads both (the scale count is recoverable from
the wire length and the receive type, so a per-payload payload
dequantizes correctly through the default per-block instance).

Quantization is lossy, so the strategy registers with
``selectable = False``: the model never auto-picks it; opt in per
communicator with ``FixedPolicy(Int8Wire.name)`` (lossy halo exchange
is a deliberate accuracy/bandwidth trade, e.g. on a DCN axis).  It is
``wire_only``: local ``pack``/``unpack`` calls fall back to the normal
kernels — only the wire format is compressed.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

import jax.numpy as jnp
from jax import lax

from repro.comm.api import Strategy
from repro.core.commit import CommittedType
from repro.kernels import ops

__all__ = [
    "Int8Wire",
    "INT8_WIRE",
    "BLOCK_ELEMS",
    "RleWire",
    "RLE_WIRE",
    "RLE_HEADER_BYTES",
    "RLE_RUN_BYTES",
]

#: bytes per float32 dequantization scale in the wire header
_SCALE_BYTES = 4

#: default quantization granularity (elements per scale)
BLOCK_ELEMS = 256


class Int8Wire(Strategy):
    """Ship float32 member bytes as int8 + per-block float32 scales."""

    name = "int8wire"
    wire_only = True       # the compressed format only exists on the wire
    selectable = False     # lossy: never auto-selected, opt in explicitly

    def __init__(self, block_elems: Optional[int] = BLOCK_ELEMS):
        #: elements per quantization block; None = one scale for the
        #: whole payload (the legacy wire format)
        self.block_elems = block_elems

    def applicable(self, ct: CommittedType) -> bool:
        # the member bytes must re-view as float32 words; the type system
        # tracks bytes, not element dtypes, so the caller opting in (via
        # FixedPolicy) asserts the buffer really holds float32 data
        return ct.size % 4 == 0 and ct.word_bytes >= 4

    def _nblocks(self, nfloats: int) -> int:
        if self.block_elems is None or nfloats == 0:
            return 1
        return -(-nfloats // self.block_elems)

    # -- §5 cost model ----------------------------------------------------
    def model_pack(self, model, ct, incount):
        # pack the members (priced like rows) + quantize: the measured
        # compress sweep when calibrated, else one extra read+write
        # sweep of the packed bytes
        size = ct.size * incount
        from repro.comm.api import ROWS

        base = ROWS.model_pack(model, ct, incount)
        m = model.measured_compress(self.name, size)
        if m is not None:
            return base + m[0]
        return base + 2 * size / model.params.hbm_bw

    def model_unpack(self, model, ct, incount):
        size = ct.size * incount
        from repro.comm.api import ROWS

        base = ROWS.model_unpack(model, ct, incount)
        m = model.measured_compress(self.name, size)
        if m is not None:
            return base + m[1]
        return base + 2 * size / model.params.hbm_bw

    def wire_bytes(self, ct: CommittedType, incount: int = 1) -> int:
        # one int8 per float32 member + one scale per quantization block
        nfloats = (ct.size * incount) // 4
        return _SCALE_BYTES * self._nblocks(nfloats) + nfloats

    # -- execution --------------------------------------------------------
    def encode_wire(self, member):
        """Packed member bytes -> quantized wire (per-block scales header
        + int8 body).  Split out from :meth:`pack` so the fused
        pack+compress entry and the compress-throughput sweep
        (:func:`repro.measure.bench.measure_compress_table`) can time the
        quantize transform on its own."""
        f = lax.bitcast_convert_type(
            member.reshape(-1, 4), jnp.float32
        ).reshape(-1)
        n = f.shape[0]
        nb = self._nblocks(n)
        block = self.block_elems if (self.block_elems and nb > 1) else n
        pad = nb * block - n
        blocks = jnp.pad(f, (0, pad)).reshape(nb, block)
        scales = (
            jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), jnp.float32(1e-30))
            / 127.0
        )
        q = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127)
        q = q.astype(jnp.int8).reshape(-1)[:n]
        header = lax.bitcast_convert_type(
            scales.astype(jnp.float32), jnp.uint8
        ).reshape(-1)
        return jnp.concatenate([header, ops.byte_view(q)])

    def pack(self, buf, ct, incount: int = 1, interpret: Optional[bool] = None):
        return self.encode_wire(
            ops.pack(buf, ct, incount=incount, interpret=interpret)
        )

    def decode_wire(self, wire, n: int):
        """Wire bytes -> the ``n`` dequantized member bytes (lossy)."""
        nfloats = n // 4
        nscales = (wire.shape[0] - nfloats) // _SCALE_BYTES
        scales = lax.bitcast_convert_type(
            wire[: _SCALE_BYTES * nscales].reshape(nscales, _SCALE_BYTES),
            jnp.float32,
        ).reshape(-1)
        q = lax.bitcast_convert_type(wire[_SCALE_BYTES * nscales :], jnp.int8)
        if nscales == 1:
            f = q.astype(jnp.float32) * scales[0]  # legacy per-payload scale
        else:
            if self.block_elems is None or nscales != self._nblocks(nfloats):
                raise ValueError(
                    f"wire carries {nscales} scales for {nfloats} floats; "
                    f"expected {self._nblocks(nfloats)} "
                    f"(block_elems={self.block_elems})"
                )
            expand = jnp.repeat(scales, self.block_elems)[:nfloats]
            f = q.astype(jnp.float32) * expand
        return lax.bitcast_convert_type(f.reshape(-1, 1), jnp.uint8).reshape(-1)

    def unpack_wire(self, comm, dst, wire, recv_ct, send_ct=None, incount=1):
        member = self.decode_wire(wire, recv_ct.size * incount)
        u = comm.select(recv_ct, incount, wire=False)
        return u.unpack(dst, member, recv_ct, incount)

    def unpack(self, buf, packed, ct, incount=1, interpret=None):
        raise TypeError(
            f"{self.name} is wire-only; use unpack_wire on the received "
            "payload"
        )


INT8_WIRE = Int8Wire()


# ===========================================================================
# lossless zero-run / RLE wire format
# ===========================================================================

#: wire header: uint32 mode (0 = stored, 1 = rle) + uint32 run count
RLE_HEADER_BYTES = 8

#: bytes one RLE run occupies on the wire (uint8 value + uint32 length)
RLE_RUN_BYTES = 5
_RUN_BYTES = RLE_RUN_BYTES


class RleWire(Strategy):
    """Lossless run-length wire format with a stored-mode fallback.

    The *exact-byte* counterpart of :class:`Int8Wire`: where int8
    quantization trades accuracy for bytes, this plugin is bit-exact —
    the member bytes are run-length encoded (one ``(value, length)``
    pair per run, the classic zero-run case collapsing whole halo shells
    of zeros into one 5-byte run) and decoded exactly on the receive
    side before the scatter.

    XLA arrays have static shapes, so a wire payload cannot change size
    with its data; the format is therefore **capacity-allocated**: the
    wire always spans ``member_bytes + 8`` bytes (`wire_bytes`), the
    8-byte header records the live mode and run count, and the tail
    beyond the encoded stream is zero.  A payload whose RLE stream would
    not fit the capacity ships verbatim under ``mode = stored`` — the
    DEFLATE stored-block discipline — so the round trip is exact for
    *every* input.

    The body is laid out as **interleaved 5-byte run records** (run
    ``i`` at body offset ``5*i`` carries ``value:u8 ++ length:u32le``),
    so the live encoded stream is literally a *prefix* of the capacity
    wire: ``wire[:8 + 5*nruns]``.  That is what makes the format
    transport-truncatable — the ``varlen`` wire schedule
    (:meth:`Communicator._issue_wire`) ships only
    :meth:`probe_stream_bytes` bytes per class, and
    :meth:`unpack_wire` decodes either a full capacity wire *or* a
    header-prefixed stream whose run count it derives from the wire
    length.  A stream budget comes from a calibration probe of the
    actual payload (never assumed); a stored-mode payload never
    truncates (its stream length *is* the capacity).

    Registered ``selectable = True``: byte-exactness holds in both
    modes, and the strategy is priced honestly — at *capacity* bytes
    (header included, always >= the packed member bytes) unless the
    selection carries a probed stream length, so the model only ever
    picks it when a length-aware transport makes the compressed bytes
    the bytes actually moved.  ``wire_only``: local pack/unpack fall
    back to the normal kernels, and the strategy stays out of the
    measured pack/unpack sweeps (``StrategyRegistry.measurable``).
    """

    name = "rlewire"
    wire_only = True        # the RLE format only exists on the wire
    selectable = True       # lossless; priced at capacity unless probed
    supports_varlen = True  # live stream is a prefix of the capacity wire

    def applicable(self, ct: CommittedType) -> bool:
        return ct.size > 0

    @staticmethod
    def _run_capacity(nbytes: int) -> int:
        """Run slots the fixed layout can hold (5 B each, inside the
        member-byte capacity)."""
        return nbytes // _RUN_BYTES

    # -- §5 cost model ----------------------------------------------------
    def model_pack(self, model, ct, incount):
        from repro.comm.api import ROWS

        # pack the members + the encode sweep: measured compress table
        # when calibrated, else one extra read + write of the bytes
        size = ct.size * incount
        base = ROWS.model_pack(model, ct, incount)
        m = model.measured_compress(self.name, size)
        if m is not None:
            return base + m[0]
        return base + 2 * size / model.params.hbm_bw

    def model_unpack(self, model, ct, incount):
        from repro.comm.api import ROWS

        size = ct.size * incount
        base = ROWS.model_unpack(model, ct, incount)
        m = model.measured_compress(self.name, size)
        if m is not None:
            return base + m[1]
        return base + 2 * size / model.params.hbm_bw

    def wire_bytes(self, ct: CommittedType, incount: int = 1) -> int:
        # capacity layout: header + the member bytes (stored-mode bound)
        return RLE_HEADER_BYTES + ct.size * incount

    # -- length-aware transport -------------------------------------------
    def probe_stream_bytes(self, ct: CommittedType, incount, buf) -> int:
        """Exact stream length (header + live run records) for a
        *concrete* payload sample — the calibration probe the varlen
        transport truncates at.  Falls back to capacity for tracers
        (no data to probe) and for stored-mode payloads (their stream
        *is* the capacity)."""
        import jax

        cap = self.wire_bytes(ct, incount)
        if isinstance(buf, jax.core.Tracer):
            return cap  # tracer: nothing to probe
        try:
            member = np.asarray(ops.pack(jnp.asarray(buf), ct, incount=incount))
        except Exception:
            return cap
        n = member.size
        if n == 0:
            return cap
        runs = int(np.count_nonzero(member[1:] != member[:-1])) + 1
        if runs > self._run_capacity(n):
            return cap  # would ship stored: no truncation possible
        return min(RLE_HEADER_BYTES + _RUN_BYTES * runs, cap)

    # -- execution --------------------------------------------------------
    def encode_wire(self, member):
        """Member bytes -> capacity wire (header + interleaved run
        records + zero tail, or header + stored body).  The fused
        pack+compress entry (:func:`repro.kernels.pack.pack_compress_ragged`)
        composes this with the member gather in one traced pass."""
        b = member
        n = b.shape[0]
        R = self._run_capacity(n)
        if R == 0:
            header = lax.bitcast_convert_type(
                jnp.array([0, 0], jnp.uint32), jnp.uint8
            ).reshape(-1)
            return jnp.concatenate([header, b])
        # run starts: position 0 plus every byte differing from its
        # predecessor; run i spans [pos_i, pos_{i+1})
        starts = jnp.concatenate(
            [jnp.ones((1,), bool), b[1:] != b[:-1]]
        )
        nruns = starts.sum().astype(jnp.uint32)
        (pos,) = jnp.where(starts, size=n, fill_value=n)
        counts = jnp.diff(jnp.append(pos, n))  # 0 past the live runs
        values = jnp.where(counts > 0, b[jnp.clip(pos, 0, n - 1)], 0)
        fits = nruns <= jnp.uint32(R)
        mode = jnp.where(fits, jnp.uint32(1), jnp.uint32(0))
        count_bytes = lax.bitcast_convert_type(
            counts[:R].astype(jnp.uint32), jnp.uint8
        )  # (R, 4)
        records = jnp.concatenate(
            [values[:R].astype(jnp.uint8)[:, None], count_bytes], axis=1
        ).reshape(_RUN_BYTES * R)  # run i at body offset 5*i
        rle_body = jnp.concatenate(
            [records, jnp.zeros((n - _RUN_BYTES * R,), jnp.uint8)]
        )
        body = jnp.where(fits, rle_body, b)
        header = lax.bitcast_convert_type(
            jnp.stack([mode, nruns]), jnp.uint8
        ).reshape(-1)
        return jnp.concatenate([header, body])

    def pack(self, buf, ct, incount: int = 1, interpret: Optional[bool] = None):
        return self.encode_wire(
            ops.pack(buf, ct, incount=incount, interpret=interpret)
        )

    def decode_wire(self, wire, n: int):
        """Wire bytes -> the ``n`` member bytes.  Accepts either the
        full capacity wire (``8 + n`` bytes, mode-dependent stored/rle
        body) or a truncated varlen stream (``8 + 5*S`` bytes, always
        rle mode; ``S`` derived from the wire length)."""
        total = wire.shape[0]
        body = wire[RLE_HEADER_BYTES:]
        if total == RLE_HEADER_BYTES + n:
            R = self._run_capacity(n)
            stream_only = False
        else:
            rec = total - RLE_HEADER_BYTES
            if rec < 0 or rec % _RUN_BYTES or rec > _RUN_BYTES * self._run_capacity(n):
                raise ValueError(
                    f"rle wire carries {total} bytes; expected "
                    f"{RLE_HEADER_BYTES + n} (capacity) for a {n}-byte "
                    f"member payload, or header + whole 5-byte run records"
                )
            R = rec // _RUN_BYTES
            stream_only = True
        if R == 0:
            return body
        records = body[: _RUN_BYTES * R].reshape(R, _RUN_BYTES)
        values = records[:, 0]
        counts = lax.bitcast_convert_type(records[:, 1:], jnp.uint32)
        # live counts sum to n exactly; dead slots are 0
        decoded = jnp.repeat(values, counts, total_repeat_length=n)
        if stream_only:
            return decoded  # a truncated stream is always rle mode
        header = lax.bitcast_convert_type(
            wire[:RLE_HEADER_BYTES].reshape(2, 4), jnp.uint32
        )
        return jnp.where(header[0] == 1, decoded, body)

    def unpack_wire(self, comm, dst, wire, recv_ct, send_ct=None, incount=1):
        member = self.decode_wire(wire, recv_ct.size * incount)
        u = comm.select(recv_ct, incount, wire=False)
        return u.unpack(dst, member, recv_ct, incount)

    def unpack(self, buf, packed, ct, incount=1, interpret=None):
        raise TypeError(
            f"{self.name} is wire-only; use unpack_wire on the received "
            "payload"
        )


RLE_WIRE = RleWire()
