"""Compressed-wire strategy plugin: int8 on the wire, upcast on unpack.

The point of the exact-byte :class:`~repro.comm.wireplan.WirePlan`
accounting is that a strategy's wire extent need not equal the packed
member bytes — a bounding window is *larger*, a compressed payload is
*smaller*.  This plugin exercises the smaller side: float32 member
bytes are symmetric-quantized to int8 for the link and dequantized on
the receive side before the scatter.

Quantization is **per 256-element block** by default: each block of the
packed payload carries its own float32 scale (the header grows by 4 B
per block), so one large-magnitude region no longer destroys the
resolution of every other region in the payload — the lossy wire is
usable on far more datatypes than the old per-payload scale allowed.
``Int8Wire(block_elems=None)`` still *produces* the legacy one-scale
format, and the decoder reads both (the scale count is recoverable from
the wire length and the receive type, so a per-payload payload
dequantizes correctly through the default per-block instance).

Quantization is lossy, so the strategy registers with
``selectable = False``: the model never auto-picks it; opt in per
communicator with ``FixedPolicy(Int8Wire.name)`` (lossy halo exchange
is a deliberate accuracy/bandwidth trade, e.g. on a DCN axis).  It is
``wire_only``: local ``pack``/``unpack`` calls fall back to the normal
kernels — only the wire format is compressed.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.comm.api import Strategy
from repro.core.commit import CommittedType
from repro.kernels import ops

__all__ = ["Int8Wire", "INT8_WIRE", "BLOCK_ELEMS"]

#: bytes per float32 dequantization scale in the wire header
_SCALE_BYTES = 4

#: default quantization granularity (elements per scale)
BLOCK_ELEMS = 256


class Int8Wire(Strategy):
    """Ship float32 member bytes as int8 + per-block float32 scales."""

    name = "int8wire"
    wire_only = True       # the compressed format only exists on the wire
    selectable = False     # lossy: never auto-selected, opt in explicitly

    def __init__(self, block_elems: Optional[int] = BLOCK_ELEMS):
        #: elements per quantization block; None = one scale for the
        #: whole payload (the legacy wire format)
        self.block_elems = block_elems

    def applicable(self, ct: CommittedType) -> bool:
        # the member bytes must re-view as float32 words; the type system
        # tracks bytes, not element dtypes, so the caller opting in (via
        # FixedPolicy) asserts the buffer really holds float32 data
        return ct.size % 4 == 0 and ct.word_bytes >= 4

    def _nblocks(self, nfloats: int) -> int:
        if self.block_elems is None or nfloats == 0:
            return 1
        return -(-nfloats // self.block_elems)

    # -- §5 cost model ----------------------------------------------------
    def model_pack(self, model, ct, incount):
        p = model.params
        size = ct.size * incount
        # pack the members (priced like rows) + quantize (one extra
        # read+write sweep of the packed bytes)
        from repro.comm.api import ROWS

        return ROWS.model_pack(model, ct, incount) + 2 * size / p.hbm_bw

    def model_unpack(self, model, ct, incount):
        p = model.params
        size = ct.size * incount
        from repro.comm.api import ROWS

        return ROWS.model_unpack(model, ct, incount) + 2 * size / p.hbm_bw

    def wire_bytes(self, ct: CommittedType, incount: int = 1) -> int:
        # one int8 per float32 member + one scale per quantization block
        nfloats = (ct.size * incount) // 4
        return _SCALE_BYTES * self._nblocks(nfloats) + nfloats

    # -- execution --------------------------------------------------------
    def pack(self, buf, ct, incount: int = 1, interpret: Optional[bool] = None):
        member = ops.pack(buf, ct, incount=incount, interpret=interpret)
        f = lax.bitcast_convert_type(
            member.reshape(-1, 4), jnp.float32
        ).reshape(-1)
        n = f.shape[0]
        nb = self._nblocks(n)
        block = self.block_elems if (self.block_elems and nb > 1) else n
        pad = nb * block - n
        blocks = jnp.pad(f, (0, pad)).reshape(nb, block)
        scales = (
            jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), jnp.float32(1e-30))
            / 127.0
        )
        q = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127)
        q = q.astype(jnp.int8).reshape(-1)[:n]
        header = lax.bitcast_convert_type(
            scales.astype(jnp.float32), jnp.uint8
        ).reshape(-1)
        return jnp.concatenate([header, ops.byte_view(q)])

    def unpack_wire(self, comm, dst, wire, recv_ct, send_ct=None, incount=1):
        nfloats = (recv_ct.size * incount) // 4
        nscales = (wire.shape[0] - nfloats) // _SCALE_BYTES
        scales = lax.bitcast_convert_type(
            wire[: _SCALE_BYTES * nscales].reshape(nscales, _SCALE_BYTES),
            jnp.float32,
        ).reshape(-1)
        q = lax.bitcast_convert_type(wire[_SCALE_BYTES * nscales :], jnp.int8)
        if nscales == 1:
            f = q.astype(jnp.float32) * scales[0]  # legacy per-payload scale
        else:
            if self.block_elems is None or nscales != self._nblocks(nfloats):
                raise ValueError(
                    f"wire carries {nscales} scales for {nfloats} floats; "
                    f"expected {self._nblocks(nfloats)} "
                    f"(block_elems={self.block_elems})"
                )
            expand = jnp.repeat(scales, self.block_elems)[:nfloats]
            f = q.astype(jnp.float32) * expand
        member = lax.bitcast_convert_type(f.reshape(-1, 1), jnp.uint8).reshape(-1)
        u = comm.select(recv_ct, incount, wire=False)
        return u.unpack(dst, member, recv_ct, incount)

    def unpack(self, buf, packed, ct, incount=1, interpret=None):
        raise TypeError(
            f"{self.name} is wire-only; use unpack_wire on the received "
            "payload"
        )


INT8_WIRE = Int8Wire()
