"""Compressed-wire strategy plugin: int8 on the wire, upcast on unpack.

The point of the exact-byte :class:`~repro.comm.wireplan.WirePlan`
accounting is that a strategy's wire extent need not equal the packed
member bytes — a bounding window is *larger*, a compressed payload is
*smaller*.  This plugin exercises the smaller side: float32 member
bytes are symmetric-quantized to int8 for the link (4 scale bytes + one
int8 per float — ~4x fewer wire bytes) and dequantized on the receive
side before the scatter.

Quantization is lossy, so the strategy registers with
``selectable = False``: the model never auto-picks it; opt in per
communicator with ``FixedPolicy(Int8Wire.name)`` (lossy halo exchange
is a deliberate accuracy/bandwidth trade, e.g. on a DCN axis).  It is
``wire_only``: local ``pack``/``unpack`` calls fall back to the normal
kernels — only the wire format is compressed.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
from jax import lax

from repro.comm.api import Strategy
from repro.core.commit import CommittedType
from repro.kernels import ops

__all__ = ["Int8Wire", "INT8_WIRE"]

#: wire header: one float32 dequantization scale
_HEADER_BYTES = 4


class Int8Wire(Strategy):
    """Ship float32 member bytes as int8 + a float32 scale header."""

    name = "int8wire"
    wire_only = True       # the compressed format only exists on the wire
    selectable = False     # lossy: never auto-selected, opt in explicitly

    def applicable(self, ct: CommittedType) -> bool:
        # the member bytes must re-view as float32 words; the type system
        # tracks bytes, not element dtypes, so the caller opting in (via
        # FixedPolicy) asserts the buffer really holds float32 data
        return ct.size % 4 == 0 and ct.word_bytes >= 4

    # -- §5 cost model ----------------------------------------------------
    def model_pack(self, model, ct, incount):
        p = model.params
        size = ct.size * incount
        # pack the members (priced like rows) + quantize (one extra
        # read+write sweep of the packed bytes)
        from repro.comm.api import ROWS

        return ROWS.model_pack(model, ct, incount) + 2 * size / p.hbm_bw

    def model_unpack(self, model, ct, incount):
        p = model.params
        size = ct.size * incount
        from repro.comm.api import ROWS

        return ROWS.model_unpack(model, ct, incount) + 2 * size / p.hbm_bw

    def wire_bytes(self, ct: CommittedType, incount: int = 1) -> int:
        # one int8 per float32 member + the scale header
        return _HEADER_BYTES + (ct.size * incount) // 4

    # -- execution --------------------------------------------------------
    def pack(self, buf, ct, incount: int = 1, interpret: Optional[bool] = None):
        member = ops.pack(buf, ct, incount=incount, interpret=interpret)
        f = lax.bitcast_convert_type(
            member.reshape(-1, 4), jnp.float32
        ).reshape(-1)
        scale = jnp.maximum(jnp.max(jnp.abs(f)), jnp.float32(1e-30)) / 127.0
        q = jnp.clip(jnp.round(f / scale), -127, 127).astype(jnp.int8)
        header = lax.bitcast_convert_type(
            scale.astype(jnp.float32).reshape(1, 1), jnp.uint8
        ).reshape(-1)
        return jnp.concatenate([header, ops.byte_view(q)])

    def unpack_wire(self, comm, dst, wire, recv_ct, send_ct=None, incount=1):
        scale = lax.bitcast_convert_type(
            wire[:_HEADER_BYTES].reshape(1, 4), jnp.float32
        ).reshape(())
        q = lax.bitcast_convert_type(wire[_HEADER_BYTES:], jnp.int8)
        f = q.astype(jnp.float32) * scale
        member = lax.bitcast_convert_type(f.reshape(-1, 1), jnp.uint8).reshape(-1)
        u = comm.select(recv_ct, incount, wire=False)
        return u.unpack(dst, member, recv_ct, incount)

    def unpack(self, buf, packed, ct, incount=1, interpret=None):
        raise TypeError(
            f"{self.name} is wire-only; use unpack_wire on the received "
            "payload"
        )


INT8_WIRE = Int8Wire()
