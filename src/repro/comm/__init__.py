"""repro.comm — the TEMPI interposer layer: datatype-aware collectives,
performance-model strategy selection, and system calibration."""

from repro.comm.interposer import Interposer
from repro.comm.perfmodel import PerfModel, StrategyEstimate, SystemParams, TPU_V5E

__all__ = ["Interposer", "PerfModel", "StrategyEstimate", "SystemParams", "TPU_V5E"]
