"""repro.comm — the TEMPI communication layer: the Communicator API with
pluggable datatype strategies, performance-model selection, fused
neighborhood collectives, and the deprecated string-mode Interposer
shim.

Empirical calibration moved to :mod:`repro.measure` (full-term sweeps,
the on-disk SystemParams store, and the persistent selection cache);
``repro.comm.calibrate`` remains as a thin shim over it."""

from repro.comm.api import (
    BaselinePolicy,
    ClassRequest,
    Communicator,
    DEFAULT_SCHEDULE_POLICY,
    FixedPolicy,
    ModelPolicy,
    MODES,
    NeighborRequest,
    Policy,
    Request,
    SendRequest,
    Strategy,
    StrategyRegistry,
    WirePlan,
    as_communicator,
    default_registry,
    plan_neighbor_alltoallv,
    policy_for_mode,
    register_strategy,
    resolve_strategy,
)
from repro.comm.compress import INT8_WIRE, Int8Wire, RLE_WIRE, RleWire
from repro.comm.interposer import Interposer
from repro.comm.perfmodel import (
    OverlapEstimate,
    PerfModel,
    ProgramEstimate,
    StrategyEstimate,
    SystemParams,
    TPU_V5E,
    synthetic_two_tier,
)
from repro.comm.scale import (
    ScaleEstimate,
    ScalePlan,
    build_scale_plan,
    scale_ladder,
)
from repro.comm.topology import (
    LINK_CLASSES,
    Topology,
    classify_and_coalesce,
)
from repro.comm.wireplan import (
    WIRE_SCHEDULES,
    WireGroup,
    collective_payload_bytes,
    plan_wire,
    reschedule,
)

# the compressed-wire plugins ship registered (selectable=False: lossy
# or capacity-padded, opt-in via FixedPolicy) so their wire accounting
# is exercised everywhere
if Int8Wire.name not in default_registry():
    register_strategy(INT8_WIRE)
if RleWire.name not in default_registry():
    register_strategy(RLE_WIRE)

__all__ = [
    "BaselinePolicy",
    "ClassRequest",
    "Communicator",
    "DEFAULT_SCHEDULE_POLICY",
    "FixedPolicy",
    "INT8_WIRE",
    "Int8Wire",
    "RLE_WIRE",
    "RleWire",
    "Interposer",
    "LINK_CLASSES",
    "MODES",
    "ModelPolicy",
    "NeighborRequest",
    "OverlapEstimate",
    "PerfModel",
    "Policy",
    "ProgramEstimate",
    "Request",
    "ScaleEstimate",
    "ScalePlan",
    "SendRequest",
    "Strategy",
    "StrategyEstimate",
    "StrategyRegistry",
    "SystemParams",
    "TPU_V5E",
    "Topology",
    "WIRE_SCHEDULES",
    "WireGroup",
    "WirePlan",
    "as_communicator",
    "build_scale_plan",
    "classify_and_coalesce",
    "collective_payload_bytes",
    "default_registry",
    "plan_neighbor_alltoallv",
    "plan_wire",
    "policy_for_mode",
    "register_strategy",
    "reschedule",
    "resolve_strategy",
    "scale_ladder",
    "synthetic_two_tier",
]
