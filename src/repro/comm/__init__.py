"""repro.comm — the TEMPI communication layer: the Communicator API with
pluggable datatype strategies, performance-model selection, fused
neighborhood collectives, and the deprecated string-mode Interposer
shim.

Empirical calibration moved to :mod:`repro.measure` (full-term sweeps,
the on-disk SystemParams store, and the persistent selection cache);
``repro.comm.calibrate`` remains as a thin shim over it."""

from repro.comm.api import (
    BaselinePolicy,
    Communicator,
    FixedPolicy,
    ModelPolicy,
    MODES,
    Policy,
    Request,
    SendRequest,
    Strategy,
    StrategyRegistry,
    as_communicator,
    default_registry,
    policy_for_mode,
    register_strategy,
    resolve_strategy,
)
from repro.comm.interposer import Interposer
from repro.comm.perfmodel import PerfModel, StrategyEstimate, SystemParams, TPU_V5E

__all__ = [
    "BaselinePolicy",
    "Communicator",
    "FixedPolicy",
    "Interposer",
    "MODES",
    "ModelPolicy",
    "PerfModel",
    "Policy",
    "Request",
    "SendRequest",
    "Strategy",
    "StrategyEstimate",
    "StrategyRegistry",
    "SystemParams",
    "TPU_V5E",
    "as_communicator",
    "default_registry",
    "policy_for_mode",
    "register_strategy",
    "resolve_strategy",
]
