"""Simulated-scale wire plans: the 3072-process regime without hardware.

The paper's headline number is a 3D halo exchange at 3072 processes; no
CI container has 3072 of anything.  What the container *does* have is
the measured wire tables — and every term of the model's schedule prices
is a pure function of per-rank bytes, class counts, and link classes.
So instead of materializing a 3072-rank :class:`~repro.comm.wireplan.
WirePlan` (whose uniform-collective tables alone would be a 3072 x 3072
matrix), :func:`build_scale_plan` constructs a :class:`ScalePlan` — a
lightweight stand-in carrying exactly the attributes the pricing paths
consume — analytically from the exchange geometry:

* process grid: the pencil decomposition ``(nodes, fy, fx)`` with
  ``(fy, fx)`` a near-square factorization of ``ranks_per_node`` —
  row-major ranking then puts one leading-axis slab per node, so
  leading-axis (``dz != 0``) delta classes cross the inter-node tier
  and all others stay on the fast tier;
* delta classes: the distinct neighbor displacements of the periodic
  ``(2*radius+1)^3 - 1``-direction stencil, merged modulo the grid dims
  (a dim of extent 2 folds +1 and -1 into one class, exactly as
  ``plan_wire``'s destination-vector grouping would);
* class bytes: face/edge/corner cell counts from the interior extents
  and radius, summed over each class's member directions;
* link classes and tier bundles: the shared geometry kernel
  :func:`repro.comm.topology.classify_and_coalesce` over the
  materialized destination vectors (O(classes x ranks), trivially
  cheap), guaranteeing the simulated plan classifies identically to a
  real plan on the same topology.

:meth:`repro.comm.perfmodel.PerfModel.at_scale` prices one scale;
:func:`scale_ladder` sweeps rank counts into the predicted schedule
ladder that ``benchmarks/bench_halo.py --assert-scale`` gates on.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.comm.topology import Topology, classify_and_coalesce

__all__ = [
    "ScaleGroup",
    "ScalePlan",
    "ScaleEstimate",
    "build_scale_plan",
    "scale_ladder",
]


@dataclass(frozen=True)
class ScaleGroup:
    """One delta class of a simulated exchange: the directions it
    merged and their summed per-rank wire bytes."""

    directions: Tuple[Tuple[int, int, int], ...]
    nbytes: int


@dataclass(frozen=True)
class ScalePlan:
    """Duck-typed stand-in for a :class:`~repro.comm.wireplan.WirePlan`
    carrying only what the pricing paths read — no per-rank segment
    layout, no O(ranks^2) collective tables."""

    nranks: int
    grid: Tuple[int, int, int]
    groups: Tuple[ScaleGroup, ...]
    wire_bytes: int
    seg_bytes: int
    fused: bool
    link_classes: Tuple[str, ...]
    tier_bundles: Tuple[Tuple[int, ...], ...]
    topology: Topology
    schedule: str = "grouped"

    @property
    def ngroups(self) -> int:
        return len(self.groups)

    @property
    def correction_bytes(self) -> int:
        """Same accounting as ``WirePlan.correction_bytes``: bytes every
        non-representative bundle member re-transmits on the fast tier."""
        return sum(
            self.groups[g].nbytes for b in self.tier_bundles for g in b[1:]
        )

    @property
    def class_cum_bytes(self) -> Tuple[int, ...]:
        out, cum = [], 0
        for grp in self.groups:
            cum += grp.nbytes
            out.append(cum)
        return tuple(out)


@dataclass(frozen=True)
class ScaleEstimate:
    """One rung of the simulated-scale ladder (``PerfModel.at_scale``)."""

    ranks: int
    nodes: int
    grid: Tuple[int, int, int]
    schedule: str               # model-cheapest (or pinned) schedule
    costs: Dict[str, float]     # schedule -> predicted seconds
    wire_bytes: int             # exact payload per rank per exchange
    correction_bytes: int       # tiered's extra fast-tier bytes
    inter_messages: Dict[str, int]  # schedule -> slow-tier messages/rank
    fingerprint: str            # the decision row key this scale pins
    pinned: bool                # True: schedule came from an existing pin


def _factor2(n: int) -> Tuple[int, int]:
    """Near-square (a, b) with a * b == n and a >= b."""
    b = int(math.isqrt(n))
    while b > 1 and n % b:
        b -= 1
    return n // b, b


def build_scale_plan(
    ranks: int,
    ranks_per_node: int,
    interior: Tuple[int, int, int] = (8, 8, 8),
    radius: int = 1,
    element_bytes: int = 4,
) -> ScalePlan:
    """Analytic wire plan of the 3D periodic halo exchange on ``ranks``
    processes, ``ranks_per_node`` per node (see the module docstring
    for the geometry)."""
    ranks = int(ranks)
    ranks_per_node = int(ranks_per_node)
    if ranks <= 0 or ranks_per_node <= 0:
        raise ValueError("ranks and ranks_per_node must be > 0")
    if ranks % ranks_per_node:
        raise ValueError(
            f"ranks={ranks} is not a multiple of "
            f"ranks_per_node={ranks_per_node}"
        )
    nodes = ranks // ranks_per_node
    fy, fx = _factor2(ranks_per_node)
    grid = (nodes, fy, fx)
    topology = Topology.blocked(ranks, ranks_per_node)

    # delta classes: directions merged by displacement mod the grid dims
    # (identical destination vector <=> identical displacement mod dims);
    # an all-zero key is a self-send — a local copy, never on the wire
    r = int(radius)
    key_to_dirs: Dict[Tuple[int, int, int], List[Tuple[int, int, int]]] = {}
    for d in itertools.product(range(-r, r + 1), repeat=3):
        if d == (0, 0, 0):
            continue
        key = tuple(di % g for di, g in zip(d, grid))
        if key == (0, 0, 0):
            continue
        key_to_dirs.setdefault(key, []).append(d)

    groups: List[ScaleGroup] = []
    dsts: List[Tuple[int, ...]] = []
    strides = (fy * fx, fx, 1)
    for key, dirs in key_to_dirs.items():
        nbytes = sum(
            math.prod(
                r if di else n for di, n in zip(d, interior)
            ) * int(element_bytes)
            for d in dirs
        )
        groups.append(ScaleGroup(directions=tuple(dirs), nbytes=nbytes))
        kz, ky, kx = key
        dsts.append(
            tuple(
                ((rank // strides[0] + kz) % grid[0]) * strides[0]
                + ((rank // strides[1] % grid[1] + ky) % grid[1]) * strides[1]
                + ((rank % grid[2] + kx) % grid[2])
                for rank in range(ranks)
            )
        )
    link_classes, tier_bundles = classify_and_coalesce(dsts, topology)
    return ScalePlan(
        nranks=ranks,
        grid=grid,
        groups=tuple(groups),
        wire_bytes=sum(g.nbytes for g in groups),
        seg_bytes=max((g.nbytes for g in groups), default=0),
        fused=len(groups) <= ranks,
        link_classes=link_classes,
        tier_bundles=tier_bundles,
        topology=topology,
    )


def scale_ladder(
    model,
    rank_counts: Sequence[int],
    ranks_per_node: int,
    interior: Tuple[int, int, int] = (8, 8, 8),
    radius: int = 1,
    element_bytes: int = 4,
    axis: Optional[str] = None,
    native: Optional[bool] = None,
    pin: bool = True,
) -> Tuple[ScaleEstimate, ...]:
    """The predicted schedule ladder: ``model.at_scale`` at each rank
    count (ascending), fixed ranks-per-node — the paper's scaling-study
    sweep run entirely on the measured tables."""
    return tuple(
        model.at_scale(
            n,
            ranks_per_node=ranks_per_node,
            interior=interior,
            radius=radius,
            element_bytes=element_bytes,
            axis=axis,
            native=native,
            pin=pin,
        )
        for n in sorted(int(n) for n in rank_counts)
    )
