"""Two-level link topology: which ranks share a node (paper scale regime).

The paper's 3072-process halo exchange is dominated by the slow
inter-node tier, yet a flat ``t_link`` table prices every hop the same.
A :class:`Topology` is the missing map: rank -> node, from which every
edge of a wire plan gets a **link class** — ``intra`` (both endpoints on
one node: ICI/NVLink-fast) or ``inter`` (the edge crosses nodes:
DCN/IB-slow).  The model then prices each delta class by the slowest
tier it crosses, and the planner can *coalesce* all classes crossing to
the same peer node into one slow-tier message (the ``tiered`` wire
schedule — see ``repro.comm.wireplan``).

A topology is deliberately tiny and frozen (hashable — it rides through
the ``plan_wire`` cache and fingerprints decision rows):

* :meth:`Topology.flat` — every rank on one node (single-host; the
  pre-hierarchy behaviour);
* :meth:`Topology.blocked` — contiguous rank blocks of
  ``ranks_per_node``, the standard slowest-axis-major placement (with a
  row-major process grid, block size = the product of the trailing grid
  dims puts one leading-axis slab per node).

:func:`classify_and_coalesce` is the shared geometry kernel: given each
delta class's destination vector it returns the per-class link classes
and the **tier bundles** — inter-crossing classes whose destination-NODE
vectors are identical, which is exactly the condition under which their
payloads can ride one slow-tier collective and be corrected to their
true destination ranks with cheap intra-node hops.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

__all__ = [
    "LINK_CLASSES",
    "Topology",
    "classify_and_coalesce",
]

#: the two tiers of the link hierarchy, fast first
LINK_CLASSES: Tuple[str, ...] = ("intra", "inter")


@dataclass(frozen=True)
class Topology:
    """Rank -> node map of a two-level machine.

    ``nodes[r]`` is the node id hosting rank ``r``.  Node ids need not
    be contiguous; only equality matters (same id = same fast tier).
    """

    nodes: Tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(int(n) for n in self.nodes))
        if not self.nodes:
            raise ValueError("a topology needs at least one rank")

    # -- constructors ----------------------------------------------------
    @staticmethod
    def flat(nranks: int) -> "Topology":
        """Every rank on one node — the single-host (no-hierarchy) map."""
        return Topology(nodes=(0,) * int(nranks))

    @staticmethod
    def blocked(nranks: int, ranks_per_node: int) -> "Topology":
        """Contiguous blocks of ``ranks_per_node`` ranks per node (the
        slowest-axis-major placement every launcher defaults to)."""
        if ranks_per_node <= 0:
            raise ValueError(f"ranks_per_node must be > 0, got {ranks_per_node}")
        return Topology(
            nodes=tuple(r // int(ranks_per_node) for r in range(int(nranks)))
        )

    # -- queries ---------------------------------------------------------
    @property
    def nranks(self) -> int:
        return len(self.nodes)

    @property
    def nnodes(self) -> int:
        return len(set(self.nodes))

    def link_class(self, src: int, dst: int) -> str:
        """``intra`` | ``inter`` for one edge."""
        return "intra" if self.nodes[src] == self.nodes[dst] else "inter"

    @property
    def fingerprint(self) -> str:
        """Stable content hash — the key component that makes wire and
        program decisions topology-specific (a pin recorded on one
        machine shape is never replayed on another)."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            key = ("topology.v1", self.nodes)
            fp = hashlib.sha256(repr(key).encode()).hexdigest()[:16]
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    def __repr__(self) -> str:
        return (
            f"Topology(nranks={self.nranks}, nnodes={self.nnodes}, "
            f"fp={self.fingerprint})"
        )


def classify_and_coalesce(
    dsts: Sequence[Sequence[int]], topology: Topology
) -> Tuple[Tuple[str, ...], Tuple[Tuple[int, ...], ...]]:
    """Link classes and tier bundles of a rank-uniform exchange.

    ``dsts[g][r]`` is the destination rank of delta class ``g`` as seen
    from rank ``r`` (one full permutation per class).  A class is
    ``inter`` when ANY of its edges crosses nodes — a bulk-synchronous
    collective completes at its slowest edge, so the whole class prices
    at the slow tier (the paper's "slowest tier it crosses" rule).

    Bundles group the inter classes by their destination-**node**
    vector: classes where every rank targets the same peer node (if not
    the same peer *rank*).  Such a bundle can travel as ONE slow-tier
    collective along any member's permutation — the concatenated payload
    lands on the right node, and each non-representative member is
    forwarded to its true destination rank by an intra-node correction
    hop (the correction edge ``dst_g0(r) -> dst_g(r)`` stays on-node
    precisely because the bundle key guarantees
    ``node(dst_g(r)) == node(dst_g0(r))`` for every rank).
    """
    nodes = topology.nodes
    link_classes: List[str] = []
    for ds in dsts:
        if len(ds) != topology.nranks:
            raise ValueError(
                f"class destination vector has {len(ds)} ranks; "
                f"topology has {topology.nranks}"
            )
        link_classes.append(
            "inter"
            if any(nodes[d] != nodes[r] for r, d in enumerate(ds))
            else "intra"
        )
    key_to_bundle: Dict[Tuple[int, ...], int] = {}
    bundles: List[List[int]] = []
    for g, ds in enumerate(dsts):
        if link_classes[g] != "inter":
            continue
        key = tuple(nodes[d] for d in ds)
        i = key_to_bundle.setdefault(key, len(bundles))
        if i == len(bundles):
            bundles.append([])
        bundles[i].append(g)
    return tuple(link_classes), tuple(tuple(b) for b in bundles)
