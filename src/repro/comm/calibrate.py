"""DEPRECATED shim: system calibration now lives in :mod:`repro.measure`.

The original module measured pack times only; the measurement subsystem
(`repro.measure.bench`) measures every model term — pack, unpack, wire,
and contiguous copy — and `repro.measure.store` persists the result
keyed by a system fingerprint.  This module keeps the old entry points
working:

    measure_pack_table()  -> repro.measure.bench.measure_pack_table
    calibrate()           -> repro.measure.bench.calibrate_params
    python -m repro.comm.calibrate [out.json]   (still writes bare
        SystemParams JSON; prefer `python -m repro.measure`)
"""

from __future__ import annotations

import sys

import jax

from repro.comm.perfmodel import SystemParams, TPU_V5E
from repro.measure.bench import (
    BLOCK_BYTES,
    PITCH,
    TOTAL_BYTES,
    calibrate_params,
    measure_pack_table,
    time_fn as _time_fn,
)

__all__ = ["measure_pack_table", "calibrate", "main"]


def calibrate(name: str | None = None) -> SystemParams:
    """Full-term calibration on the running backend (see
    :func:`repro.measure.bench.calibrate_params`)."""
    return calibrate_params(name=name)


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else "system_params.json"
    params = calibrate()
    with open(out, "w") as f:
        f.write(params.to_json())
    print(f"wrote {out} ({jax.default_backend()} backend)")


if __name__ == "__main__":
    main()
