"""System parameter calibration (paper §6.3: "TEMPI provides a binary
that records system performance parameters to the file system.  This
binary should be run once before TEMPI is used in an application.").

Measures pack/unpack kernel latency over a sparse (contiguous-block-size
x total-object-size) grid on the *running* backend and writes a
:class:`~repro.comm.perfmodel.SystemParams` JSON.  On a real TPU the
measurements are wall-clock; on CPU containers they still provide a
useful relative ordering, and the analytic ``TPU_V5E`` table remains the
default for roofline work.

Run:  PYTHONPATH=src python -m repro.comm.calibrate [out.json]
"""

from __future__ import annotations

import dataclasses
import math
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BYTE, TypeRegistry, Vector
from repro.kernels import pack
from repro.comm.perfmodel import SystemParams, TPU_V5E

__all__ = ["measure_pack_table", "calibrate", "main"]

# paper Fig. 10 sweeps 64 B - 4 MiB objects over block sizes; we use a
# coarser grid (interpolated at query time)
BLOCK_BYTES = (8, 32, 128, 512)
TOTAL_BYTES = (1 << 10, 1 << 14, 1 << 18, 1 << 22)
PITCH = 512  # paper Fig. 7 uses 512 B pitch


def _time_fn(fn, *args, iters: int = 5) -> float:
    fn(*args)  # compile / warm caches
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def measure_pack_table(
    strategies=None,
) -> Dict[str, List[Tuple[float, float, float]]]:
    """Measure every calibratable registered strategy (or an explicit
    iterable of strategies/names)."""
    from repro.comm.api import default_registry, resolve_strategy

    if strategies is None:
        strats = default_registry().measurable()
    else:
        strats = tuple(resolve_strategy(s) for s in strategies)
    reg = TypeRegistry()
    table: Dict[str, List[Tuple[float, float, float]]] = {
        s.name: [] for s in strats
    }
    for blk in BLOCK_BYTES:
        pitch = max(PITCH, 2 * blk)
        for total in TOTAL_BYTES:
            nblocks = max(total // blk, 1)
            ct = reg.commit(Vector(nblocks, blk, pitch, BYTE))
            buf = jnp.zeros((ct.extent + 64,), jnp.uint8)
            for s in strats:
                cap = s.calibration_cap
                if cap is not None and nblocks > cap:
                    continue  # per-block unrolled HLO blows up past the cap
                jfn = jax.jit(lambda b, _ct=ct, _s=s: pack(b, _ct, strategy=_s))
                sec = _time_fn(jfn, buf)
                table[s.name].append(
                    (math.log2(blk), math.log2(nblocks * blk), sec)
                )
    return table


def calibrate(name: str | None = None) -> SystemParams:
    backend = jax.default_backend()
    table = measure_pack_table()
    base = TPU_V5E if backend == "tpu" else dataclasses.replace(
        TPU_V5E, name=f"{backend}_measured"
    )
    return dataclasses.replace(
        base,
        name=name or f"{backend}_calibrated",
        pack_table={k: tuple(v) for k, v in table.items()},
    )


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else "system_params.json"
    params = calibrate()
    with open(out, "w") as f:
        f.write(params.to_json())
    print(f"wrote {out} ({jax.default_backend()} backend)")


if __name__ == "__main__":
    main()
