"""The Communicator API: pluggable datatype strategies, request-based
nonblocking transfers, and fused neighborhood collectives.

This module is the *single* home of every strategy and mode name in the
system.  TEMPI's central claim is that an interposed layer can pick the
best datatype-handling implementation per call site; the seam that makes
that claim extensible is a registry of :class:`Strategy` plugins rather
than string comparisons scattered through the runtime:

* a :class:`Strategy` bundles the §5 cost model terms (``model_pack`` /
  ``model_unpack`` / ``wire_bytes`` -> :meth:`Strategy.plan`) with the
  execution paths (``pack`` / ``unpack`` / ``unpack_wire`` and the
  per-repetition ``pack_leaf`` / ``unpack_leaf`` kernels used by
  ``repro.kernels.ops``);
* a :class:`StrategyRegistry` holds the installed strategies; the
  :class:`~repro.comm.perfmodel.PerfModel` selects among *whatever is
  registered* — the paper's "one-shot" analogue (:class:`Bounding`) is
  an ordinary plugin, not a special case hardwired in ``sendrecv``;
* a :class:`Communicator` binds a mesh axis + :class:`SystemParams` and
  exposes MPI-shaped entry points: ``commit``, ``pack``/``unpack``,
  request-based ``isend``/``irecv`` (the wire op is issued eagerly so
  XLA can overlap independent exchanges; :meth:`Request.wait`
  materializes the unpack), and a fused
  :meth:`Communicator.neighbor_alltoallv` — the paper's actual
  ``MPI_Alltoallv`` halo transport — that packs every region at its
  **exact** wire extent into one flat buffer described by a
  :class:`~repro.comm.wireplan.WirePlan` and issues the cheapest wire
  schedule that can carry that ragged layout (a native ragged
  collective, a byte-exact uniform ``all_to_all``, or one ``ppermute``
  per delta class — see ``repro.comm.wireplan`` for the ladder).  The
  old padded-class layout is gone: the plan's ``wire_bytes`` is the sum
  of per-peer packed extents, and that same count is what the
  :class:`~repro.comm.perfmodel.PerfModel` prices and the
  ``DecisionCache`` records.

``repro.comm.interposer.Interposer`` remains as a thin deprecated shim
over :class:`Communicator` (mode strings map to :class:`Policy` objects
via :func:`policy_for_mode`).
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core.commit import CommittedType, TypeRegistry, WireSegment
from repro.core.datatypes import Datatype
from repro.core.strided_block import StridedBlock
from repro.kernels import ops
from repro.kernels import ref as refk
from repro.kernels.geometry import (
    VMEM_BUDGET_BYTES,
    PackGeometry,
    plan_geometry,
)
from repro.kernels.pack import (
    pack_compress_ragged,
    pack_dma,
    pack_ragged,
    pack_rows,
)
from repro.kernels.unpack import (
    decode_unpack_ragged,
    unpack_dma,
    unpack_ragged,
    unpack_rows,
)
from repro.comm.perfmodel import (
    PerfModel,
    StrategyEstimate,
    SystemParams,
    TPU_V5E,
)
from repro.comm.wireplan import WireGroup, WirePlan, plan_wire

__all__ = [
    "Strategy",
    "StrategyRegistry",
    "default_registry",
    "register_strategy",
    "resolve_strategy",
    "static_choice",
    "Policy",
    "ModelPolicy",
    "BaselinePolicy",
    "FixedPolicy",
    "policy_for_mode",
    "MODES",
    "Request",
    "SendRequest",
    "ClassRequest",
    "NeighborRequest",
    "Communicator",
    "as_communicator",
    "WirePlan",
    "WireGroup",
    "plan_neighbor_alltoallv",
    "DEFAULT_SCHEDULE_POLICY",
]

StrategyLike = Union[str, "Strategy", None]

#: baseline per-block copy emulation explodes HLO size past this many
#: blocks; beyond it the baseline degrades to the gather path (still a
#: fair stand-in: the real baselines issue that many cudaMemcpyAsyncs)
BASELINE_BLOCK_CAP = 1024


# ===========================================================================
# Strategy protocol
# ===========================================================================

class Strategy:
    """One way to move a committed datatype: cost model + execution.

    Subclass and :func:`register_strategy` (or register on a private
    :class:`StrategyRegistry`) to add a transfer strategy; the
    performance model then selects it whenever it wins.  Override points:

    ``applicable``    can this strategy handle the type at all?
    ``model_pack`` /  the §5 cost terms (seconds); ``plan`` assembles the
    ``model_unpack``  full T = T_pack + T_link + T_unpack estimate
    ``wire_bytes``    bytes this strategy puts on the wire
    ``pack``          produce the wire payload from the user buffer
    ``unpack``        scatter *packed member bytes* into the buffer
    ``unpack_wire``   consume the wire payload (differs from ``unpack``
                      only when the wire format isn't the packed bytes,
                      e.g. :class:`Bounding`'s contiguous window)
    ``pack_leaf`` /   per-repetition 2D/3D kernel dispatch used by
    ``unpack_leaf``   ``repro.kernels.ops`` once geometry is planned
    """

    name: str = "abstract"
    #: only meaningful when bytes cross the wire (no local pack/unpack)
    wire_only: bool = False
    #: participates in automatic PerfModel selection
    selectable: bool = True
    #: the wire format is length-aware: the live payload is a prefix of
    #: the capacity wire, truncatable at :meth:`probe_stream_bytes` —
    #: the "varlen" wire schedule only forms over such strategies
    supports_varlen: bool = False
    #: calibration sweep cap on block count (None = unbounded)
    calibration_cap: Optional[int] = None

    # -- applicability ----------------------------------------------------
    def applicable(self, ct: CommittedType) -> bool:
        return True

    # -- §5 cost model ----------------------------------------------------
    def model_pack(self, model: PerfModel, ct: CommittedType, incount: int) -> float:
        raise NotImplementedError

    def model_unpack(self, model: PerfModel, ct: CommittedType, incount: int) -> float:
        sb = ct.block
        if sb is not None and self._table_covers(sb, incount):
            m = model.measured_unpack(self.name, sb.counts[0], ct.size * incount)
            if m is not None:
                return m
        # no measured unpack table: strided writes are slower than pack
        # (paper §6.3 observes the same pack/unpack asymmetry)
        return 1.5 * self.model_pack(model, ct, incount)

    def _table_covers(self, sb: StridedBlock, incount: int) -> bool:
        """Whether this strategy's measured tables can legitimately
        answer for an object of this many blocks.  The calibration sweep
        never measures past ``calibration_cap``, so interpolating there
        would extrapolate a small-object time onto an object the cap
        exists to exclude (e.g. pricing 500k unrolled per-block copies
        at a 512-block measurement) — fall back to the analytic model."""
        cap = self.calibration_cap
        return cap is None or sb.num_blocks * incount <= cap

    def wire_bytes(self, ct: CommittedType, incount: int = 1) -> int:
        return ct.packed_extent(incount)

    def probe_stream_bytes(
        self, ct: CommittedType, incount: int, buf: jax.Array
    ) -> int:
        """Effective wire bytes for a *concrete* payload sample.  The
        default wire format is not length-aware, so the stream length
        is the capacity; ``supports_varlen`` strategies override this
        with an exact probe of the encoded stream."""
        return self.wire_bytes(ct, incount)

    def wire_segment(
        self, ct: CommittedType, incount: int = 1, offset: int = 0
    ) -> WireSegment:
        """The exact wire-segment descriptor this strategy's payload for
        ``ct`` occupies — the unit every :class:`WirePlan` is built
        from.  Strategies whose wire format differs from the packed
        member bytes (bounding windows, compressed payloads) inherit
        this and only override :meth:`wire_bytes`."""
        return ct.wire_segment(
            offset=offset, incount=incount, nbytes=self.wire_bytes(ct, incount)
        )

    def plan(
        self, model: PerfModel, ct: CommittedType, incount: int, hops: int = 1
    ) -> StrategyEstimate:
        """Full strategy estimate (paper Eqs. 1-3 analogue), priced on
        the exact wire-segment extent."""
        seg = self.wire_segment(ct, incount)
        return StrategyEstimate(
            self.name,
            self.model_pack(model, ct, incount),
            model.t_link(seg.nbytes, hops),
            self.model_unpack(model, ct, incount),
            wire_bytes=seg.nbytes,
        )

    # -- execution --------------------------------------------------------
    def pack(
        self,
        buf: jax.Array,
        ct: CommittedType,
        incount: int = 1,
        interpret: Optional[bool] = None,
    ) -> jax.Array:
        return ops.pack(buf, ct, incount=incount, strategy=self, interpret=interpret)

    def unpack(
        self,
        buf: jax.Array,
        packed: jax.Array,
        ct: CommittedType,
        incount: int = 1,
        interpret: Optional[bool] = None,
    ) -> jax.Array:
        return ops.unpack(
            buf, packed, ct, incount=incount, strategy=self, interpret=interpret
        )

    def unpack_wire(
        self,
        comm: "Communicator",
        dst: jax.Array,
        wire: jax.Array,
        recv_ct: CommittedType,
        send_ct: Optional[CommittedType] = None,
        incount: int = 1,
    ) -> jax.Array:
        """Consume received wire bytes.  Default: the wire carries packed
        member bytes; scatter them with the strategy the communicator
        selects for the receive type."""
        u = comm.select(recv_ct, incount, wire=False)
        return u.unpack(dst, wire, recv_ct, incount)

    # -- per-repetition kernel dispatch (called from repro.kernels.ops) ---
    def pack_leaf(
        self,
        b: jax.Array,
        sb: StridedBlock,
        geom: Optional[PackGeometry],
        interpret: bool,
    ) -> jax.Array:
        raise TypeError(f"strategy {self.name!r} has no local pack kernel")

    def unpack_leaf(
        self,
        b: jax.Array,
        packed: jax.Array,
        sb: StridedBlock,
        geom: Optional[PackGeometry],
        interpret: bool,
    ) -> jax.Array:
        raise TypeError(f"strategy {self.name!r} has no local unpack kernel")

    # ---------------------------------------------------------------------
    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Strategy {self.name}>"


def _analytic_prologue(model, strategy, ct, incount):
    """Shared cost-model prologue: generic-type fallback and measured
    pack-table lookup (refused past the strategy's calibration cap —
    see :meth:`Strategy._table_covers`).  Returns (params, size, block,
    measured|None)."""
    p = model.params
    size = ct.size * incount
    sb = ct.block
    if sb is None:
        return p, size, None, p.kernel_launch + 2 * size / p.hbm_bw
    if not strategy._table_covers(sb, incount):
        return p, size, sb, None
    return p, size, sb, model.measured(strategy.name, sb.counts[0], size)


class Rows(Strategy):
    """Pitched row kernel, then one contiguous collective ≙ the paper's
    "device" method: Pallas double-buffers full-pitch row groups."""

    name = "rows"

    def applicable(self, ct: CommittedType) -> bool:
        return ct.block is not None and plan_geometry(ct.block) is not None

    def model_pack(self, model, ct, incount):
        p, size, sb, m = _analytic_prologue(model, self, ct, incount)
        if sb is None or m is not None:
            return m
        geom = plan_geometry(sb)
        over = geom.overfetch if geom else 1.0
        touched = size * over + size  # pitched read + contiguous write
        return p.kernel_launch + touched / p.hbm_bw

    def pack_leaf(self, b, sb, geom, interpret):
        if geom is None:
            return refk.pack_ref(b, sb)
        return ops.run_pack_kernel(b, geom, pack_rows, interpret)

    def unpack_leaf(self, b, packed, sb, geom, interpret):
        if geom is None:
            return refk.unpack_ref(b, packed, sb)
        if geom.planes > 1 and geom.plane_rows < geom.rows:
            # interleaved planes: row read-modify-write would lose
            # updates; use the windowed DMA kernel instead
            kernel = _dma_unpack_kernel
        else:
            kernel = unpack_rows
        return ops.run_unpack_kernel(b, packed, geom, kernel, interpret)


def _dma_pack_kernel(src2d, geom, interpret=False):
    return pack_dma(src2d, geom, VMEM_BUDGET_BYTES, interpret=interpret)


def _dma_unpack_kernel(dst2d, pk3, geom, interpret=False):
    return unpack_dma(dst2d, pk3, geom, VMEM_BUDGET_BYTES, interpret)


class Dma(Strategy):
    """Strided-descriptor DMA kernel ≙ the paper's "staged" method: one
    DMA per row-chunk, no pitch over-fetch."""

    name = "dma"

    def applicable(self, ct: CommittedType) -> bool:
        return ct.block is not None and plan_geometry(ct.block) is not None

    def model_pack(self, model, ct, incount):
        p, size, sb, m = _analytic_prologue(model, self, ct, incount)
        if sb is None or m is not None:
            return m
        nblocks = sb.num_blocks * incount
        chunks = max(nblocks // 128, 1)  # descriptors per ~128-row chunk
        return p.kernel_launch + chunks * p.dma_setup + 2 * size / p.hbm_bw

    def pack_leaf(self, b, sb, geom, interpret):
        if geom is None:
            return refk.pack_ref(b, sb)
        return ops.run_pack_kernel(b, geom, _dma_pack_kernel, interpret)

    def unpack_leaf(self, b, packed, sb, geom, interpret):
        if geom is None:
            return refk.unpack_ref(b, packed, sb)
        return ops.run_unpack_kernel(b, packed, geom, _dma_unpack_kernel, interpret)


class XlaBlocks(Strategy):
    """Per-block XLA copies into a contiguous buffer — the naive
    CUDA-aware-MPI baseline every implementation shares."""

    name = "xla"
    calibration_cap = 512  # unrolled per-block HLO blows up past this

    def model_pack(self, model, ct, incount):
        p, size, sb, m = _analytic_prologue(model, self, ct, incount)
        if sb is None or m is not None:
            return m
        nblocks = sb.num_blocks * incount
        return nblocks * p.xla_copy_overhead + 2 * size / p.hbm_bw

    def pack_leaf(self, b, sb, geom, interpret):
        if geom is None:
            return refk.pack_ref(b, sb)
        return refk.pack_xla_blocks(b, sb)

    def unpack_leaf(self, b, packed, sb, geom, interpret):
        if geom is None:
            return refk.unpack_ref(b, packed, sb)
        return refk.unpack_xla_blocks(b, packed, sb)


class Gather(Strategy):
    """Oracle gather/scatter fallback (offset-list walk).  Correct for
    every type; never auto-selected."""

    name = "ref"
    selectable = False

    def model_pack(self, model, ct, incount):
        # modeled like the per-block baseline: a gather touches every
        # block individually
        p, size, sb, m = _analytic_prologue(model, self, ct, incount)
        if sb is None or m is not None:
            return m
        return sb.num_blocks * incount * p.xla_copy_overhead + 2 * size / p.hbm_bw

    def pack_leaf(self, b, sb, geom, interpret):
        return refk.pack_ref(b, sb)

    def unpack_leaf(self, b, packed, sb, geom, interpret):
        return refk.unpack_ref(b, packed, sb)


class Auto(Strategy):
    """Static geometry heuristic used when no calibrated model drives the
    choice: the pitched row kernel wins while its over-fetch stays
    moderate (automatic double-buffering); the strided-DMA kernel wins
    for small blocks at large pitches.  Not a modeled strategy — it
    defers to :func:`static_choice` per leaf."""

    name = "auto"
    selectable = False

    def model_pack(self, model, ct, incount):
        geom = plan_geometry(ct.block) if ct.block is not None else None
        return static_choice(geom).model_pack(model, ct, incount)

    def pack_leaf(self, b, sb, geom, interpret):
        return static_choice(geom).pack_leaf(b, sb, geom, interpret)

    def unpack_leaf(self, b, packed, sb, geom, interpret):
        return static_choice(geom).unpack_leaf(b, packed, sb, geom, interpret)


class Bounding(Strategy):
    """The paper's "one-shot" analogue: ship the contiguous bounding
    window of the object with no sender-side pack at all; the receiver
    extracts the member bytes.  Wins when the object is dense in its
    extent — zero staging, pays over-transfer instead of pack cost."""

    name = "bounding"
    wire_only = True

    def applicable(self, ct: CommittedType) -> bool:
        return ct.block is not None

    def model_pack(self, model, ct, incount):
        return 0.0  # no pack at all

    def model_unpack(self, model, ct, incount):
        return 0.0  # extraction is priced in plan(), not here

    def wire_bytes(self, ct, incount=1):
        sb = ct.block
        if sb is None:
            return ct.extent * incount
        return sb.extent + (incount - 1) * ct.extent

    def plan(self, model, ct, incount, hops=1):
        sb = ct.block
        if sb is not None and sb.size == sb.extent:
            t_extract = 0.0  # fully dense: the wire bytes ARE the data
        else:
            # receiver must extract the member bytes from the bounding
            # window and splice them into the destination (two kernels)
            t_extract = ROWS.model_pack(model, ct, incount) + ROWS.model_unpack(
                model, ct, incount
            )
        nbytes = self.wire_bytes(ct, incount)
        return StrategyEstimate(
            self.name, 0.0, model.t_link(nbytes, hops), t_extract,
            wire_bytes=nbytes,
        )

    def pack(self, buf, ct, incount=1, interpret=None):
        sb = ct.block
        if sb is None:
            raise ValueError(f"{self.name} needs a strided block")
        ext = self.wire_bytes(ct, incount)
        return lax.dynamic_slice(ops.byte_view(buf), (sb.start,), (ext,))

    def unpack_wire(self, comm, dst, wire, recv_ct, send_ct=None, incount=1):
        # extract member bytes from the received window: same geometry as
        # the send type, rebased to start 0
        send_ct = send_ct or recv_ct
        sb = send_ct.block
        rb = StridedBlock(0, sb.counts, sb.strides)
        if incount > 1:
            parts = [
                ops.pack_block(
                    lax.dynamic_slice(
                        wire, (r * send_ct.extent,), (sb.extent,)
                    ),
                    rb,
                )
                for r in range(incount)
            ]
            packed = jnp.concatenate(parts)
        else:
            packed = ops.pack_block(wire, rb)
        u = comm.select(recv_ct, incount, wire=False)
        return u.unpack(dst, packed, recv_ct, incount)

    def unpack(self, buf, packed, ct, incount=1, interpret=None):
        raise TypeError(
            f"{self.name} has no local unpack; use unpack_wire on the "
            "received window"
        )


# ===========================================================================
# registry
# ===========================================================================

class StrategyRegistry:
    """Installed strategies, by name.  The default registry carries the
    paper's menu; register plugins here (or on a copy, for isolated
    experiments) and the model immediately selects among them."""

    def __init__(self, strategies: Sequence[Strategy] = ()):
        self._by_name: Dict[str, Strategy] = {}
        self._version = 0  # bumped on mutation; invalidates model caches
        for s in strategies:
            self.register(s)

    @property
    def version(self) -> int:
        return self._version

    def register(self, strategy: Union[Strategy, type]) -> Strategy:
        if isinstance(strategy, type):
            strategy = strategy()
        if not strategy.name or strategy.name == Strategy.name:
            raise ValueError("strategy needs a distinct .name")
        if strategy.name in self._by_name:
            raise ValueError(f"strategy {strategy.name!r} already registered")
        self._by_name[strategy.name] = strategy
        self._version += 1
        return strategy

    def get(self, name: StrategyLike) -> Strategy:
        if isinstance(name, Strategy):
            return name
        if name is None:
            name = Auto.name
        s = self._by_name.get(name)
        if s is None:
            raise ValueError(
                f"unknown strategy {name!r}; registered: {self.names()}"
            )
        return s

    def names(self) -> Tuple[str, ...]:
        return tuple(self._by_name)

    def selectable(self) -> Tuple[Strategy, ...]:
        return tuple(s for s in self._by_name.values() if s.selectable)

    def measurable(self) -> Tuple[Strategy, ...]:
        """Strategies with a real pack kernel worth calibrating."""
        return tuple(
            s for s in self._by_name.values() if s.selectable and not s.wire_only
        )

    def copy(self) -> "StrategyRegistry":
        return StrategyRegistry(tuple(self._by_name.values()))

    def __iter__(self):
        return iter(self._by_name.values())

    def __contains__(self, name: object) -> bool:
        return name in self._by_name

    def __len__(self) -> int:
        return len(self._by_name)


ROWS = Rows()
DMA = Dma()
XLA = XlaBlocks()
REF = Gather()
AUTO = Auto()
BOUNDING = Bounding()

_DEFAULT_REGISTRY = StrategyRegistry((ROWS, DMA, XLA, REF, AUTO, BOUNDING))


def default_registry() -> StrategyRegistry:
    """The process-global strategy registry."""
    return _DEFAULT_REGISTRY


def register_strategy(strategy: Union[Strategy, type]) -> Strategy:
    """Install a strategy plugin into the default registry."""
    return _DEFAULT_REGISTRY.register(strategy)


def resolve_strategy(
    strategy: StrategyLike, registry: Optional[StrategyRegistry] = None
) -> Strategy:
    """Name -> Strategy (None resolves to the static-auto strategy)."""
    return (registry or _DEFAULT_REGISTRY).get(strategy)


def static_choice(geom: Optional[PackGeometry]) -> Strategy:
    """Geometry-only kernel choice used by :class:`Auto` (the calibrated
    model refines this crossover, as the paper's model picks one-shot vs
    device)."""
    if geom is None:
        return REF
    return ROWS if geom.overfetch <= 4.0 else DMA


# ===========================================================================
# policies (strategy-selection behaviours; the old Interposer "modes")
# ===========================================================================

class Policy:
    """Decides the strategy per (committed type, incount, wire?) call."""

    def select(
        self, comm: "Communicator", ct: CommittedType, incount: int, wire: bool
    ) -> Strategy:
        raise NotImplementedError


class ModelPolicy(Policy):
    """Performance-model selection over the registered strategies (§5) —
    the paper's TEMPI behaviour."""

    def select(self, comm, ct, incount, wire):
        est = comm.model.select(
            ct, incount, allow_bounding=wire, registry=comm.strategies
        )
        return comm.strategies.get(est.strategy)


class BaselinePolicy(Policy):
    """Naive per-block copies (emulating the datatype handling every
    CUDA-aware MPI shares), degrading to the gather path past the HLO
    block cap."""

    def __init__(self, block_cap: int = BASELINE_BLOCK_CAP):
        self.block_cap = block_cap

    def select(self, comm, ct, incount, wire):
        if ct.block is not None and ct.block.num_blocks * incount > self.block_cap:
            return comm.strategies.get(REF.name)
        return comm.strategies.get(XLA.name)


class FixedPolicy(Policy):
    """Force one strategy for experiments.  Wire-only strategies (e.g.
    bounding) cannot serve local pack/unpack calls; those fall back to
    the static-auto heuristic so ``unpack``/``sendrecv`` keep working."""

    def __init__(self, strategy: StrategyLike):
        self.strategy = resolve_strategy(strategy)

    def select(self, comm, ct, incount, wire):
        s = comm.strategies.get(self.strategy)
        if s.wire_only and not wire:
            return comm.strategies.get(AUTO.name)
        return s


#: legacy Interposer mode names (kept for the shim + CLI flags)
MODES = ("baseline", "tempi", Rows.name, Dma.name, XlaBlocks.name, Gather.name)


def policy_for_mode(mode: str) -> Policy:
    """Map a legacy mode string to a Policy (ValueError on unknown)."""
    if mode == "baseline":
        return BaselinePolicy()
    if mode == "tempi":
        return ModelPolicy()
    if mode in MODES:
        return FixedPolicy(mode)
    raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")


# ===========================================================================
# requests (nonblocking semantics)
# ===========================================================================

_PENDING = object()


class Request:
    """Handle to a pending communication.  The wire transport is issued
    when the request is created (so XLA is free to overlap independent
    exchanges); :meth:`wait` materializes the receive-side unpack."""

    def __init__(self, thunk: Optional[Callable[[], jax.Array]] = None,
                 value: jax.Array = _PENDING):
        self._thunk = thunk
        self._value = value

    @property
    def completed(self) -> bool:
        return self._value is not _PENDING

    def wait(self) -> jax.Array:
        if self._value is _PENDING:
            self._value = self._thunk()
            self._thunk = None
        return self._value


class SendRequest(Request):
    """An issued wire transfer: holds the (traced) received payload plus
    the metadata ``irecv`` needs to unpack it.  ``segment`` is the exact
    :class:`~repro.core.commit.WireSegment` the payload occupied on the
    wire (what the communicator's byte accounting recorded)."""

    def __init__(self, wire: jax.Array, strategy: Strategy,
                 send_ct: CommittedType, incount: int,
                 segment: Optional[WireSegment] = None):
        super().__init__(value=wire)
        self.strategy = strategy
        self.send_ct = send_ct
        self.incount = incount
        self.segment = segment


class ClassRequest(Request):
    """One delta class of a fused neighborhood exchange: the class's
    received wire payload plus exactly the unpacks that consume it.
    Completable independently of its siblings — the recv regions of
    distinct transfers never overlap, so classes may be unpacked in any
    completion order and the buffer is bit-identical.

    ``transfers`` names the plan-level transfer indices riding in this
    class (for halo exchanges these map 1:1 onto ``DIRECTIONS``), which
    is what lets a region scheduler translate "this class landed" into
    "these rim regions are computable"."""

    def __init__(self, index: int, payload: jax.Array,
                 transfers: Sequence[int], nbytes: int,
                 unpack: Callable[[jax.Array, jax.Array], jax.Array]):
        super().__init__(value=payload)
        self.index = int(index)
        self.transfers = tuple(transfers)
        self.nbytes = int(nbytes)
        self._unpack = unpack
        #: set by :meth:`NeighborRequest.wait_any` once the class's
        #: unpacks have been applied to the exchange buffer
        self.applied = False

    def ready(self) -> bool:
        """Best-effort completion probe: True when the payload is known
        to be resident (``jax.Array.is_ready``).  Traced payloads have
        no runtime notion of readiness and report True, so a traced
        drain loop proceeds in deterministic plan order."""
        probe = getattr(self._value, "is_ready", None)
        if callable(probe):
            try:
                return bool(probe())
            except Exception:
                return True
        return True

    def unpack_into(self, buf: jax.Array) -> jax.Array:
        """Apply this class's unpacks to ``buf`` (returns the updated
        buffer).  Normally driven by :meth:`NeighborRequest.wait_any`."""
        self.applied = True
        return self._unpack(buf, self._value)


class NeighborRequest(Request):
    """The request :meth:`Communicator.ineighbor_alltoallv` returns:
    a fused exchange split into independently-completable per-class
    :class:`ClassRequest` handles.

    ``wait()`` keeps the historical monolithic contract — drain every
    class, return the fully-unpacked buffer.  Overlap-aware callers
    (the region-split stencil path) instead drive :meth:`wait_any` in a
    drain loop, reading :attr:`buffer` between drains: each drained
    class has written its recv regions, every other region of the
    buffer is untouched, so any consumer whose inputs are covered by
    the drained classes may run immediately."""

    def __init__(self, buf: jax.Array, classes: Sequence[ClassRequest],
                 plan: Optional[WirePlan] = None,
                 on_drain: Optional[Callable[["NeighborRequest",
                                              ClassRequest], None]] = None):
        super().__init__()
        self._buf = buf
        self.classes = tuple(classes)
        self.plan = plan
        #: class indices in the order they were drained
        self.drained: List[int] = []
        self._on_drain = on_drain
        if not self.classes:
            self._value = buf

    @property
    def buffer(self) -> jax.Array:
        """The exchange buffer with every *drained* class unpacked (and
        the send-side contents everywhere else)."""
        return self._buf

    @property
    def pending(self) -> Tuple[ClassRequest, ...]:
        return tuple(c for c in self.classes if not c.applied)

    def wait_any(self) -> ClassRequest:
        """Drain one class: prefer the first whose payload is already
        resident (out-of-order completion), fall back to plan order, and
        apply its unpacks to :attr:`buffer`.  Returns the drained class;
        raises ``ValueError`` once all classes are drained."""
        pend = [c for c in self.classes if not c.applied]
        if not pend:
            raise ValueError("wait_any() on a fully drained request")
        pick = next((c for c in pend if c.ready()), pend[0])
        self._buf = pick.unpack_into(self._buf)
        self.drained.append(pick.index)
        if self._on_drain is not None:
            self._on_drain(self, pick)
        if len(self.drained) == len(self.classes):
            self._value = self._buf
        return pick

    def wait(self) -> jax.Array:
        while self._value is _PENDING:
            self.wait_any()
        return self._value


# ===========================================================================
# fused neighborhood alltoallv planning (host-side, cached)
# ===========================================================================

#: how :meth:`Communicator.plan_neighbor` chooses a wire schedule when
#: the caller does not say: ``"model"`` prices grouped launches vs
#: uniform padding on the measured wire tables (ROADMAP: the flipped
#: default); ``"exact"`` restores the byte-exact ladder per call.
DEFAULT_SCHEDULE_POLICY = "model"


def plan_neighbor_alltoallv(
    sizes: Tuple[int, ...],
    perms: Tuple[Tuple[Tuple[int, int], ...], ...],
    fingerprints: Optional[Tuple[str, ...]] = None,
    uniform_waste_tolerance: float = 0.0,
) -> WirePlan:
    """Group ``len(sizes)`` transfers (one full permutation each) into
    an exact-byte :class:`WirePlan`.  Thin alias over
    :func:`repro.comm.wireplan.plan_wire` kept as the public planning
    entry point of this module."""
    return plan_wire(
        tuple(sizes),
        tuple(tuple(map(tuple, p)) for p in perms),
        fingerprints=fingerprints,
        uniform_waste_tolerance=uniform_waste_tolerance,
    )


# ===========================================================================
# the Communicator
# ===========================================================================

class Communicator:
    """Datatype-aware communication endpoint bound to a mesh axis.

    Parameters
    ----------
    axis_name: default mesh axis for the collective entry points (each
        accepts a per-call override).
    params: system parameter table for the performance model.
    registry: datatype commit cache (``MPI_Type_commit`` analogue).
    strategies: strategy registry; defaults to the process-global one.
    policy: strategy-selection behaviour; defaults to model selection.
    decisions: optional :class:`repro.measure.DecisionCache` — persists
        strategy selections (fingerprint-keyed) and records the audit
        log.
    telemetry: optional :class:`repro.fleet.ExchangeTelemetry` — the
        runtime half of the feedback loop.  Planning entry points
        register the model's predicted seconds per decision key
        (host-side, safe under tracing); the *blocking* entry points
        (:meth:`sendrecv`, :meth:`neighbor_alltoallv`) additionally
        observe wall time — but only when running eagerly: inside a
        ``jit``/``shard_map`` trace a timer would measure tracing, so
        tracer arguments skip the probe and jitted workloads time their
        compiled step from the launch layer instead.
    tracer: optional :class:`repro.obs.Tracer` — structured per-phase
        spans on the same paths the telemetry probe times, under the
        same guard: eager blocking entry points record ``exchange`` →
        ``pack``/``wire``/``unpack`` spans with ``block_until_ready``
        at each phase boundary (the decision signature and the model's
        per-phase predictions ride as span attributes); inside a jax
        trace nothing records, and fused compiled iterations are
        attributed from the launch layer instead
        (:func:`repro.obs.trace.attribute_program_iteration`).
    topology: optional :class:`repro.comm.topology.Topology` — the
        rank -> node map of a two-level machine.  Wire plans pick up
        link-class annotations, pricing charges the slow tier per
        crossing class, the ``tiered`` coalesced schedule joins the
        candidate set, and every wire/program decision signature gains
        the topology fingerprint (``train.elastic.replan_on_remesh``
        re-prices when it changes).
    """

    def __init__(
        self,
        axis_name: Optional[str] = None,
        params: SystemParams = TPU_V5E,
        registry: Optional[TypeRegistry] = None,
        strategies: Optional[StrategyRegistry] = None,
        policy: Optional[Policy] = None,
        decisions=None,
        telemetry=None,
        tracer=None,
        topology=None,
    ):
        self.axis_name = axis_name
        self.registry = registry or TypeRegistry()
        self.strategies = strategies or default_registry()
        #: optional repro.comm.topology.Topology (rank -> node): wire
        #: plans get link-class annotations, the model prices each delta
        #: class by the slowest tier it crosses, and the ``tiered``
        #: (per-peer-node coalesced) schedule becomes a candidate
        self.model = PerfModel(
            params, decisions=decisions, axis=axis_name, topology=topology
        )
        self.policy = policy or ModelPolicy()
        self.telemetry = telemetry
        self.tracer = tracer
        self.wire_ops = 0  # collectives issued through this communicator
        self.wire_payload_bytes = 0  # exact bytes those collectives carried
        # per-delta-class wire accounting, keyed "<plan fp>/c<class>":
        # issue counts and exact bytes per class, plus the 1-based drain
        # position wait_any() last observed for the class — the counters
        # `python -m repro.fleet stats` renders region completion from
        self.wire_class_ops: Dict[str, int] = {}
        self.wire_class_bytes: Dict[str, int] = {}
        self.wire_class_drains: Dict[str, int] = {}
        # compressed-wire (varlen schedule) accounting: exchanges that
        # rode a length-aware transport, their capacity bytes vs the
        # stream bytes actually issued — the honest ratio stats()
        # publishes as the ``comm.compress.ratio`` gauge
        self.compress_exchanges = 0
        self.compress_capacity_bytes = 0
        self.compress_stream_bytes = 0

    def _tracing_spans(self, *operands) -> bool:
        """Whether the blocking entry points should record spans for
        this call: a tracer is attached, no operand is a jax tracer, and
        execution is eager (the tracer guard — same rule as telemetry)."""
        return (
            self.tracer is not None
            and self.tracer.active
            and not any(isinstance(b, jax.core.Tracer) for b in operands)
        )

    # ------------------------------------------------------------------
    def _axis(self, axis_name: Optional[str]) -> str:
        axis = axis_name or self.axis_name
        if axis is None:
            raise ValueError(
                "no axis_name: bind one at construction or pass it per call"
            )
        return axis

    # ------------------------------------------------------------------
    # commit (MPI_Type_commit)
    # ------------------------------------------------------------------
    def commit(self, dt: Datatype) -> CommittedType:
        return self.registry.commit(dt)

    # ------------------------------------------------------------------
    # strategy selection
    # ------------------------------------------------------------------
    def select(
        self, ct: CommittedType, incount: int = 1, wire: bool = True
    ) -> Strategy:
        """The strategy the active policy picks for this call site."""
        return self.policy.select(self, ct, incount, wire)

    # ------------------------------------------------------------------
    # MPI_Pack / MPI_Unpack (paper §6.2)
    # ------------------------------------------------------------------
    def pack(self, buf: jax.Array, ct: CommittedType, incount: int = 1) -> jax.Array:
        return self.select(ct, incount, wire=False).pack(buf, ct, incount)

    def unpack(
        self, buf: jax.Array, packed: jax.Array, ct: CommittedType, incount: int = 1
    ) -> jax.Array:
        return self.select(ct, incount, wire=False).unpack(buf, packed, ct, incount)

    # ------------------------------------------------------------------
    # point-to-point (requests; paper §6.3)
    # ------------------------------------------------------------------
    def isend(
        self,
        buf: jax.Array,
        ct: CommittedType,
        perm: Sequence[Tuple[int, int]],
        axis_name: Optional[str] = None,
        incount: int = 1,
    ) -> SendRequest:
        """Pack ``ct`` out of ``buf`` and issue the wire transport NOW;
        the returned request carries the (traced) received payload."""
        axis = self._axis(axis_name)
        s = self.select(ct, incount, wire=True)
        seg = s.wire_segment(ct, incount)
        if self.telemetry is not None:
            # price through the chosen strategy directly (no decision
            # recording — a baseline/fixed policy must not grow decision
            # rows just because telemetry is attached)
            est = s.plan(self.model, ct, incount)
            self.telemetry.register(ct.fingerprint, est.total, s.name)
        payload = s.pack(buf, ct, incount)
        wire = lax.ppermute(payload, axis, list(perm))
        self.wire_ops += 1
        self.wire_payload_bytes += seg.nbytes
        return SendRequest(wire, s, ct, incount, segment=seg)

    def irecv(
        self,
        buf: jax.Array,
        ct: CommittedType,
        send_req: SendRequest,
        incount: Optional[int] = None,
    ) -> Request:
        """Bind a destination buffer + receive type to an issued send;
        ``wait()`` materializes the unpack."""
        inc = send_req.incount if incount is None else incount
        return Request(
            thunk=lambda: send_req.strategy.unpack_wire(
                self, buf, send_req.wait(), ct, send_req.send_ct, inc
            )
        )

    def sendrecv(
        self,
        src_buf: jax.Array,
        dst_buf: jax.Array,
        send_ct: CommittedType,
        perm: Sequence[Tuple[int, int]],
        axis_name: Optional[str] = None,
        recv_ct: Optional[CommittedType] = None,
        incount: int = 1,
    ) -> jax.Array:
        """Blocking pack -> permute -> unpack; returns the updated
        ``dst_buf``.  With telemetry attached and eager arguments, the
        whole blocking exchange is timed against the send type's
        fingerprint (tracers skip the probe — a timer inside a trace
        measures tracing, not transfer).  With a tracer attached the
        same eager path additionally records an ``exchange`` span with
        ``pack``/``wire``/``unpack`` children, blocking at each phase
        boundary so the split is a real observation, not attribution."""
        if self._tracing_spans(src_buf):
            return self._sendrecv_traced(
                src_buf, dst_buf, send_ct, perm, axis_name, recv_ct, incount
            )
        if self.telemetry is None or isinstance(src_buf, jax.core.Tracer):
            req = self.isend(src_buf, send_ct, perm, axis_name, incount)
            return self.irecv(dst_buf, recv_ct or send_ct, req).wait()
        t0 = time.perf_counter()
        req = self.isend(src_buf, send_ct, perm, axis_name, incount)
        out = self.irecv(dst_buf, recv_ct or send_ct, req).wait()
        jax.block_until_ready(out)  # async dispatch would under-report
        self.telemetry.observe(send_ct.fingerprint, time.perf_counter() - t0)
        return out

    def _sendrecv_traced(
        self, src_buf, dst_buf, send_ct, perm, axis_name, recv_ct, incount
    ) -> jax.Array:
        """Eager :meth:`sendrecv` with per-phase spans.  Same work as
        isend + irecv, laid out phase by phase so each span boundary can
        block — the paper's pack/wire/unpack decomposition observed
        directly."""
        axis = self._axis(axis_name)
        s = self.select(send_ct, incount, wire=True)
        seg = s.wire_segment(send_ct, incount)
        est = s.plan(self.model, send_ct, incount)
        if self.telemetry is not None:
            self.telemetry.register(send_ct.fingerprint, est.total, s.name)
        t0 = time.perf_counter()
        with self.tracer.span(
            "exchange", fingerprint=send_ct.fingerprint, strategy=s.name,
            wire_bytes=seg.nbytes, incount=incount, pred=est.total,
        ):
            with self.tracer.span("pack", pred=est.t_pack):
                payload = s.pack(src_buf, send_ct, incount)
                jax.block_until_ready(payload)
            with self.tracer.span("wire", pred=est.t_link,
                                  wire_bytes=seg.nbytes):
                wire = lax.ppermute(payload, axis, list(perm))
                jax.block_until_ready(wire)
            self.wire_ops += 1
            self.wire_payload_bytes += seg.nbytes
            with self.tracer.span("unpack", pred=est.t_unpack):
                out = s.unpack_wire(
                    self, dst_buf, wire, recv_ct or send_ct, send_ct, incount
                )
                jax.block_until_ready(out)
        if self.telemetry is not None:
            self.telemetry.observe(
                send_ct.fingerprint, time.perf_counter() - t0
            )
        return out

    # ------------------------------------------------------------------
    # fused neighborhood alltoallv (the paper's MPI_Alltoallv halo path)
    # ------------------------------------------------------------------
    def plan_neighbor(
        self,
        send_cts: Sequence[CommittedType],
        perms: Sequence[Sequence[Tuple[int, int]]],
        strategies: Optional[Sequence[Strategy]] = None,
        uniform_waste_tolerance: float = 0.0,
        schedule_policy: Optional[str] = None,
        probe: Optional[jax.Array] = None,
    ) -> Tuple[Tuple[Strategy, ...], WirePlan]:
        """Select a strategy per transfer and lay the exchange out as an
        exact-byte :class:`WirePlan`.  Call once at setup time (e.g.
        ``make_halo_step``) and hand the result to
        :meth:`ineighbor_alltoallv` to keep the per-call host work at
        dictionary lookups.  The plan is priced through the performance
        model and recorded (``wire_bytes`` included) in the attached
        :class:`~repro.measure.decisions.DecisionCache`, if any.

        ``schedule_policy`` picks how the wire schedule is chosen
        (default: :data:`DEFAULT_SCHEDULE_POLICY` — ``"model"``):

        ``"model"``   :meth:`PerfModel.choose_wire_schedule` trades the
                      grouped schedule's per-class collective launches
                      against the uniform collective's padding bytes on
                      the measured (per-axis) wire tables; the chosen
                      schedule and the prices of the rejected
                      alternatives are recorded in the decision row.
                      The padding it may buy is bounded by the uniform
                      row-equalized layout and byte-gated in CI with a
                      padded allowance (``bench_halo --assert-ragged``).
        ``"exact"``   the byte-exact ladder (``uniform`` only within
                      ``uniform_waste_tolerance`` of zero padding) — the
                      strict wire-bytes regression gates assume this.

        ``probe`` (a *concrete* sample of the exchange buffer) turns on
        length-aware planning: strategy selection may pick a
        ``supports_varlen`` compressor priced at the payload's probed
        stream length, the plan is annotated with per-class
        ``stream_bytes`` (single-transfer classes only — a truncated
        multi-transfer class would cut its later segments), and the
        model-priced schedule choice can then pick the ``varlen``
        transport.  The ratio is taken from the probe, never assumed;
        a tracer probe is ignored.
        """
        if schedule_policy is None:
            schedule_policy = DEFAULT_SCHEDULE_POLICY
        if schedule_policy not in ("exact", "model"):
            raise ValueError(
                f"unknown schedule_policy {schedule_policy!r}; "
                "expected 'exact' or 'model'"
            )
        t_plan0 = (
            time.perf_counter()
            if self.tracer is not None and self.tracer.active else None
        )
        if probe is not None and isinstance(probe, jax.core.Tracer):
            probe = None  # tracers carry no data to probe
        if strategies is not None:
            strats = tuple(strategies)
        elif probe is not None and isinstance(self.policy, ModelPolicy):
            # probed selection: varlen-capable compressors are priced at
            # the payload's actual stream length, so a zero-heavy class
            # can pick rle where capacity pricing never would
            strats = tuple(
                self.strategies.get(
                    self.model.select(
                        ct, 1, allow_bounding=True,
                        registry=self.strategies, probe=probe,
                    ).strategy
                )
                for ct in send_cts
            )
        else:
            strats = tuple(self.select(ct, 1, wire=True) for ct in send_cts)
        segs = [strats[i].wire_segment(send_cts[i]) for i in range(len(strats))]
        plan = plan_wire(
            tuple(s.nbytes for s in segs),
            tuple(tuple(map(tuple, p)) for p in perms),
            fingerprints=tuple(s.fingerprint for s in segs),
            uniform_waste_tolerance=uniform_waste_tolerance,
            topology=self.model.topology,
        )
        if probe is not None and any(
            getattr(s, "supports_varlen", False) for s in strats
        ):
            # attach per-class stream lengths AFTER planning so the
            # plan_wire cache stays payload-independent; only
            # single-transfer classes may truncate
            per_transfer = [
                strats[i].probe_stream_bytes(send_cts[i], 1, probe)
                for i in range(len(strats))
            ]
            per_group = tuple(
                min(per_transfer[grp.transfers[0]], grp.nbytes)
                if len(grp.transfers) == 1
                else grp.nbytes
                for grp in plan.groups
            )
            if sum(per_group) < plan.wire_bytes:
                plan = plan.with_stream_bytes(per_group)
        note = ""
        if schedule_policy == "model":
            plan, costs = self.model.choose_wire_schedule(plan)
            note = " priced[" + " ".join(
                f"{k}={v:.3e}" for k, v in sorted(costs.items())
            ) + "]"
        est = self.model.price_exchange(plan, note=note)
        if self.telemetry is not None:
            # trace-time half of the probe: the prediction is on file
            # before the first observation arrives
            self.telemetry.register(plan.fingerprint, est.total, est.strategy)
            # per-delta-class completion predictions ride next to the
            # whole-exchange key so drift attribution can name the slow
            # direction, not just the slow exchange
            if plan.ngroups > 1:
                completions = self.model.price_class_completions(plan)
                for g, t_c in enumerate(completions):
                    self.telemetry.register(
                        f"{plan.fingerprint}/c{g}", t_c,
                        f"class/{plan.schedule}",
                    )
            if plan.stream_bytes:
                # achieved-ratio ring: predicted = the probed ratio this
                # plan was priced at; each exchange observes the ratio
                # it actually issued so drift can flag decay
                self.telemetry.register(
                    f"{plan.fingerprint}/ratio", plan.stream_ratio,
                    "compress/ratio",
                )
        if t_plan0 is not None:
            self.tracer.add_manual(
                "plan", t_plan0, time.perf_counter() - t_plan0,
                fingerprint=plan.fingerprint, strategy=est.strategy,
                schedule=plan.schedule, wire_bytes=plan.issued_bytes,
                nsegments=len(plan.segments), pred=est.total,
            )
        return strats, plan

    def _issue_wire(
        self, wire: jax.Array, plan: WirePlan, axis: str
    ) -> List[jax.Array]:
        """Put the flat exact-byte wire buffer on the link with the
        plan's schedule; returns one received payload per group (exact
        ``nbytes`` for the ragged schedules, a padded row — harmless,
        segment slicing never reads the tail — for ``uniform``)."""
        if plan.schedule == "grouped":
            rows = []
            for goff, grp in zip(plan.group_offsets, plan.groups):
                payload = lax.dynamic_slice(wire, (goff,), (grp.nbytes,))
                rows.append(lax.ppermute(payload, axis, list(grp.perm)))
            return rows

        if plan.schedule == "varlen":
            # length-aware transport: each class ships only its probed
            # stream length — a strict PREFIX of its capacity slot (the
            # compressed formats interleave run records, so truncation
            # loses nothing the decoder needs).  Native ragged collective
            # with per-class stream sizes when the primitive exists;
            # truncated per-class ppermutes otherwise.  Bit-exact vs the
            # capacity path for payloads within the probed stream budget.
            if len(plan.stream_bytes) != plan.ngroups:
                raise ValueError("varlen schedule on a stream-unannotated plan")
            if compat.has_ragged_all_to_all() and plan.fused:
                ngroups = len(plan.groups)  # pragma: no cover - needs new JAX
                in_off = np.zeros((plan.nranks, plan.nranks), np.int32)
                in_sz = np.zeros_like(in_off)
                out_off = np.zeros_like(in_off)
                recv_sz = np.zeros_like(in_off)
                for r in range(plan.nranks):
                    for d, g in enumerate(plan.send_rows[r]):
                        if g < ngroups:
                            in_off[r, d] = plan.group_offsets[g]
                            in_sz[r, d] = plan.stream_bytes[g]
                            out_off[r, d] = plan.group_offsets[g]
                    for g, s in enumerate(plan.recv_rows[r]):
                        recv_sz[r, s] = plan.stream_bytes[g]
                me = lax.axis_index(axis)
                got = compat.ragged_all_to_all(
                    wire,
                    jnp.zeros_like(wire),
                    jnp.asarray(in_off)[me],
                    jnp.asarray(in_sz)[me],
                    jnp.asarray(out_off)[me],
                    jnp.asarray(recv_sz)[me],
                    axis_name=axis,
                )
                return [
                    lax.dynamic_slice(got, (goff,), (sb,))
                    for goff, sb in zip(plan.group_offsets, plan.stream_bytes)
                ]
            rows = []
            for goff, sb, grp in zip(
                plan.group_offsets, plan.stream_bytes, plan.groups
            ):
                payload = lax.dynamic_slice(wire, (goff,), (sb,))
                rows.append(lax.ppermute(payload, axis, list(grp.perm)))
            return rows

        if plan.schedule == "tiered":
            # two-level transport: fast-tier classes go per-class like
            # grouped; every inter-tier bundle travels as ONE coalesced
            # collective along its representative's permutation (the
            # concatenated payload lands on the right peer NODE), then
            # each non-representative member is forwarded to its true
            # destination rank by an intra-node correction hop — the
            # edge (dst_g0(r), dst_g(r)) stays on-node by the bundle-key
            # invariant and composes two bijections, so it is itself a
            # valid permutation
            if plan.link_classes is None:
                raise ValueError("tiered schedule on an unannotated plan")
            out: List[Optional[jax.Array]] = [None] * len(plan.groups)
            bundled = {g for b in plan.tier_bundles for g in b}
            for g, (goff, grp) in enumerate(
                zip(plan.group_offsets, plan.groups)
            ):
                if g in bundled:
                    continue
                payload = lax.dynamic_slice(wire, (goff,), (grp.nbytes,))
                out[g] = lax.ppermute(payload, axis, list(grp.perm))
            for b in plan.tier_bundles:
                g0 = b[0]
                parts = [
                    lax.dynamic_slice(
                        wire,
                        (plan.group_offsets[g],),
                        (plan.groups[g].nbytes,),
                    )
                    for g in b
                ]
                payload = (
                    jnp.concatenate(parts) if len(parts) > 1 else parts[0]
                )
                got = lax.ppermute(
                    payload, axis, list(plan.groups[g0].perm)
                )
                d0 = dict(plan.groups[g0].perm)
                off = 0
                for g in b:
                    part = lax.dynamic_slice(
                        got, (off,), (plan.groups[g].nbytes,)
                    )
                    off += plan.groups[g].nbytes
                    if g == g0:
                        out[g] = part
                    else:
                        dg = dict(plan.groups[g].perm)
                        corr = [
                            (d0[r], dg[r]) for r in range(plan.nranks)
                        ]
                        out[g] = lax.ppermute(part, axis, corr)
            return out

        if plan.schedule == "uniform":
            parts = []
            for goff, grp in zip(plan.group_offsets, plan.groups):
                row = lax.dynamic_slice(wire, (goff,), (grp.nbytes,))
                if grp.nbytes < plan.seg_bytes:
                    row = jnp.concatenate(
                        [row, jnp.zeros((plan.seg_bytes - grp.nbytes,), jnp.uint8)]
                    )
                parts.append(row)
            stacked = jnp.stack(
                parts + [jnp.zeros((plan.seg_bytes,), jnp.uint8)]
            )
            me = lax.axis_index(axis)
            send = jnp.asarray(np.asarray(plan.send_rows, np.int32))[me]
            sendbuf = jnp.take(stacked, send, axis=0)
            got = lax.all_to_all(sendbuf, axis, split_axis=0, concat_axis=0)
            back = jnp.asarray(np.asarray(plan.recv_rows, np.int32))[me]
            by_group = jnp.take(got, back, axis=0)
            return [by_group[g] for g in range(len(plan.groups))]

        # "ragged": one native ragged collective — exact bytes, one op.
        # Requires lax.ragged_all_to_all (the planner only selects this
        # schedule when repro.compat reports it available).
        # Per-peer metadata semantics: input_offsets/send_sizes and
        # output_offsets are indexed by DESTINATION peer — the chunk this
        # rank sends to peer d is operand[in_off[d]:+in_sz[d]] and lands
        # at out_off[d] in d's OUTPUT buffer.  A group travels under the
        # same global offset on both sides (the flat layout is
        # rank-uniform), so out_off mirrors in_off.  recv_sizes is
        # indexed by SOURCE peer: the bytes arriving from s are the
        # group whose recv_rows entry names s.
        ngroups = len(plan.groups)  # pragma: no cover - needs new JAX
        in_off = np.zeros((plan.nranks, plan.nranks), np.int32)
        in_sz = np.zeros_like(in_off)
        out_off = np.zeros_like(in_off)
        recv_sz = np.zeros_like(in_off)
        for r in range(plan.nranks):
            for d, g in enumerate(plan.send_rows[r]):
                if g < ngroups:
                    in_off[r, d] = plan.group_offsets[g]
                    in_sz[r, d] = plan.groups[g].nbytes
                    out_off[r, d] = plan.group_offsets[g]
            for g, s in enumerate(plan.recv_rows[r]):
                recv_sz[r, s] = plan.groups[g].nbytes
        me = lax.axis_index(axis)
        got = compat.ragged_all_to_all(
            wire,
            jnp.zeros_like(wire),
            jnp.asarray(in_off)[me],
            jnp.asarray(in_sz)[me],
            jnp.asarray(out_off)[me],
            jnp.asarray(recv_sz)[me],
            axis_name=axis,
        )
        return [
            lax.dynamic_slice(got, (goff,), (grp.nbytes,))
            for goff, grp in zip(plan.group_offsets, plan.groups)
        ]

    def _phase_predictions(
        self, send_cts, strategies, plan
    ) -> Tuple[float, float, float]:
        """Model-predicted (pack, wire, unpack) seconds for one fused
        exchange — the ``pred`` attributes the per-phase spans carry, so
        an exported trace joins observed against predicted without the
        model in hand.  Host-side, computed only on traced eager calls."""
        t_pack = t_unpack = 0.0
        for ct, strat in zip(send_cts, strategies):
            est = strat.plan(self.model, ct, 1)
            t_pack += est.t_pack
            t_unpack += est.t_unpack
        try:
            costs = self.model.price_wire_schedules(plan)
            t_wire = float(costs.get(plan.schedule, 0.0))
        except Exception:
            t_wire = self.model.t_link(plan.issued_bytes, 1)
        return t_pack, t_wire, t_unpack

    def ineighbor_alltoallv(
        self,
        buf: jax.Array,
        send_cts: Sequence[CommittedType],
        recv_cts: Sequence[CommittedType],
        perms: Sequence[Sequence[Tuple[int, int]]],
        axis_name: Optional[str] = None,
        plan: Optional[WirePlan] = None,
        strategies: Optional[Sequence[Strategy]] = None,
    ) -> Request:
        """Nonblocking fused neighborhood exchange: transfer ``i`` packs
        ``send_cts[i]`` out of ``buf``, ships it along ``perms[i]``, and
        unpacks into ``recv_cts[i]`` of the same buffer.  Every region
        is packed at its exact wire extent into one flat buffer
        (:func:`repro.kernels.pack.pack_ragged`) laid out by a
        :class:`WirePlan`, and the plan's schedule puts exactly those
        bytes on the wire — no class padding; ``wait()`` materializes
        the unpacks.  Pass a prebuilt ``plan``/``strategies`` pair (from
        :meth:`plan_neighbor`) to skip per-call planning.

        Returns a :class:`NeighborRequest`: one :class:`ClassRequest`
        per delta class, independently completable via ``wait_any()``
        (region-split overlap drains them in completion order), with
        ``wait()`` preserving the monolithic drain-everything
        contract."""
        if not (len(send_cts) == len(recv_cts) == len(perms)):
            raise ValueError("send_cts, recv_cts, perms must align")
        axis = self._axis(axis_name)
        n = len(send_cts)
        if n == 0:
            return Request(value=buf)
        if strategies is None:
            strategies = tuple(self.select(ct, 1, wire=True) for ct in send_cts)
        if plan is None:
            _, plan = self.plan_neighbor(send_cts, perms, strategies=strategies)
        elif len(plan.segments) != n:
            raise ValueError(
                f"wire plan describes {len(plan.segments)} transfers, "
                f"got {n} send types"
            )

        def leaf_packer(strat: Strategy, ct: CommittedType):
            # fused pack+compress: compressors expose their wire encoder
            # separately so the member gather and the encode ride ONE
            # traced expression (no extra materialized pass); plain
            # strategies' wire format IS their packed bytes
            enc = getattr(strat, "encode_wire", None)
            if enc is not None:
                return (lambda b: ops.pack(b, ct), enc)
            return (lambda b: strat.pack(b, ct), None)

        entries = [
            (plan.segments[i].offset, *leaf_packer(strategies[i], send_cts[i]))
            for i in range(n)
        ]
        if self._tracing_spans(buf):
            # eager + traced: the pack and wire phases block at their
            # span boundaries so each is observed separately (the
            # predicted terms come from the member estimates and the
            # model's wire-schedule pricing)
            t_pack, t_wire, _ = self._phase_predictions(
                send_cts, strategies, plan
            )
            with self.tracer.span("pack", pred=t_pack,
                                  nbytes=plan.wire_bytes):
                wire = pack_compress_ragged(buf, entries, plan.wire_bytes)
                jax.block_until_ready(wire)
            with self.tracer.span("wire", pred=t_wire,
                                  wire_bytes=plan.issued_bytes,
                                  schedule=plan.schedule):
                group_rows = self._issue_wire(wire, plan, axis)
                jax.block_until_ready(group_rows)
        else:
            wire = pack_compress_ragged(buf, entries, plan.wire_bytes)
            group_rows = self._issue_wire(wire, plan, axis)
        varlen = plan.schedule == "varlen"
        self.wire_ops += plan.wire_ops
        self.wire_payload_bytes += plan.issued_bytes
        fp = plan.fingerprint
        if varlen:
            # compressed-wire accounting: capacity vs what actually
            # moved, plus the achieved-ratio ring drift audits against
            self.compress_exchanges += 1
            self.compress_capacity_bytes += plan.wire_bytes
            self.compress_stream_bytes += plan.effective_wire_bytes
            if self.telemetry is not None:
                self.telemetry.observe(f"{fp}/ratio", plan.stream_ratio)
        for g, grp in enumerate(plan.groups):
            key = f"{fp}/c{g}"
            self.wire_class_ops[key] = self.wire_class_ops.get(key, 0) + 1
            self.wire_class_bytes[key] = (
                self.wire_class_bytes.get(key, 0)
                + (plan.stream_bytes[g] if varlen else grp.nbytes)
            )

        def leaf_decoder(strat, recv_ct):
            dec = getattr(strat, "decode_wire", None)
            if dec is None:
                return None
            return lambda part: dec(part, recv_ct.size)

        def leaf_unpacker(strat, recv_ct, send_ct):
            # fused decompress+unpack: when the strategy exposes its
            # wire decoder the leaf receives decoded MEMBER bytes and
            # only scatters; otherwise unpack_wire consumes the raw
            # wire payload as before
            if getattr(strat, "decode_wire", None) is not None:
                return lambda dst, member: self.select(
                    recv_ct, 1, wire=False
                ).unpack(dst, member, recv_ct, 1)
            return lambda dst, part: strat.unpack_wire(
                self, dst, part, recv_ct, send_ct, 1
            )

        def class_unpacker(grp: WireGroup, g: int):
            # under the varlen schedule a single-transfer class's
            # payload is the truncated stream — the leaf decodes it at
            # its received length (the decoder derives the run count
            # from the wire length)
            stream = plan.stream_bytes[g] if varlen else grp.nbytes
            leaves = [
                (
                    off,
                    stream if len(grp.transfers) == 1
                    else plan.segments[i].nbytes,
                    leaf_decoder(strategies[i], recv_cts[i]),
                    leaf_unpacker(strategies[i], recv_cts[i], send_cts[i]),
                )
                for i, off in zip(grp.transfers, grp.offsets)
            ]
            return lambda dst, payload: decode_unpack_ragged(
                dst, payload, leaves
            )

        classes = [
            ClassRequest(
                g, group_rows[g], grp.transfers,
                plan.stream_bytes[g] if varlen else grp.nbytes,
                class_unpacker(grp, g),
            )
            for g, grp in enumerate(plan.groups)
        ]
        # drain-side probe: gauge the completion order unconditionally
        # (host-side dict write), and on eager drains observe per-class
        # completion latency against the registered per-class prediction
        # and record a per-class wire span — the same guard discipline
        # as the whole-exchange probes
        eager = not isinstance(buf, jax.core.Tracer)
        observe = eager and self.telemetry is not None
        tracing = eager and self._tracing_spans(buf)
        issued_at = time.perf_counter()

        def on_drain(req: NeighborRequest, cls: ClassRequest) -> None:
            key = f"{fp}/c{cls.index}"
            self.wire_class_drains[key] = len(req.drained)
            if not (observe or tracing):
                return
            jax.block_until_ready(req.buffer)
            dt = time.perf_counter() - issued_at
            if observe:
                self.telemetry.observe(key, dt)
            if tracing:
                self.tracer.add_manual(
                    "wire_class", issued_at, dt, fingerprint=fp,
                    nbytes=cls.nbytes, transfers=len(cls.transfers),
                    drain_order=len(req.drained), **{"class": cls.index},
                )

        return NeighborRequest(buf, classes, plan=plan, on_drain=on_drain)

    def neighbor_alltoallv(
        self,
        buf: jax.Array,
        send_cts: Sequence[CommittedType],
        recv_cts: Sequence[CommittedType],
        perms: Sequence[Sequence[Tuple[int, int]]],
        axis_name: Optional[str] = None,
        plan: Optional[WirePlan] = None,
        strategies: Optional[Sequence[Strategy]] = None,
    ) -> jax.Array:
        """Blocking :meth:`ineighbor_alltoallv`.  With telemetry
        attached and eager arguments the fused exchange is timed against
        the wire plan's fingerprint (the same key the decision cache
        records the schedule choice under).  With a tracer attached the
        eager call records the full span hierarchy: ``exchange`` (the
        decision signature in its attributes) hosting ``plan`` (when
        planned here), ``pack``/``wire`` (inside
        :meth:`ineighbor_alltoallv`) and ``unpack``."""
        if len(send_cts) > 0 and self._tracing_spans(buf):
            return self._neighbor_alltoallv_traced(
                buf, send_cts, recv_cts, perms, axis_name, plan, strategies
            )
        if (
            self.telemetry is None
            or isinstance(buf, jax.core.Tracer)
            or len(send_cts) == 0
        ):
            return self.ineighbor_alltoallv(
                buf, send_cts, recv_cts, perms, axis_name, plan, strategies
            ).wait()
        if plan is None:
            strategies, plan = self.plan_neighbor(
                send_cts, perms, strategies=strategies
            )
        t0 = time.perf_counter()
        out = self.ineighbor_alltoallv(
            buf, send_cts, recv_cts, perms, axis_name, plan, strategies
        ).wait()
        jax.block_until_ready(out)
        self.telemetry.observe(plan.fingerprint, time.perf_counter() - t0)
        return out

    def _neighbor_alltoallv_traced(
        self, buf, send_cts, recv_cts, perms, axis_name, plan, strategies
    ) -> jax.Array:
        """Eager blocking fused exchange under the tracer: one
        ``exchange`` span whose children decompose the call."""
        t0 = time.perf_counter()
        with self.tracer.span("exchange") as sp:
            if strategies is None:
                strategies = tuple(
                    self.select(ct, 1, wire=True) for ct in send_cts
                )
            if plan is None:
                strategies, plan = self.plan_neighbor(
                    send_cts, perms, strategies=strategies
                )
            t_pack, t_wire, t_unpack = self._phase_predictions(
                send_cts, strategies, plan
            )
            if sp is not None:
                sp.attrs.update(
                    fingerprint=plan.fingerprint,
                    strategy=f"wire/{plan.schedule}",
                    schedule=plan.schedule,
                    wire_bytes=plan.issued_bytes,
                    ngroups=len(plan.groups),
                    pred=t_pack + t_wire + t_unpack,
                )
            req = self.ineighbor_alltoallv(
                buf, send_cts, recv_cts, perms, axis_name, plan, strategies
            )
            with self.tracer.span("unpack", pred=t_unpack):
                out = req.wait()
                jax.block_until_ready(out)
        if self.telemetry is not None:
            self.telemetry.observe(
                plan.fingerprint, time.perf_counter() - t0
            )
        return out

    # ------------------------------------------------------------------
    # collectives on datatypes
    # ------------------------------------------------------------------
    def all_gather_packed(
        self,
        buf: jax.Array,
        ct: CommittedType,
        axis_name: Optional[str] = None,
        incount: int = 1,
    ) -> jax.Array:
        """Pack the datatype then all-gather the contiguous payloads.
        Returns (axis_size, size*incount) bytes."""
        axis = self._axis(axis_name)
        packed = self.pack(buf, ct, incount)
        self.wire_ops += 1
        return lax.all_gather(packed, axis)

    def all_to_all_packed(
        self,
        buf: jax.Array,
        cts: Sequence[CommittedType],
        axis_name: Optional[str] = None,
    ) -> jax.Array:
        """MPI_Alltoallv over equal-size segments: pack one datatype per
        peer into a single contiguous buffer, then all_to_all.  All
        ``cts`` must have equal packed size (pad types to match);
        returns (npeers, segment) received bytes."""
        axis = self._axis(axis_name)
        sizes = {ct.size for ct in cts}
        if len(sizes) != 1:
            raise ValueError("all_to_all_packed needs equal-size segments")
        parts = [self.pack(buf, ct) for ct in cts]
        sendbuf = jnp.stack(parts)  # (npeers, seg)
        self.wire_ops += 1
        return lax.all_to_all(sendbuf, axis, split_axis=0, concat_axis=0)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        """Cumulative counters for this communicator.  Every call also
        publishes them into the process metrics registry
        (:func:`repro.obs.metrics.publish_comm_stats`), so
        ``default_metrics().snapshot()`` — and the ``metrics.json`` the
        production ``save()`` persists — always reflects the latest
        totals."""
        out = {
            "committed_types": len(self.registry),
            "commit_hits": self.registry.hits,
            "model_lookups": self.model.lookups,
            "model_hits": self.model.hits,
            "strategies": len(self.strategies),
            "wire_ops": self.wire_ops,
            "wire_payload_bytes": self.wire_payload_bytes,
            "wire_classes": len(self.wire_class_bytes),
            "wire_class_ops": dict(self.wire_class_ops),
            "wire_class_bytes": dict(self.wire_class_bytes),
            "wire_class_drains": dict(self.wire_class_drains),
            "compress_exchanges": self.compress_exchanges,
            "compress_capacity_bytes": self.compress_capacity_bytes,
            "compress_stream_bytes": self.compress_stream_bytes,
            "compress_ratio": (
                self.compress_stream_bytes / self.compress_capacity_bytes
                if self.compress_capacity_bytes
                else 1.0
            ),
            "telemetry_keys": (
                len(self.telemetry) if self.telemetry is not None else 0
            ),
        }
        from repro.obs.metrics import publish_comm_stats

        publish_comm_stats(out, self.telemetry)
        return out


def as_communicator(obj) -> Communicator:
    """Accept a Communicator or anything wrapping one (the Interposer
    shim exposes ``.comm``)."""
    if isinstance(obj, Communicator):
        return obj
    comm = getattr(obj, "comm", None)
    if isinstance(comm, Communicator):
        return comm
    raise TypeError(f"expected a Communicator (or shim), got {type(obj)!r}")
