"""The interposer: datatype-aware communication over jax.lax collectives
(paper §4, adapted per DESIGN.md §2).

TEMPI sits between the application and the system MPI via dynamic-linker
symbol interposition.  JAX has no symbol table to interpose, so the seam
is the *collective call site*: every framework transfer of structured
non-contiguous data goes through an :class:`Interposer`, which

  1. commits the datatype once (cached canonicalization, §3),
  2. consults the performance model for a strategy (§5),
  3. packs with the selected Pallas kernel,
  4. invokes the *underlying* collective (``lax.ppermute`` /
     ``all_to_all`` / ``all_gather`` — the "system MPI" here is XLA's
     collective runtime, which the interposer, like TEMPI, cannot
     modify),
  5. unpacks on the receiving side.

Switching ``mode`` between ``baseline`` (per-block copies, emulating the
naive CUDA-aware MPI datatype handling every implementation shares) and
``tempi`` (canonical kernels + model selection) requires **zero
application change** — the transparency property of the paper.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.commit import CommittedType, TypeRegistry
from repro.core.datatypes import Datatype
from repro.core.strided_block import StridedBlock
from repro.kernels.ops import byte_view, pack, pack_block, unbyte_view, unpack
from repro.comm.perfmodel import PerfModel, StrategyEstimate, SystemParams, TPU_V5E

__all__ = ["Interposer", "Mode"]

Mode = str  # "baseline" | "tempi" | "rows" | "dma" | "xla" | "ref"

#: baseline per-block copy emulation explodes HLO size past this many
#: blocks; beyond it the baseline degrades to the gather path (still a
#: fair stand-in: the real baselines issue that many cudaMemcpyAsyncs)
_BASELINE_BLOCK_CAP = 1024


class Interposer:
    """Datatype-aware communication layer.

    Parameters
    ----------
    mode: "tempi" (canonical kernels + model selection), "baseline"
        (per-block copies), or a forced strategy name for experiments.
    params: system parameter table for the performance model.
    """

    def __init__(
        self,
        mode: Mode = "tempi",
        params: SystemParams = TPU_V5E,
        registry: Optional[TypeRegistry] = None,
    ):
        if mode not in ("baseline", "tempi", "rows", "dma", "xla", "ref"):
            raise ValueError(f"unknown mode {mode!r}")
        self.mode = mode
        self.registry = registry or TypeRegistry()
        self.model = PerfModel(params)

    # ------------------------------------------------------------------
    # commit (MPI_Type_commit)
    # ------------------------------------------------------------------
    def commit(self, dt: Datatype) -> CommittedType:
        return self.registry.commit(dt)

    # ------------------------------------------------------------------
    # strategy selection
    # ------------------------------------------------------------------
    def _strategy(self, ct: CommittedType, incount: int, wire: bool) -> str:
        if self.mode == "baseline":
            if ct.block is not None and ct.block.num_blocks * incount > _BASELINE_BLOCK_CAP:
                return "ref"
            return "xla"
        if self.mode != "tempi":
            return self.mode
        est = self.model.select(ct, incount, allow_bounding=wire)
        return est.strategy

    # ------------------------------------------------------------------
    # MPI_Pack / MPI_Unpack (paper §6.2)
    # ------------------------------------------------------------------
    def pack(self, buf: jax.Array, ct: CommittedType, incount: int = 1) -> jax.Array:
        strat = self._strategy(ct, incount, wire=False)
        return pack(buf, ct, incount=incount, strategy=strat)

    def unpack(
        self, buf: jax.Array, packed: jax.Array, ct: CommittedType, incount: int = 1
    ) -> jax.Array:
        strat = self._strategy(ct, incount, wire=False)
        return unpack(buf, packed, ct, incount=incount, strategy=strat)

    # ------------------------------------------------------------------
    # MPI_Send/Recv analogue: point-to-point permute on a datatype
    # (paper §6.3).  Must be called inside shard_map with `axis_name`.
    # ------------------------------------------------------------------
    def sendrecv(
        self,
        src_buf: jax.Array,
        dst_buf: jax.Array,
        send_ct: CommittedType,
        perm: Sequence[Tuple[int, int]],
        axis_name: str,
        recv_ct: Optional[CommittedType] = None,
        incount: int = 1,
    ) -> jax.Array:
        """Pack ``send_ct`` out of ``src_buf``, permute across ``perm``,
        unpack into ``dst_buf`` at ``recv_ct`` (default: same type).

        Returns the updated ``dst_buf``.
        """
        recv_ct = recv_ct or send_ct
        strat = self._strategy(send_ct, incount, wire=True)
        if strat == "bounding" and send_ct.block is not None:
            return self._sendrecv_bounding(
                src_buf, dst_buf, send_ct, recv_ct, perm, axis_name, incount
            )
        packed = pack(src_buf, send_ct, incount=incount, strategy=strat)
        wire = lax.ppermute(packed, axis_name, perm)
        rstrat = self._strategy(recv_ct, incount, wire=False)
        return unpack(dst_buf, wire, recv_ct, incount=incount, strategy=rstrat)

    def _sendrecv_bounding(
        self, src_buf, dst_buf, send_ct, recv_ct, perm, axis_name, incount
    ):
        """"one-shot" analogue: ship the contiguous bounding window, no
        sender-side pack; the receiver extracts the member bytes."""
        sb = send_ct.block
        ext = sb.extent + (incount - 1) * send_ct.extent
        wire = lax.dynamic_slice(byte_view(src_buf), (sb.start,), (ext,))
        recv = lax.ppermute(wire, axis_name, perm)
        # extract member bytes from the received window: same geometry,
        # rebased to start 0
        rb = StridedBlock(0, sb.counts, sb.strides)
        if incount > 1:
            parts = [
                pack_block(
                    lax.dynamic_slice(recv, (r * send_ct.extent,), (sb.extent,)),
                    rb,
                )
                for r in range(incount)
            ]
            packed = jnp.concatenate(parts)
        else:
            packed = pack_block(recv, rb)
        rstrat = self._strategy(recv_ct, incount, wire=False)
        return unpack(dst_buf, packed, recv_ct, incount=incount, strategy=rstrat)

    # ------------------------------------------------------------------
    # collectives on datatypes
    # ------------------------------------------------------------------
    def all_gather_packed(
        self, buf: jax.Array, ct: CommittedType, axis_name: str, incount: int = 1
    ) -> jax.Array:
        """Pack the datatype then all-gather the contiguous payloads.
        Returns (axis_size, size*incount) bytes."""
        strat = self._strategy(ct, incount, wire=False)
        packed = pack(buf, ct, incount=incount, strategy=strat)
        return lax.all_gather(packed, axis_name)

    def all_to_all_packed(
        self,
        buf: jax.Array,
        cts: Sequence[CommittedType],
        axis_name: str,
    ) -> jax.Array:
        """MPI_Alltoallv analogue (the paper's halo-exchange transport):
        pack one datatype per peer into a single contiguous buffer, then
        all_to_all the equal-size segments.

        All ``cts`` must have equal packed size (pad types to match);
        returns (npeers, segment) received bytes.
        """
        sizes = {ct.size for ct in cts}
        if len(sizes) != 1:
            raise ValueError("all_to_all_packed needs equal-size segments")
        parts = [
            pack(buf, ct, strategy=self._strategy(ct, 1, wire=False)) for ct in cts
        ]
        sendbuf = jnp.stack(parts)  # (npeers, seg)
        return lax.all_to_all(sendbuf, axis_name, split_axis=0, concat_axis=0)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return {
            "committed_types": len(self.registry),
            "commit_hits": self.registry.hits,
            "model_lookups": self.model.lookups,
            "model_hits": self.model.hits,
        }
