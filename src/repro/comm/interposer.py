"""DEPRECATED shim: the string-mode ``Interposer`` over the Communicator.

The interposer seam (paper §4) now lives in :mod:`repro.comm.api`: a
:class:`~repro.comm.api.Communicator` with a pluggable strategy registry,
request-based nonblocking transfers, and a fused neighborhood
alltoallv.  This class remains so existing call sites keep working:
every method delegates to an underlying Communicator (exposed as
``.comm``), and the legacy ``mode`` strings map onto
:class:`~repro.comm.api.Policy` objects via
:func:`~repro.comm.api.policy_for_mode`.

Migration (see docs/comm_api.md):

    Interposer(mode="tempi")     -> Communicator()
    Interposer(mode="baseline")  -> Communicator(policy=BaselinePolicy())
    Interposer(mode=<strategy>)  -> Communicator(policy=FixedPolicy(...))
    ip.sendrecv(...)             -> comm.sendrecv(...) (or isend/irecv)
    26x ip.sendrecv halo loop    -> comm.neighbor_alltoallv(...)
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax

from repro.core.commit import CommittedType, TypeRegistry
from repro.core.datatypes import Datatype
from repro.comm.api import Communicator, policy_for_mode
from repro.comm.perfmodel import SystemParams, TPU_V5E

__all__ = ["Interposer", "Mode"]

Mode = str  # legacy alias; see repro.comm.api.MODES for the valid names


class Interposer:
    """Deprecated facade over :class:`~repro.comm.api.Communicator`.

    Parameters
    ----------
    mode: "tempi" (canonical kernels + model selection), "baseline"
        (per-block copies), or a forced strategy name for experiments.
    params: system parameter table for the performance model.
    """

    def __init__(
        self,
        mode: Mode = "tempi",
        params: SystemParams = TPU_V5E,
        registry: Optional[TypeRegistry] = None,
    ):
        self.mode = mode
        self.comm = Communicator(
            params=params, registry=registry, policy=policy_for_mode(mode)
        )

    # -- state passthroughs -------------------------------------------
    @property
    def registry(self) -> TypeRegistry:
        return self.comm.registry

    @property
    def model(self):
        return self.comm.model

    # ------------------------------------------------------------------
    def commit(self, dt: Datatype) -> CommittedType:
        return self.comm.commit(dt)

    def _strategy(self, ct: CommittedType, incount: int, wire: bool) -> str:
        return self.comm.select(ct, incount, wire=wire).name

    def pack(self, buf: jax.Array, ct: CommittedType, incount: int = 1) -> jax.Array:
        return self.comm.pack(buf, ct, incount)

    def unpack(
        self, buf: jax.Array, packed: jax.Array, ct: CommittedType, incount: int = 1
    ) -> jax.Array:
        return self.comm.unpack(buf, packed, ct, incount)

    def sendrecv(
        self,
        src_buf: jax.Array,
        dst_buf: jax.Array,
        send_ct: CommittedType,
        perm: Sequence[Tuple[int, int]],
        axis_name: str,
        recv_ct: Optional[CommittedType] = None,
        incount: int = 1,
    ) -> jax.Array:
        return self.comm.sendrecv(
            src_buf, dst_buf, send_ct, perm, axis_name, recv_ct, incount
        )

    def all_gather_packed(
        self, buf: jax.Array, ct: CommittedType, axis_name: str, incount: int = 1
    ) -> jax.Array:
        return self.comm.all_gather_packed(buf, ct, axis_name, incount)

    def all_to_all_packed(
        self,
        buf: jax.Array,
        cts: Sequence[CommittedType],
        axis_name: str,
    ) -> jax.Array:
        return self.comm.all_to_all_packed(buf, cts, axis_name)

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        return self.comm.stats()
