"""WirePlan: exact-byte wire layout for fused neighborhood exchanges.

TEMPI's canonical representation tells the library exactly how many
bytes a committed datatype really occupies once packed; this module
turns that knowledge into the wire layout itself.  The previous fused
``neighbor_alltoallv`` padded every delta-class segment to the largest
class (≈1.6x over-transfer on the 2x2x2 halo); a :class:`WirePlan`
instead lays every transfer out at its *exact* packed extent — a flat
per-destination buffer of :class:`~repro.core.commit.WireSegment`
descriptors, no class padding, no row equalization — and then picks the
cheapest wire **schedule** that can carry that ragged layout:

``ragged``
    one ``lax.ragged_all_to_all`` collective (requires a JAX that has
    the primitive — see :func:`repro.compat.has_ragged_all_to_all`).
    Exact bytes, one wire op.
``uniform``
    one plain ``all_to_all`` over destination-ordered rows.  A uniform
    collective *must* equalize rows, so this schedule is only chosen
    when the padding it would add stays within
    ``uniform_waste_tolerance`` (default 0: byte-exact or not at all).
``grouped``
    one ``ppermute`` per delta class, each carrying exactly that class's
    concatenated segments.  Always available, always byte-exact; this is
    also the large-grid fallback (ROADMAP item 2): past
    ``grouped_fallback_rank_factor`` x the class count, most fused rows
    would be zero, so the plan degrades to per-class sends regardless of
    primitive availability.
``varlen``
    the length-aware grouped schedule for compressed payloads: each
    delta class's send is truncated at its *stream length*
    (:attr:`WirePlan.stream_bytes`, probed from the actual payload by a
    ``supports_varlen`` strategy such as
    :class:`~repro.comm.compress.RleWire`), so the compressed bytes —
    not the capacity — are the bytes on the wire.  Rides one truncated
    ``ppermute`` per class, or a single native ``ragged_all_to_all``
    with per-class stream sizes when the primitive is available
    (:func:`repro.compat.has_ragged_all_to_all`).  Bit-exact vs the
    capacity path: the stream is a strict prefix of the capacity wire
    and the decoder derives the run count from the wire length.
``tiered``
    the hierarchy-aware grouped schedule.  With a
    :class:`~repro.comm.topology.Topology` annotation, every delta class
    whose edges stay on one node still rides its own ``ppermute``, but
    classes crossing the inter-node tier are **coalesced per peer
    node**: each tier bundle (classes sharing a destination-node vector
    — see :func:`~repro.comm.topology.classify_and_coalesce`) travels as
    ONE slow-tier collective carrying the concatenated member payloads
    along the representative member's permutation, then each
    non-representative member is forwarded to its true destination rank
    by an *intra-node* correction ``ppermute``.  Fewer slow-tier
    messages, bought with ``correction_bytes`` of extra fast-tier
    traffic — the trade ``PerfModel.price_wire_schedules`` prices; the
    exact ladder never picks it on its own.

The schedule choice is host-side and cached; the payload accounting
(:attr:`WirePlan.wire_bytes` = the sum of per-peer packed extents, and
:attr:`WirePlan.issued_bytes` = what the chosen schedule actually puts
on the wire) is what ``PerfModel.price_exchange`` prices and the
``DecisionCache`` records.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.commit import WireSegment
from repro.comm.topology import Topology, classify_and_coalesce

__all__ = [
    "WireGroup",
    "WirePlan",
    "plan_wire",
    "reschedule",
    "GROUPED_FALLBACK_RANK_FACTOR",
    "collective_payload_bytes",
    "WIRE_COLLECTIVES",
    "WIRE_SCHEDULES",
]

#: past ``factor * ngroups`` ranks the fused single-collective layout is
#: mostly zero rows (non-neighbor peers); the plan then always takes the
#: grouped per-class schedule (ROADMAP: grid-size threshold fallback)
GROUPED_FALLBACK_RANK_FACTOR = 4.0

#: primitive names that put payload on the wire in our schedules
WIRE_COLLECTIVES = ("ppermute", "all_to_all", "ragged_all_to_all")

#: every wire schedule a plan can carry ("tiered" needs a topology
#: annotation, "varlen" a stream-length annotation; the exact ladder
#: only ever picks the first three)
WIRE_SCHEDULES = ("ragged", "uniform", "grouped", "tiered", "varlen")


@dataclass(frozen=True)
class WireGroup:
    """One delta class of a rank-uniform exchange: the transfers whose
    destination is the same rank *for every rank* share one wire payload
    of exactly ``nbytes`` (the sum of their segment extents)."""

    transfers: Tuple[int, ...]        # transfer ids riding this class
    offsets: Tuple[int, ...]          # group-local byte offset per transfer
    nbytes: int                       # exact payload — no padding
    perm: Tuple[Tuple[int, int], ...]  # the class's (src, dst) edges


@dataclass(frozen=True)
class WirePlan:
    """Host-computed exact-byte layout of a fused neighborhood exchange.

    ``segments[i]`` is transfer ``i``'s :class:`WireSegment` with its
    *global* offset in the flat send buffer; ``groups[g]`` carries the
    group-local offsets the receive side unpacks at.  ``wire_bytes`` is
    the ragged optimum (sum of segment extents); ``issued_bytes`` is
    what the chosen schedule actually transfers (equal to
    ``wire_bytes`` for the exact schedules, ``nranks * seg_bytes`` for
    the padded uniform collective).
    """

    nranks: int
    groups: Tuple[WireGroup, ...]
    segments: Tuple[WireSegment, ...]
    group_offsets: Tuple[int, ...]
    schedule: str                # "ragged" | "uniform" | "grouped" | "tiered"
    fused: bool                       # group -> peer injective per rank
    wire_bytes: int                   # sum of exact segment extents
    seg_bytes: int                    # uniform row size (largest group)
    send_rows: Tuple[Tuple[int, ...], ...]   # [rank][dest] -> group|G
    recv_rows: Tuple[Tuple[int, ...], ...]   # [rank][group] -> source
    # two-level hierarchy annotation (None/() when planned flat): the
    # per-class link class, the inter-tier coalescing bundles, and the
    # topology that derived them (hashable; keys the plan fingerprint)
    link_classes: Optional[Tuple[str, ...]] = None
    tier_bundles: Tuple[Tuple[int, ...], ...] = ()
    topology: Optional[Topology] = None
    # per-class *effective* (stream) lengths for the length-aware
    # "varlen" schedule — () when no payload probe annotated the plan.
    # stream_bytes[g] <= groups[g].nbytes always; a class whose payload
    # cannot truncate (multi-transfer group, stored-mode stream, or a
    # strategy without varlen support) carries its full capacity here.
    stream_bytes: Tuple[int, ...] = ()

    @property
    def ngroups(self) -> int:
        return len(self.groups)

    @property
    def wire_ops(self) -> int:
        """Collectives the schedule issues.  ``tiered`` issues one
        ``ppermute`` per intra class, one per tier bundle, and one
        correction hop per non-representative bundle member — which
        totals ``ngroups`` exactly like ``grouped``; the win is *which
        tier* the ops cross, not how many there are."""
        if self.schedule in ("ragged", "uniform"):
            return 1
        return len(self.groups)

    @property
    def correction_bytes(self) -> int:
        """Extra fast-tier bytes the ``tiered`` schedule re-transmits:
        every non-representative bundle member crosses the wire twice
        (once inside the coalesced slow-tier message, once on the
        intra-node correction hop)."""
        return sum(
            self.groups[g].nbytes for b in self.tier_bundles for g in b[1:]
        )

    @property
    def inter_messages(self) -> int:
        """Slow-tier messages per rank per exchange: what the 3072-rank
        regime is bought down by.  Each inter-crossing class is its own
        slow message under ``grouped`` (and still crosses to its own
        peer inside the fused collectives); ``tiered`` sends one per
        peer-node bundle.  0 when the plan was laid out flat."""
        if not self.link_classes:
            return 0
        n_inter = sum(1 for c in self.link_classes if c == "inter")
        if self.schedule == "tiered":
            return len(self.tier_bundles)
        return n_inter

    @property
    def effective_wire_bytes(self) -> int:
        """Sum of per-class stream lengths — what a length-aware
        transport would actually move.  Equals ``wire_bytes`` (the
        capacity) when the plan carries no stream annotation."""
        if not self.stream_bytes:
            return self.wire_bytes
        return sum(self.stream_bytes)

    @property
    def stream_ratio(self) -> float:
        """``effective_wire_bytes / wire_bytes`` — the achieved
        compression ratio of the probed payload (1.0 unannotated)."""
        if not self.wire_bytes:
            return 1.0
        return self.effective_wire_bytes / self.wire_bytes

    @property
    def issued_bytes(self) -> int:
        """Bytes the chosen schedule actually puts on the wire."""
        if self.schedule == "uniform":
            return self.nranks * self.seg_bytes
        if self.schedule == "tiered":
            return self.wire_bytes + self.correction_bytes
        if self.schedule == "varlen":
            return self.effective_wire_bytes
        return self.wire_bytes

    @property
    def padding_bytes(self) -> int:
        return max(0, self.issued_bytes - self.wire_bytes)

    def with_stream_bytes(self, stream: Tuple[int, ...]) -> "WirePlan":
        """Annotate the plan with per-class stream lengths (probed from
        a concrete payload) — attached *after* planning so the
        :func:`plan_wire` cache stays payload-independent.  Lengths are
        clamped to each class's capacity; a short tuple raises."""
        if len(stream) != self.ngroups:
            raise ValueError(
                f"stream_bytes needs one length per delta class "
                f"({self.ngroups}); got {len(stream)}"
            )
        clamped = tuple(
            min(int(s), g.nbytes) for s, g in zip(stream, self.groups)
        )
        return dataclasses.replace(self, stream_bytes=clamped)

    @property
    def class_cum_bytes(self) -> Tuple[int, ...]:
        """Cumulative wire bytes through each delta class, in issue
        order.  Under the grouped schedule the k-th per-class collective
        cannot complete before every earlier class's bytes have been on
        the wire, so ``class_cum_bytes[k]`` is the byte term of class
        ``k``'s completion time (``PerfModel.price_class_completions``);
        fused schedules complete all classes together at
        ``issued_bytes``."""
        out, cum = [], 0
        for grp in self.groups:
            cum += grp.nbytes
            out.append(cum)
        return tuple(out)

    @property
    def fingerprint(self) -> str:
        """Stable content hash of the layout (keys DecisionCache rows
        for exchange pricing, as ``CommittedType.fingerprint`` keys
        per-type selections)."""
        fp = self.__dict__.get("_fingerprint")
        if fp is None:
            key = (
                "wireplan.v1",
                self.nranks,
                self.schedule,
                tuple((s.fingerprint, s.offset, s.nbytes) for s in self.segments),
                tuple(g.perm for g in self.groups),
            )
            if self.topology is not None:
                # appended only when a topology annotated the plan, so
                # every pre-hierarchy fingerprint (and its pinned
                # decision rows) survives unchanged
                key = key + (self.topology.fingerprint,)
            if self.stream_bytes:
                # likewise: stream lengths key the fingerprint only on
                # probe-annotated plans, so a pinned varlen row is
                # specific to the payload shape it was probed on
                key = key + (self.stream_bytes,)
            fp = hashlib.sha256(repr(key).encode()).hexdigest()[:16]
            object.__setattr__(self, "_fingerprint", fp)
        return fp


def _choose_schedule(
    nranks: int,
    ngroups: int,
    fused: bool,
    wire_bytes: int,
    uniform_bytes: int,
    uniform_waste_tolerance: float,
    native: bool,
    rank_factor: float,
) -> str:
    """The fallback ladder described in the module docstring."""
    if ngroups and nranks > rank_factor * ngroups:
        # grid-size threshold: most fused rows would be zero (or, for
        # the native ragged op, dead per-peer metadata) — per-class
        # sends win outright on large grids
        return "grouped"
    if native and fused:
        return "ragged"
    if fused and wire_bytes > 0:
        waste = (uniform_bytes - wire_bytes) / wire_bytes
        if waste <= uniform_waste_tolerance:
            return "uniform"
    return "grouped"


@functools.lru_cache(maxsize=256)
def plan_wire(
    sizes: Tuple[int, ...],
    perms: Tuple[Tuple[Tuple[int, int], ...], ...],
    fingerprints: Optional[Tuple[str, ...]] = None,
    uniform_waste_tolerance: float = 0.0,
    native: Optional[bool] = None,
    rank_factor: float = GROUPED_FALLBACK_RANK_FACTOR,
    topology: Optional[Topology] = None,
) -> WirePlan:
    """Lay ``len(sizes)`` transfers (one full permutation each) out as an
    exact-byte wire plan.  ``sizes[i]`` is transfer ``i``'s wire-segment
    extent (the selected strategy's exact wire bytes); ``fingerprints``
    optionally carries the committed types' content hashes into the
    segment descriptors.

    ``topology`` (hashable, rides the plan cache) annotates the plan
    with per-class link classes and inter-tier coalescing bundles; it is
    ignored — the plan stays flat — when its rank count does not match
    the permutations' (e.g. a single-host test mesh planned against a
    production topology)."""
    if native is None:
        from repro.compat import has_ragged_all_to_all

        native = has_ragged_all_to_all()
    n = len(perms)
    if len(sizes) != n:
        raise ValueError("sizes and perms must align")
    ranks = sorted({s for p in perms for s, _ in p})
    nranks = len(ranks)
    if ranks != list(range(nranks)):
        raise ValueError("perms must cover ranks 0..R-1")
    dst: List[Dict[int, int]] = []
    src: List[Dict[int, int]] = []
    for i, p in enumerate(perms):
        d = dict(p)
        if sorted(d) != ranks or sorted(d.values()) != ranks:
            raise ValueError(f"perm {i} is not a permutation of the ranks")
        dst.append(d)
        src.append({v: k for k, v in d.items()})

    # group transfers by their full destination vector (rank-uniform)
    key_to_group: Dict[Tuple[int, ...], int] = {}
    members_per_group: List[List[int]] = []
    for i in range(n):
        key = tuple(dst[i][r] for r in range(nranks))
        g = key_to_group.setdefault(key, len(members_per_group))
        if g == len(members_per_group):
            members_per_group.append([])
        members_per_group[g].append(i)
    ngroups = len(members_per_group)

    fps = fingerprints or ("",) * n
    groups: List[WireGroup] = []
    group_offsets: List[int] = []
    seg_list: List[Optional[WireSegment]] = [None] * n
    flat = 0
    for members in members_per_group:
        offs, acc = [], 0
        for i in members:
            offs.append(acc)
            seg_list[i] = WireSegment(
                fingerprint=fps[i], offset=flat + acc, nbytes=sizes[i]
            )
            acc += sizes[i]
        groups.append(
            WireGroup(
                transfers=tuple(members),
                offsets=tuple(offs),
                nbytes=acc,
                perm=tuple((r, dst[members[0]][r]) for r in range(nranks)),
            )
        )
        group_offsets.append(flat)
        flat += acc
    seg_bytes = max((g.nbytes for g in groups), default=0)

    # per-rank uniform-collective tables (destination-ordered rows)
    send_rows, recv_rows = [], []
    fused = ngroups <= nranks
    for r in range(nranks):
        dests = [dst[g.transfers[0]][r] for g in groups]
        if len(set(dests)) != ngroups:
            fused = False
        row = [ngroups] * nranks  # ngroups = the zero dummy row
        for g, d in enumerate(dests):
            row[d] = g
        send_rows.append(tuple(row))
        recv_rows.append(tuple(src[g.transfers[0]][r] for g in groups))

    schedule = _choose_schedule(
        nranks,
        ngroups,
        fused,
        flat,
        nranks * seg_bytes,
        uniform_waste_tolerance,
        native,
        rank_factor,
    )
    link_classes: Optional[Tuple[str, ...]] = None
    tier_bundles: Tuple[Tuple[int, ...], ...] = ()
    if topology is not None and topology.nranks == nranks:
        link_classes, tier_bundles = classify_and_coalesce(
            tuple(
                tuple(dst[g.transfers[0]][r] for r in range(nranks))
                for g in groups
            ),
            topology,
        )
    else:
        topology = None
    return WirePlan(
        nranks=nranks,
        groups=tuple(groups),
        segments=tuple(seg_list),
        group_offsets=tuple(group_offsets),
        schedule=schedule,
        fused=fused,
        wire_bytes=flat,
        seg_bytes=seg_bytes,
        send_rows=tuple(send_rows),
        recv_rows=tuple(recv_rows),
        link_classes=link_classes,
        tier_bundles=tier_bundles,
        topology=topology,
    )


def reschedule(plan: WirePlan, schedule: str) -> WirePlan:
    """The same layout under a different wire schedule.

    The segment layout, groups, and byte accounting are schedule-
    independent; only the transport differs — so a model-priced schedule
    choice (``PerfModel.choose_wire_schedule``) swaps the schedule
    without replanning.  ``ragged``/``uniform`` require a fused plan
    (group -> peer injective per rank); the returned plan's fingerprint
    and ``issued_bytes`` reflect the new schedule.
    """
    if schedule == plan.schedule:
        return plan
    if schedule not in WIRE_SCHEDULES:
        raise ValueError(f"unknown wire schedule {schedule!r}")
    if schedule in ("ragged", "uniform") and not plan.fused:
        raise ValueError(
            f"schedule {schedule!r} needs a fused plan (group->peer "
            "injective per rank)"
        )
    if schedule == "tiered" and plan.link_classes is None:
        raise ValueError(
            "schedule 'tiered' needs a topology-annotated plan "
            "(plan_wire(..., topology=...))"
        )
    if schedule == "varlen" and len(plan.stream_bytes) != plan.ngroups:
        raise ValueError(
            "schedule 'varlen' needs a stream-annotated plan "
            "(WirePlan.with_stream_bytes, one probed length per class)"
        )
    return dataclasses.replace(plan, schedule=schedule)


# ===========================================================================
# payload accounting over traced programs (tests + CI regression gate)
# ===========================================================================

def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    return math.prod(shape) * dtype.itemsize if shape else dtype.itemsize


def _walk_jaxpr(jaxpr, counts: Dict[str, int]) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in WIRE_COLLECTIVES:
            # ragged_all_to_all's invars also carry the destination
            # buffer and four offset/size vectors — only the first
            # operand is wire payload; the simple collectives put every
            # operand on the wire
            invars = eqn.invars[:1] if name == "ragged_all_to_all" else eqn.invars
            counts[name] = counts.get(name, 0) + sum(
                _aval_bytes(v.aval) for v in invars
                if hasattr(v, "aval")
            )
            counts["ops"] = counts.get("ops", 0) + 1
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                _walk_jaxpr(sub, counts)


def _sub_jaxprs(val):
    import jax.core as jcore

    if isinstance(val, jcore.Jaxpr):
        yield val
    elif isinstance(val, jcore.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _sub_jaxprs(v)


def collective_payload_bytes(fn, *args) -> Dict[str, int]:
    """Trace ``fn(*args)`` and total the operand bytes of every wire
    collective in the jaxpr (recursing through pjit/shard_map bodies).

    Returns ``{"ops": <collective count>, "total": <bytes>,
    <primitive>: <bytes>, ...}`` — the ground truth the wire-bytes
    regression tests compare against ``WirePlan.issued_bytes``.
    """
    import jax

    jaxpr = jax.make_jaxpr(fn)(*args)
    counts: Dict[str, int] = {"ops": 0}
    _walk_jaxpr(jaxpr.jaxpr, counts)
    counts["total"] = sum(v for k, v in counts.items() if k != "ops")
    return counts
