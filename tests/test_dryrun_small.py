"""Integration: the dry-run machinery on a small emulated mesh.

Compiles one cell per family kind (dense train / moe train / ssm decode /
swa long-decode / encdec prefill) on an 8-device (2x2x2) multi-pod mesh
in a subprocess — the same code path as the 512-device production runs,
shrunk for CI.
"""

import pytest

from tests._subproc import run_with_devices

CODE = r"""
import jax
from repro.configs.base import ShapeConfig
from repro.configs.registry import smoke_config
from repro.distributed.sharding import DEFAULT_RULES, use_rules
from repro.launch.mesh import make_test_mesh
from repro.launch.dryrun import build_cell
from repro.roofline.hlo_cost import parse_hlo_cost

mesh = make_test_mesh(data=2, model=2, pod=2)

cells = [
    ("qwen2-0.5b", ShapeConfig("train", 64, 8, "train")),
    ("mixtral-8x22b", ShapeConfig("train", 64, 8, "train")),
    ("rwkv6-7b", ShapeConfig("decode", 64, 8, "decode")),
    ("h2o-danube-1.8b", ShapeConfig("long", 128, 8, "long-decode")),
    ("seamless-m4t-large-v2", ShapeConfig("prefill", 64, 8, "prefill")),
    ("zamba2-2.7b", ShapeConfig("decode", 64, 8, "decode")),
]

for arch, shape in cells:
    cfg = smoke_config(arch)
    with use_rules(mesh, DEFAULT_RULES):
        fn, args, shardings, donate = build_cell(cfg, shape, mesh, DEFAULT_RULES)
        jfn = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = parse_hlo_cost(compiled.as_text())
        assert cost.flops > 0, arch
        print(f"{arch}/{shape.kind}: OK flops={cost.flops:.2e} "
              f"coll={cost.coll_bytes:.2e}")
print("DRYRUN_SMALL_OK")
"""


@pytest.mark.slow
def test_dryrun_small_mesh():
    out = run_with_devices(CODE, ndev=8, timeout=900)
    assert "DRYRUN_SMALL_OK" in out
    assert out.count("OK flops") == 6
