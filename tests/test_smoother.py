"""Tests: the in-launch data-axis smoother workload (ISSUE 5).

The smoother is the first in-tree launch workload that builds a
:class:`~repro.halo.program.HaloProgram`, so these tests cover the whole
``--halo-steps`` seam end to end: production communicator ->
process-default fusion depth -> cycle program -> ``program/s=N``
Decision row -> pinned, checksum-identical rerun.
"""

import numpy as np
import pytest

from repro.halo import STENCIL26, get_default_halo_steps, set_default_halo_steps
from repro.launch.smoother import CYCLES, run_smoother, smoother_cycle
from repro.measure.production import production_communicator


class TestSmootherCycle:
    def test_named_cycles(self):
        assert smoother_cycle("smooth") == (STENCIL26,)
        pc = smoother_cycle("predictor-corrector")
        assert len(pc) == 2
        assert pc[0].radii == (2, 1, 1) and pc[1].radii == (1, 1, 1)
        assert set(CYCLES) == {"smooth", "predictor-corrector"}
        with pytest.raises(ValueError, match="unknown smoother cycle"):
            smoother_cycle("laplacian")


class TestRunSmoother:
    def test_records_program_decision_and_pins_rerun(self, tmp_path):
        before = get_default_halo_steps()
        try:
            comm, save = production_communicator(
                tmp_path, axis_name="data", calibrate=False, halo_steps="auto"
            )
            report = run_smoother(comm, iters=1, interior=(8, 8, 8),
                                  cycle="predictor-corrector")
            assert report.decision_recorded
            assert not report.program.pinned  # first run prices, not pins
            assert report.program.cycle_len == 2
            assert np.isfinite(report.checksum)
            rows = comm.model.decisions.program_rows()
            assert len(rows) == 1
            assert rows[0].strategy == f"program/s={report.program.steps}"
            save()

            # "the rerun": a fresh production communicator over the same
            # store pins the depth and reproduces the field bit-exactly
            comm2, _ = production_communicator(
                tmp_path, axis_name="data", calibrate=False, halo_steps="auto"
            )
            report2 = run_smoother(comm2, iters=1, interior=(8, 8, 8),
                                   cycle="predictor-corrector")
            assert report2.program.pinned
            assert report2.program.steps == report.program.steps
            assert report2.checksum == report.checksum
            assert report2.decision_recorded
        finally:
            set_default_halo_steps(before)

    def test_fixed_depth_and_summary(self, tmp_path):
        before = get_default_halo_steps()
        try:
            comm, _ = production_communicator(
                tmp_path, axis_name="data", calibrate=False, halo_steps=1
            )
            report = run_smoother(comm, iters=2, interior=(6, 6, 6),
                                  cycle="smooth")
            assert report.program.steps == 1
            assert report.iterations == 2
            assert "smoother:" in report.summary
            assert "exchanges/cycle=1.00" in report.summary
        finally:
            set_default_halo_steps(before)
