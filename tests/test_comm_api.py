"""Unit tests: the Communicator API — strategy registry, policies,
request-based transfers, and the fused neighborhood alltoallv.

These are direct (non-hypothesis) tests; they run on a single CPU device
(self-permutes on a 1-rank mesh exercise the full pack -> wire -> unpack
machinery in-process)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm import (
    BaselinePolicy,
    Communicator,
    FixedPolicy,
    Interposer,
    MODES,
    Strategy,
    StrategyRegistry,
    as_communicator,
    default_registry,
    policy_for_mode,
    resolve_strategy,
)
from repro.comm.api import AUTO, BOUNDING, DMA, REF, ROWS, XLA, plan_neighbor_alltoallv
from repro.core import BYTE, Contiguous, Subarray, TypeRegistry, Vector
from repro.halo.exchange import DIRECTIONS, HaloSpec
from repro.kernels.ref import pack_ref


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("x",))


# ===========================================================================
# registry
# ===========================================================================

class TestRegistry:
    def test_default_registry_contents(self):
        names = default_registry().names()
        for s in (ROWS, DMA, XLA, REF, AUTO, BOUNDING):
            assert s.name in names

    def test_resolve(self):
        assert resolve_strategy(ROWS.name) is ROWS
        assert resolve_strategy(None) is AUTO
        assert resolve_strategy(DMA) is DMA

    def test_unknown_strategy_raises(self):
        with pytest.raises(ValueError, match="unknown strategy"):
            resolve_strategy("warp-drive")

    def test_duplicate_register_raises(self):
        reg = default_registry().copy()
        with pytest.raises(ValueError, match="already registered"):
            reg.register(type(ROWS)())

    def test_copy_is_isolated(self):
        reg = default_registry().copy()

        class Probe(Strategy):
            name = "probe"

        reg.register(Probe)
        assert "probe" in reg
        assert "probe" not in default_registry()

    def test_selectable_excludes_oracle_and_auto(self):
        sel = {s.name for s in default_registry().selectable()}
        assert REF.name not in sel
        assert AUTO.name not in sel
        assert BOUNDING.name in sel


class TestPluginSelection:
    def test_registered_plugin_wins_selection(self):
        class Teleport(Strategy):
            name = "teleport"

            def model_pack(self, model, ct, incount):
                return 0.0

            def model_unpack(self, model, ct, incount):
                return 0.0

            def wire_bytes(self, ct, incount=1):
                return 0

        reg = default_registry().copy()
        comm = Communicator(strategies=reg)
        ct = comm.commit(Vector(4096, 8, 4096, BYTE))
        before = comm.select(ct).name  # populate the selection cache
        assert before != "teleport"
        # registering a plugin must invalidate cached selections
        reg.register(Teleport())
        assert comm.select(ct).name == "teleport"
        # the default registry is untouched
        assert Communicator().select(ct).name != "teleport"

    def test_model_selects_among_registered(self):
        # with bounding removed from the registry, a dense contiguous
        # type must fall back to a pack-based strategy
        reg = StrategyRegistry((ROWS, DMA, XLA, REF, AUTO))
        comm = Communicator(strategies=reg)
        ct = comm.commit(Contiguous(1000, BYTE))
        assert comm.select(ct).name != BOUNDING.name
        assert Communicator().select(
            Communicator().commit(Contiguous(1000, BYTE))
        ).name == BOUNDING.name


# ===========================================================================
# policies / shim
# ===========================================================================

class TestPolicies:
    def test_policy_for_mode_rejects_unknown(self):
        with pytest.raises(ValueError):
            policy_for_mode("nope")

    def test_modes_cover_shim_surface(self):
        for mode in MODES:
            policy_for_mode(mode)  # must not raise

    def test_fixed_policy_forces_strategy(self):
        comm = Communicator(policy=FixedPolicy(DMA.name))
        ct = comm.commit(Contiguous(64, BYTE))
        assert comm.select(ct) is DMA

    def test_fixed_wire_only_policy_still_unpacks(self):
        # forcing the wire-only bounding strategy must not break local
        # pack/unpack calls (they fall back to the auto heuristic)
        comm = Communicator(policy=FixedPolicy(BOUNDING.name))
        ct = comm.commit(Vector(4, 8, 16, BYTE))
        assert comm.select(ct, wire=True) is BOUNDING
        assert comm.select(ct, wire=False) is AUTO
        buf = jnp.arange(ct.extent + 8, dtype=jnp.uint8)
        packed = comm.pack(buf, ct)
        out = comm.unpack(jnp.zeros_like(buf), packed, ct)
        assert out.shape == buf.shape

    def test_baseline_policy_degrades_past_cap(self):
        comm = Communicator(policy=BaselinePolicy(block_cap=16))
        ct = comm.commit(Vector(32, 8, 64, BYTE))
        assert comm.select(ct) is REF
        small = comm.commit(Vector(4, 8, 64, BYTE))
        assert comm.select(small) is XLA

    def test_interposer_is_shim_over_communicator(self):
        ip = Interposer()
        assert isinstance(ip.comm, Communicator)
        assert as_communicator(ip) is ip.comm
        assert as_communicator(ip.comm) is ip.comm
        with pytest.raises(TypeError):
            as_communicator(object())


# ===========================================================================
# pack/unpack through the Communicator (every strategy agrees with ref)
# ===========================================================================

class TestPackUnpack:
    def test_all_strategies_roundtrip(self):
        rng = np.random.default_rng(3)
        dt = Subarray((96, 8, 4), (40, 5, 2), (8, 1, 1), BYTE)
        buf = jnp.asarray(rng.integers(0, 255, (96 * 8 * 4,), dtype=np.uint8))
        dst = jnp.asarray(rng.integers(0, 255, (96 * 8 * 4,), dtype=np.uint8))
        want_p = None
        want_u = None
        for s in (ROWS, DMA, XLA, REF, AUTO):
            comm = Communicator(policy=FixedPolicy(s))
            ct = comm.commit(dt)
            if want_p is None:
                want_p = np.asarray(pack_ref(buf, ct.block))
            p = comm.pack(buf, ct)
            np.testing.assert_array_equal(np.asarray(p), want_p, err_msg=s.name)
            u = np.asarray(comm.unpack(dst, p, ct))
            if want_u is None:
                want_u = u
            np.testing.assert_array_equal(u, want_u, err_msg=s.name)


# ===========================================================================
# requests + wire ops (1-rank mesh: self-permutes)
# ===========================================================================

class TestRequests:
    def _setup(self):
        comm = Communicator(axis_name="x")
        send = comm.commit(Subarray((64,), (8,), (0,), BYTE))
        recv = comm.commit(Subarray((64,), (8,), (32,), BYTE))
        return comm, send, recv

    def test_isend_irecv_roundtrip(self):
        comm, send, recv = self._setup()
        seen = {}

        def body(b):
            req = comm.isend(b, send, [(0, 0)])
            out = comm.irecv(b, recv, req)
            seen["pending"] = out.completed
            res = out.wait()
            seen["done"] = out.completed
            assert out.wait() is res  # idempotent
            return res

        fn = jax.jit(shard_map(
            body, mesh=_mesh1(), in_specs=P(), out_specs=P(), check_vma=False
        ))
        buf = jnp.arange(64, dtype=jnp.uint8)
        out = np.asarray(fn(buf))
        assert seen == {"pending": False, "done": True}
        want = np.arange(64, dtype=np.uint8)
        want[32:40] = want[0:8]
        np.testing.assert_array_equal(out, want)

    def test_overlapped_requests(self):
        """Two exchanges issued before either wait — both land."""
        comm, send, recv = self._setup()
        send2 = comm.commit(Subarray((64,), (4,), (16,), BYTE))
        recv2 = comm.commit(Subarray((64,), (4,), (48,), BYTE))

        def body(b):
            r1 = comm.isend(b, send, [(0, 0)])
            r2 = comm.isend(b, send2, [(0, 0)])
            out = comm.irecv(b, recv, r1).wait()
            return comm.irecv(out, recv2, r2).wait()

        fn = jax.jit(shard_map(
            body, mesh=_mesh1(), in_specs=P(), out_specs=P(), check_vma=False
        ))
        out = np.asarray(fn(jnp.arange(64, dtype=jnp.uint8)))
        want = np.arange(64, dtype=np.uint8)
        want[32:40] = want[0:8]
        want[48:52] = want[16:20]
        np.testing.assert_array_equal(out, want)


# ===========================================================================
# fused neighborhood alltoallv
# ===========================================================================

class TestNeighborAlltoallv:
    def test_plan_groups_halo_directions_into_delta_classes(self):
        spec = HaloSpec(grid=(2, 2, 2), interior=(4, 4, 4))
        perms = tuple(
            tuple(spec.perm(d)) for d in DIRECTIONS
        )
        sizes = tuple(64 for _ in DIRECTIONS)
        plan = plan_neighbor_alltoallv(sizes, perms)
        assert plan.fused
        assert plan.nranks == 8
        # 26 directions collapse into the 7 displacement classes mod 2
        assert len(plan.groups) == 7
        assert sorted(
            i for g in plan.groups for i in g.transfers
        ) == list(range(26))
        for r in range(8):
            dests = [d for d in range(8) if plan.send_rows[r][d] != 7]
            assert len(dests) == 7  # one segment per peer, none to self
        # exact-byte layout: every transfer has its own wire segment, the
        # total is the ragged optimum, and the segments tile the buffer
        assert plan.wire_bytes == 26 * 64
        assert sorted(s.offset for s in plan.segments) == [
            64 * i for i in range(26)
        ]
        # class totals are unequal (2/4/8 members x 64B) so a uniform
        # all_to_all would have to pad: the plan must not choose it at
        # zero waste tolerance on this JAX
        assert plan.seg_bytes == 8 * 64
        assert plan.padding_bytes == 0
        assert plan.issued_bytes == plan.wire_bytes

    def test_plan_uniform_schedule_requires_tolerance(self):
        # same halo layout: opting into waste tolerance re-enables the
        # single uniform collective (1 op, padded rows)
        from repro.comm.wireplan import plan_wire

        spec = HaloSpec(grid=(2, 2, 2), interior=(4, 4, 4))
        perms = tuple(tuple(map(tuple, spec.perm(d))) for d in DIRECTIONS)
        sizes = tuple(64 for _ in DIRECTIONS)
        exact = plan_wire(sizes, perms, native=False)
        assert exact.schedule == "grouped"
        assert exact.wire_ops == 7
        tolerant = plan_wire(sizes, perms, native=False,
                             uniform_waste_tolerance=10.0)
        assert tolerant.schedule == "uniform"
        assert tolerant.wire_ops == 1
        assert tolerant.issued_bytes == 8 * tolerant.seg_bytes
        assert tolerant.padding_bytes > 0

    def test_plan_grid_size_threshold_forces_grouped(self):
        # past rank_factor x ngroups the fused layout is mostly zero
        # rows: the plan must take the grouped fallback even when a
        # native ragged collective (or infinite tolerance) is claimed
        from repro.comm.wireplan import plan_wire

        nranks = 16
        ring = tuple((r, (r + 1) % nranks) for r in range(nranks))
        plan = plan_wire((128,), (ring,), native=True,
                         uniform_waste_tolerance=float("inf"))
        assert plan.ngroups == 1
        assert plan.schedule == "grouped"
        assert plan.issued_bytes == plan.wire_bytes == 128

    def test_plan_rejects_non_permutation(self):
        with pytest.raises(ValueError):
            plan_neighbor_alltoallv((8,), (((0, 0), (1, 0)),))

    def test_single_rank_fused_exchange(self):
        comm = Communicator(axis_name="x")
        send_cts = [
            comm.commit(Subarray((64,), (8,), (0,), BYTE)),
            comm.commit(Subarray((64,), (4,), (16,), BYTE)),
        ]
        recv_cts = [
            comm.commit(Subarray((64,), (8,), (32,), BYTE)),
            comm.commit(Subarray((64,), (4,), (48,), BYTE)),
        ]
        perms = [[(0, 0)], [(0, 0)]]

        def body(b):
            return comm.neighbor_alltoallv(b, send_cts, recv_cts, perms)

        fn = jax.jit(shard_map(
            body, mesh=_mesh1(), in_specs=P(), out_specs=P(), check_vma=False
        ))
        buf = jnp.arange(64, dtype=jnp.uint8)
        out = np.asarray(fn(buf))
        want = np.arange(64, dtype=np.uint8)
        want[32:40] = want[0:8]
        want[48:52] = want[16:20]
        np.testing.assert_array_equal(out, want)

        # the whole exchange must be ONE collective whichever schedule
        # the default (model-priced) policy lands on for the single
        # delta class
        from repro.comm import collective_payload_bytes

        counts = collective_payload_bytes(fn, buf)
        assert counts["ops"] == 1
        # the exact ladder keeps the old shape: one uniform all_to_all
        strats, plan = comm.plan_neighbor(send_cts, perms,
                                          schedule_policy="exact")
        assert plan.schedule == "uniform" and plan.wire_ops == 1

        def body_exact(b):
            return comm.neighbor_alltoallv(
                b, send_cts, recv_cts, perms, plan=plan, strategies=strats
            )

        fn_exact = jax.jit(shard_map(
            body_exact, mesh=_mesh1(), in_specs=P(), out_specs=P(),
            check_vma=False
        ))
        np.testing.assert_array_equal(np.asarray(fn_exact(buf)), want)
        jaxpr = str(jax.make_jaxpr(fn_exact)(buf))
        assert jaxpr.count("all_to_all") == 1
        assert "ppermute" not in jaxpr

    def test_mismatched_lengths_raise(self):
        comm = Communicator(axis_name="x")
        ct = comm.commit(Contiguous(8, BYTE))
        with pytest.raises(ValueError):
            comm.ineighbor_alltoallv(jnp.zeros(8, jnp.uint8), [ct], [], [])


# ===========================================================================
# stats plumbing
# ===========================================================================

def test_stats_include_wire_ops_and_strategies():
    comm = Communicator(axis_name="x")
    s = comm.stats()
    assert s["wire_ops"] == 0
    assert s["strategies"] == len(default_registry())
    assert s["committed_types"] == 0
