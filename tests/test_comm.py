"""Unit tests: interposer + performance model + calibration plumbing."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm import Interposer, PerfModel, SystemParams, TPU_V5E
from repro.comm.perfmodel import _interp2d
from repro.core import BYTE, Contiguous, Subarray, TypeRegistry, Vector
from repro.kernels.ref import pack_ref, unpack_ref


class TestPerfModel:
    def setup_method(self):
        self.reg = TypeRegistry()
        self.model = PerfModel(TPU_V5E)

    def test_strategies_ordered_sanely(self):
        # tiny contiguous block at huge stride: dma beats rows (over-fetch)
        ct = self.reg.commit(Vector(4096, 8, 4096, BYTE))
        t_rows = self.model.estimate(ct, 1, "rows").total
        t_dma = self.model.estimate(ct, 1, "dma").total
        t_xla = self.model.estimate(ct, 1, "xla").total
        assert t_dma < t_rows
        assert t_dma < t_xla  # 4096 per-block copies are the baseline pain

    def test_xla_scales_with_block_count(self):
        few = self.reg.commit(Vector(4, 256, 512, BYTE))
        many = self.reg.commit(Vector(4096, 256, 512, BYTE))
        assert self.model.t_pack(many, 1, "xla") > 100 * self.model.t_pack(
            few, 1, "xla"
        )

    def test_bounding_for_contiguous(self):
        ct = self.reg.commit(Contiguous(1000, BYTE))
        assert self.model.select(ct).strategy == "bounding"

    def test_selection_cached(self):
        ct = self.reg.commit(Vector(16, 64, 512, BYTE))
        a = self.model.select(ct)
        b = self.model.select(ct)
        assert a is b
        assert self.model.hits == 1

    def test_measured_table_interpolation(self):
        table = (
            (3.0, 10.0, 1e-6), (3.0, 20.0, 2e-6),
            (9.0, 10.0, 3e-6), (9.0, 20.0, 6e-6),
        )
        mid = _interp2d(table, 6.0, 15.0)
        assert 1e-6 < mid < 6e-6
        # corner exact
        assert _interp2d(table, 3.0, 10.0) == pytest.approx(1e-6)
        # clamped outside the grid
        assert _interp2d(table, 0.0, 0.0) == pytest.approx(1e-6)

    def test_params_json_roundtrip(self):
        p = SystemParams(
            name="t", pack_table={"rows": ((1.0, 2.0, 3e-6),)}
        )
        q = SystemParams.from_json(p.to_json())
        assert q == p


class TestInterposer:
    def test_mode_validation(self):
        with pytest.raises(ValueError):
            Interposer(mode="nope")

    def test_pack_unpack_modes_agree(self):
        rng = np.random.default_rng(0)
        dt = Subarray((128, 16, 4), (48, 7, 3), (16, 2, 1), BYTE)
        buf = jnp.asarray(rng.integers(0, 255, (128 * 16 * 4,), dtype=np.uint8))
        dst = jnp.asarray(rng.integers(0, 255, (128 * 16 * 4,), dtype=np.uint8))
        outs = {}
        for mode in ("baseline", "tempi", "rows", "dma"):
            ip = Interposer(mode=mode)
            ct = ip.commit(dt)
            packed = ip.pack(buf, ct)
            outs[mode] = (
                np.asarray(packed),
                np.asarray(ip.unpack(dst, packed, ct)),
            )
        want_p = np.asarray(pack_ref(buf, ip.commit(dt).block))
        for mode, (p, u) in outs.items():
            np.testing.assert_array_equal(p, want_p, err_msg=mode)
            np.testing.assert_array_equal(
                u, outs["baseline"][1], err_msg=mode
            )

    def test_baseline_degrades_to_gather_beyond_cap(self):
        ip = Interposer(mode="baseline")
        ct = ip.commit(Vector(5000, 8, 64, BYTE))
        assert ip._strategy(ct, 1, wire=False) == "ref"

    def test_stats(self):
        ip = Interposer()
        ct = ip.commit(Vector(4, 8, 16, BYTE))
        ip.model.select(ct)
        s = ip.stats()
        assert s["committed_types"] == 1
        assert s["model_lookups"] >= 1
