"""Tests: region-split halo overlap (ROADMAP: per-direction wire
completion).  The 3^3 core/face/edge/corner decomposition must exactly
partition the first application's output window; per-delta-class
ClassRequest/NeighborRequest drains must compose in any completion
order; the model's core/rim pricing must pick and pin an
``overlap/mode=...`` decision; and region mode must stay bit-identical
to the monolithic path on a real 2x2x2 grid for s in {1, 2, 3}."""

import itertools

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm import ClassRequest, Communicator, NeighborRequest
from repro.halo import (
    DIRECTIONS,
    HaloSpec,
    STENCIL26,
    StencilOp,
    as_ops,
    cycle_halo_radii,
    halo_exchange,
    halo_regions,
    make_halo_plan,
    make_halo_types,
    overlap_region_descriptors,
    overlapped_stencil_iteration,
    stencil_steps,
)
from repro.measure import DecisionCache


# ---------------------------------------------------------------------------
# the decomposition: core + faces/edges/corners exactly partition
# ---------------------------------------------------------------------------

def _assert_partition(spec, ops):
    """Brute force: summing every region's indicator must give exactly 1
    on the first application's output window and 0 elsewhere."""
    ops = as_ops(ops)
    first = ops[0]
    cover = np.zeros(spec.alloc, dtype=np.int32)
    for reg in halo_regions(spec, ops):
        sl = tuple(slice(o, o + s) for o, s in zip(reg.origin, reg.shape))
        cover[sl] += 1
    window = np.zeros(spec.alloc, dtype=np.int32)
    window[tuple(
        slice(r, r + n + 2 * (hr - r))
        for n, hr, r in zip(spec.interior, spec.radii, first.radii)
    )] = 1
    np.testing.assert_array_equal(cover, window)


def test_regions_structure_26_point():
    """Roomy interior, single-step halo: the full 3^3 decomposition —
    one core, 6 faces, 12 edges, 8 corners — with the expected band ->
    transfer wiring."""
    spec = HaloSpec(grid=(1, 1, 1), interior=(8, 7, 6), radius=1)
    regions = halo_regions(spec, STENCIL26)
    by_rank = {}
    for reg in regions:
        by_rank.setdefault(sum(abs(s) for s in reg.sig), []).append(reg)
    assert len(by_rank[0]) == 1      # core
    assert len(by_rank[1]) == 6      # faces
    assert len(by_rank[2]) == 12     # edges
    assert len(by_rank[3]) == 8      # corners

    core = by_rank[0][0]
    assert core.sig == (0, 0, 0)
    assert core.bands == () and core.transfers == ()
    assert core.shape == (6, 5, 4)   # interior - 2r per axis

    face = next(r for r in by_rank[1] if r.sig == (-1, 0, 0))
    assert face.bands == ((-1, 0, 0),)
    assert face.transfers == (DIRECTIONS.index((1, 0, 0)),)

    corner = next(r for r in by_rank[3] if r.sig == (1, 1, 1))
    # the corner's neighborhood reaches the face, edge and corner bands
    # on its octant: 2^3 - 1 bands
    assert len(corner.bands) == 7
    assert len(corner.transfers) == 7


@pytest.mark.parametrize("interior,radius,ops", [
    # the classic 26-point smoother, two fused steps
    ((8, 7, 6), 2, STENCIL26),
    # asymmetric per-dim radii: deep along the slow axis
    ((6, 5, 4), (4, 2, 2), StencilOp((2, 1, 1))),
    # heterogeneous cycle, radii from the cycle (s = 2 repeats)
    ((6, 5, 4), None, (StencilOp((2, 1, 1), 0.5), StencilOp((1, 1, 1), 0.25))),
    # interior shallower than 2r: the low/high read-sets overlap
    ((2, 5, 4), 2, STENCIL26),
    # tiny domain
    ((1, 1, 1), 1, STENCIL26),
    # deep shell (hr > 2r): dependency over-approximation territory
    ((6, 6, 6), 4, STENCIL26),
])
def test_regions_exact_partition(interior, radius, ops):
    if radius is None:
        radius = cycle_halo_radii(as_ops(ops), 2)
    spec = HaloSpec(grid=(1, 1, 1), interior=interior, radius=radius)
    _assert_partition(spec, ops)


def test_regions_partition_property():
    """Property test: for any geometry — asymmetric per-dim radii,
    heterogeneous cycle radii, interiors down to the halo depth — the
    nonempty regions exactly partition the window (no overlap, no
    gap)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def geometries(draw):
        ncycle = draw(st.integers(1, 2))
        ops = tuple(
            StencilOp(tuple(
                draw(st.integers(1, 2)) for _ in range(3)
            ))
            for _ in range(ncycle)
        )
        steps = draw(st.integers(1, 2))
        hr = cycle_halo_radii(ops, steps)
        interior = tuple(draw(st.integers(h, h + 5)) for h in hr)
        return interior, hr, ops

    @settings(max_examples=80, deadline=None)
    @given(geometries())
    def check(geom):
        interior, hr, ops = geom
        spec = HaloSpec(grid=(1, 1, 1), interior=interior, radius=hr)
        _assert_partition(spec, ops)

    check()


# ---------------------------------------------------------------------------
# per-class Request semantics
# ---------------------------------------------------------------------------

class _FakePayload:
    """Stands in for a received jax.Array: readiness is scripted."""

    def __init__(self, ready=False):
        self.ready = ready

    def is_ready(self):
        return self.ready


def _class(index, ready=False):
    # unpacking appends the class index to the (tuple-valued) buffer —
    # enough to observe exactly which classes landed, in which order
    return ClassRequest(
        index, _FakePayload(ready), transfers=(index,), nbytes=8 * index,
        unpack=lambda buf, payload, i=index: buf + (i,),
    )


def test_class_request_out_of_order_completion():
    classes = [_class(0), _class(1, ready=True), _class(2)]
    drains = []
    req = NeighborRequest(
        (), classes, on_drain=lambda r, c: drains.append(c.index)
    )
    assert not req.completed
    assert len(req.pending) == 3

    # class 1's wire landed first: wait_any must drain IT, not plan order
    got = req.wait_any()
    assert got.index == 1 and got.applied
    assert req.buffer == (1,)

    # class 2 lands next; class 0 still in flight
    classes[2]._value.ready = True
    assert req.wait_any().index == 2
    # nothing ready -> fall back to plan order (deterministic drain)
    assert req.wait_any().index == 0

    assert req.drained == [1, 2, 0]
    assert drains == [1, 2, 0]
    assert req.buffer == (1, 2, 0)
    assert req.completed and req.wait() == (1, 2, 0)
    with pytest.raises(ValueError):
        req.wait_any()


def test_class_request_wait_drains_everything():
    req = NeighborRequest((), [_class(i) for i in range(4)])
    assert req.wait() == (0, 1, 2, 3)  # plan order when nothing is ready
    assert req.drained == [0, 1, 2, 3]
    assert all(c.applied for c in req.classes)


def test_class_request_empty_exchange_completes_immediately():
    req = NeighborRequest("buf", [])
    assert req.completed and req.wait() == "buf"


# ---------------------------------------------------------------------------
# model pricing: per-class completions, core/rim schedule, pinning
# ---------------------------------------------------------------------------

def _plan_7_classes(comm, schedule_policy="exact"):
    spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=2)
    types = make_halo_types(spec, comm)
    plan = make_halo_plan(spec, comm, types, schedule_policy=schedule_policy)
    return spec, plan


def test_price_class_completions_profile():
    comm = Communicator(axis_name="ranks")
    spec, plan = _plan_7_classes(comm)
    from repro.comm import reschedule

    grouped = reschedule(plan.wire, "grouped")
    comps = comm.model.price_class_completions(grouped)
    assert len(comps) == grouped.ngroups == 7
    # grouped: class k rides the k-th collective — completions must be
    # strictly increasing (cumulative bytes + per-launch latency)
    assert all(b > a for a, b in zip(comps, comps[1:]))
    # fused schedules complete every class together
    uniform = reschedule(plan.wire, "uniform")
    ucomps = comm.model.price_class_completions(uniform)
    assert len(set(ucomps)) == 1 and len(ucomps) == 7


def test_overlap_descriptors_and_pricing():
    comm = Communicator(axis_name="ranks")
    spec, plan = _plan_7_classes(comm)
    core_bytes, rims = overlap_region_descriptors(spec, STENCIL26, plan.wire)
    # radius 2, interior (6,5,4): core is the (2,1,0)-shaped... empty in
    # x -> core_bytes 0 is allowed; rims must all be nonempty with deps
    # inside the plan's class space
    assert core_bytes >= 0
    assert rims and all(nb > 0 for nb, _ in rims)
    ncls = plan.wire.ngroups
    assert all(
        deps and all(0 <= c < ncls for c in deps) for _, deps in rims
    )

    ests = comm.model.price_overlap(
        plan.wire, rims, core_bytes, STENCIL26.nneighbors
    )
    assert set(ests) == {"monolithic", "region"}
    mono, region = ests["monolithic"], ests["region"]
    assert mono.t_total >= max(mono.t_wire, mono.t_core)
    assert len(mono.t_rims) == len(rims)
    assert region.class_completions == mono.class_completions
    # neither mode finishes before the slowest class has landed
    assert region.t_total >= region.t_wire


def test_choose_overlap_mode_records_then_pins():
    comm = Communicator(axis_name="ranks", decisions=DecisionCache())
    spec, plan = _plan_7_classes(comm)
    core_bytes, rims = overlap_region_descriptors(spec, STENCIL26, plan.wire)

    mode, ests, pinned = comm.model.choose_overlap_mode(
        plan.wire, rims, core_bytes, STENCIL26.nneighbors
    )
    assert mode in ("monolithic", "region") and not pinned
    rows = [
        d for d in comm.model.decisions.log
        if d.strategy.startswith("overlap/mode=")
    ]
    assert len(rows) == 1
    assert rows[0].strategy == f"overlap/mode={mode}"
    assert "regions=" in rows[0].signature

    # the recorded row pins the rerun — no re-pricing flip possible
    mode2, _, pinned2 = comm.model.choose_overlap_mode(
        plan.wire, rims, core_bytes, STENCIL26.nneighbors
    )
    assert (mode2, pinned2) == (mode, True)

    # a hand-pinned row overrides the priced winner entirely
    import dataclasses

    other = "region" if mode == "monolithic" else "monolithic"
    forced = DecisionCache([
        dataclasses.replace(rows[0], strategy=f"overlap/mode={other}")
    ])
    comm2 = Communicator(axis_name="ranks", decisions=forced)
    mode3, _, pinned3 = comm2.model.choose_overlap_mode(
        plan.wire, rims, core_bytes, STENCIL26.nneighbors
    )
    assert pinned3 and mode3 == other


# ---------------------------------------------------------------------------
# end to end: region mode bit-identical, single rank + 8 ranks
# ---------------------------------------------------------------------------

def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("ranks",))


@pytest.mark.parametrize("mode", ["region", "auto"])
def test_region_mode_matches_plain_single_rank(mode):
    spec = HaloSpec(grid=(1, 1, 1), interior=(6, 5, 4), radius=2)
    az, ay, ax = spec.alloc
    comm = Communicator(axis_name="ranks", decisions=DecisionCache())
    types = make_halo_types(spec, comm)
    probe = {}

    def plain(local):
        local = halo_exchange(local, spec, comm, "ranks", types)
        return stencil_steps(local, spec, steps=2)

    def split(local):
        return overlapped_stencil_iteration(
            local, spec, comm, "ranks", types, steps=2, probe=probe,
            mode=mode,
        )

    mesh = _mesh1()
    jp = jax.jit(shard_map(plain, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    jo = jax.jit(shard_map(split, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False))
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(az, ay, ax)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(jp(x)), np.asarray(jo(x)))
    assert probe["pending_during_interior"] is True
    assert probe["overlap_mode"] in ("monolithic", "region")
    if mode == "region":
        assert probe["overlap_mode"] == "region"
        # single-rank periodic grid: one delta class carries all 26
        # transfers, every rim drains on the first (only) wait_any
        assert probe["rim_regions"] == 26
        assert probe["class_drain_order"] == (0,)
        assert len(probe["region_order"]) == 26
    else:
        # auto resolved and pinned an overlap/mode decision
        assert any(
            d.strategy == f"overlap/mode={probe['overlap_mode']}"
            for d in comm.model.decisions.log
        )


def test_region_mode_rejects_unknown():
    spec = HaloSpec(grid=(1, 1, 1), interior=(6, 5, 4), radius=1)
    comm = Communicator(axis_name="ranks")
    with pytest.raises(ValueError, match="overlap mode"):
        overlapped_stencil_iteration(
            jnp.zeros(spec.alloc, jnp.float32), spec, comm, "ranks",
            steps=1, mode="sideways",
        )


REGION_8RANK_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator
from repro.halo import (HaloSpec, halo_exchange, make_halo_plan,
                        make_halo_types, overlapped_stencil_iteration,
                        stencil_steps)

mesh = Mesh(np.array(jax.devices()), ("ranks",))
for s in (1, 2, 3):
    spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=s)
    R = spec.nranks
    az, ay, ax = spec.alloc
    assert len(jax.devices()) == R
    comm = Communicator(axis_name="ranks")
    types = make_halo_types(spec, comm)
    plan = make_halo_plan(spec, comm, types, schedule_policy="exact")
    probe = {}

    def plain(local):
        local = halo_exchange(local, spec, comm, "ranks", types, plan=plan)
        return stencil_steps(local, spec, steps=s)

    def region(local):
        return overlapped_stencil_iteration(
            local, spec, comm, "ranks", types, steps=s, probe=probe,
            plan=plan, mode="region")

    def mono(local):
        return overlapped_stencil_iteration(
            local, spec, comm, "ranks", types, steps=s,
            plan=plan, mode="monolithic")

    kw = dict(mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"),
              check_vma=False)
    jp = jax.jit(shard_map(plain, **kw))
    jr = jax.jit(shard_map(region, **kw))
    jm = jax.jit(shard_map(mono, **kw))
    rng = np.random.default_rng(11 + s)
    x = jnp.asarray(rng.normal(size=(R * az, ay, ax)).astype(np.float32))
    ref = np.asarray(jp(x))
    np.testing.assert_array_equal(ref, np.asarray(jr(x)),
                                  err_msg=f"region s={s}")
    np.testing.assert_array_equal(ref, np.asarray(jm(x)),
                                  err_msg=f"monolithic s={s}")
    assert probe["overlap_mode"] == "region"
    assert probe["rim_regions"] == 26, probe
    assert sorted(probe["class_drain_order"]) == list(
        range(plan.wire.ngroups)), probe
    assert plan.wire.ngroups == 7
print("REGION_SPLIT_OK")
"""


@pytest.mark.slow
def test_region_mode_matches_monolithic_8_ranks_deep():
    """The tentpole invariant on a real 2x2x2 grid: region-split is
    bit-identical to BOTH the plain exchange-then-cycle path and the
    monolithic overlap path, for fusion depths s in {1, 2, 3}."""
    from tests._subproc import run_with_devices

    out = run_with_devices(REGION_8RANK_CODE, ndev=8)
    assert "REGION_SPLIT_OK" in out
