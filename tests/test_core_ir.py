"""Unit tests for datatype translation + canonicalization (paper §2-3.2)."""

import pytest

from repro.core import (
    BYTE,
    FLOAT,
    INT32,
    Contiguous,
    DenseData,
    Hvector,
    StreamData,
    Subarray,
    Vector,
    dense_folding,
    make_cuboid_hvector,
    make_cuboid_subarray,
    make_cuboid_vector_of_hvector,
    simplify,
    strided_block_of,
    stream_elision,
    translate,
)


class TestExtents:
    def test_named(self):
        assert FLOAT.extent == 4 and FLOAT.size == 4
        assert BYTE.extent == 1

    def test_contiguous(self):
        c = Contiguous(10, FLOAT)
        assert c.extent == 40 and c.size == 40

    def test_vector(self):
        # 3 blocks of 2 floats, stride 5 floats: extent (2*5+2)*4
        v = Vector(3, 2, 5, FLOAT)
        assert v.extent == (2 * 5 + 2) * 4
        assert v.size == 3 * 2 * 4

    def test_hvector(self):
        h = Hvector(3, 2, 100, FLOAT)
        assert h.extent == 2 * 100 + 8
        assert h.size == 24

    def test_subarray_extent_is_full_array(self):
        s = Subarray((8, 4), (2, 2), (1, 1), FLOAT)
        assert s.extent == 8 * 4 * 4
        assert s.size == 2 * 2 * 4

    def test_validation(self):
        with pytest.raises(ValueError):
            Vector(3, 4, 2, BYTE)  # stride < blocklength
        with pytest.raises(ValueError):
            Subarray((4,), (5,), (0,), BYTE)  # subsize > size
        with pytest.raises(ValueError):
            Subarray((4,), (2,), (3,), BYTE)  # start+subsize > size


class TestTranslation:
    def test_named_is_dense(self):
        t = translate(FLOAT)
        assert isinstance(t.data, DenseData)
        assert t.data.extent == 4 and t.data.offset == 0
        assert not t.children

    def test_contiguous_is_stream(self):
        t = translate(Contiguous(7, FLOAT))
        assert isinstance(t.data, StreamData)
        assert t.data.count == 7 and t.data.stride == 4

    def test_vector_two_streams(self):
        t = translate(Vector(3, 2, 5, FLOAT))
        assert isinstance(t.data, StreamData)
        assert t.data.count == 3 and t.data.stride == 20  # 5 floats
        c = t.child
        assert isinstance(c.data, StreamData)
        assert c.data.count == 2 and c.data.stride == 4

    def test_subarray_nest_matches_paper_fig2(self):
        # Fig 2 bottom: 3D byte subarray A=(256,512,1024) E=(100,13,47)
        t = translate(Subarray((256, 512, 1024), (100, 13, 47), (0, 0, 0), BYTE))
        assert isinstance(t.data, StreamData)
        assert (t.data.count, t.data.stride) == (47, 131072)
        t1 = t.child
        assert (t1.data.count, t1.data.stride) == (13, 256)
        t2 = t1.child
        assert (t2.data.count, t2.data.stride) == (100, 1)
        assert isinstance(t2.child.data, DenseData)

    def test_subarray_offsets_bytes(self):
        t = translate(Subarray((8, 4), (2, 2), (3, 1), INT32))
        # outer dim: stride 8*4=32B, start 1 -> offset 32
        assert t.data.offset == 32
        # inner dim: stride 4B, start 3 -> offset 12
        assert t.child.data.offset == 12


class TestCanonicalize:
    def test_dense_folding_contig_bytes(self):
        t = translate(Contiguous(100, BYTE))
        assert dense_folding(t)
        assert isinstance(t.data, DenseData) and t.data.extent == 100

    def test_stream_elision_blocklength_one(self):
        t = translate(Hvector(13, 1, 256, Vector(100, 1, 1, BYTE)))
        simplify(t)
        # canonical: Stream{13,256} over Dense{100}
        assert isinstance(t.data, StreamData)
        assert (t.data.count, t.data.stride) == (13, 256)
        assert isinstance(t.child.data, DenseData)
        assert t.child.data.extent == 100
        # direct rewrite API also works on fresh trees
        t2 = translate(Vector(5, 1, 1, Contiguous(2, BYTE)))
        assert stream_elision(t2) or dense_folding(t2)

    def test_full_subsize_folds_away(self):
        # subsizes == sizes in the two inner dims -> contiguous planes fold
        sb = strided_block_of(Subarray((8, 4, 5), (8, 4, 2), (0, 0, 0), BYTE))
        assert sb.counts == (64,) and sb.strides == (1,)

    def test_count_one_root_elided(self):
        sb = strided_block_of(Vector(1, 3, 5, BYTE))
        assert sb.counts == (3,) and sb.strides == (1,) and sb.start == 0

    def test_elision_keeps_offset(self):
        # Subarray dim with subsize 1 and a nonzero start must keep its
        # offset when elided (our documented fix to Alg. 3).
        sb = strided_block_of(Subarray((8, 4, 5), (2, 1, 3), (0, 2, 1), BYTE))
        # elided middle dim contributes offset 2*8=16; outer start 1*32=32
        assert sb.start == 48
        assert sb.counts == (2, 3) and sb.strides == (1, 32)


class TestFig2Equivalence:
    """The paper's core claim: equivalent constructions canonicalize to the
    same compact representation."""

    ALLOC = (256, 512, 1024)
    EXT = (100, 13, 47)

    def test_three_constructions_identical(self):
        a = make_cuboid_subarray(self.ALLOC, self.EXT)
        b = make_cuboid_hvector(self.ALLOC, self.EXT)
        c = make_cuboid_vector_of_hvector(self.ALLOC, self.EXT)
        sa, sb_, sc = map(strided_block_of, (a, b, c))
        assert sa == sb_ == sc
        assert sa.counts == (100, 13, 47)
        assert sa.strides == (1, 256, 131072)
        assert sa.start == 0

    def test_float_vs_byte_description(self):
        ae = (64, 32, 16)
        ee = (16, 8, 4)
        by = Subarray(ae, ee, (0, 0, 0), BYTE)
        fl = Subarray(
            (ae[0] // 4, ae[1], ae[2]), (ee[0] // 4, ee[1], ee[2]), (0, 0, 0), FLOAT
        )
        assert strided_block_of(by) == strided_block_of(fl)

    def test_row_equivalences(self):
        E0, A0 = 96, 256
        rows = [
            Contiguous(E0, BYTE),
            Contiguous(E0 // 4, FLOAT),
            Vector(1, E0, E0, BYTE),
            Vector(E0 // 4, 1, 1, FLOAT),
            Hvector(E0, 1, 1, BYTE),
            Subarray((A0,), (E0,), (0,), BYTE),
        ]
        blocks = {strided_block_of(r) for r in rows}
        assert len(blocks) == 1
        (sb,) = blocks
        assert sb.counts == (E0,)


class TestWordSelection:
    def test_float_aligned(self):
        sb = strided_block_of(Vector(13, 25, 64, FLOAT))
        assert sb.word_bytes() == 4

    def test_byte_misaligned(self):
        sb = strided_block_of(Subarray((256,), (3,), (1,), BYTE))
        assert sb.word_bytes() == 1

    def test_eight_byte(self):
        sb = strided_block_of(Vector(4, 2, 4, Contiguous(2, INT32)))
        # blocks of 16B at stride 32B
        assert sb.counts[0] == 16 and sb.word_bytes() == 8
