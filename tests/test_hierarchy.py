"""Two-level hierarchy tests: topology maps, per-link-class tables
(STORE_FORMAT 5), tier-aware pricing with the inter == intra oracle,
the tiered coalesced transport, simulated-scale pricing toward the
3072-process regime, and elastic re-planning of topology-keyed pins.
"""

import dataclasses
import json

import pytest

from repro.comm import (
    PerfModel,
    SystemParams,
    Topology,
    WIRE_SCHEDULES,
    build_scale_plan,
    classify_and_coalesce,
    plan_wire,
    reschedule,
    scale_ladder,
    synthetic_two_tier,
)
from repro.measure import (
    COMPATIBLE_FORMATS,
    Decision,
    DecisionCache,
    ParamsStore,
    STORE_FORMAT,
    load_ci_params,
)
from tests._subproc import run_with_devices

# ===========================================================================
# shared geometry: 8 ranks, 4 per node (ranks 0-3 node 0, 4-7 node 1)
# ===========================================================================

TOPO84 = Topology.blocked(8, 4)


def _xor1(n):
    """Swap within on-node pairs — every edge stays intra."""
    return tuple((r, r ^ 1) for r in range(n))


def _shift(n, k):
    return tuple((r, (r + k) % n) for r in range(n))


def _shift_xor(n, k):
    """Shift then pair-swap: same destination-NODE vector as the plain
    shift, different destination ranks — the bundle condition."""
    return tuple((r, ((r + k) % n) ^ 1) for r in range(n))


#: three delta classes on TOPO84: intra, inter, inter (same node vector
#: as the other inter class -> they coalesce into one tier bundle)
PERMS_TIER = (_xor1(8), _shift(8, 4), _shift_xor(8, 4))
SIZES_TIER = (8, 12, 16)


def _topo_plan():
    return plan_wire(SIZES_TIER, PERMS_TIER, native=False, topology=TOPO84)


def _flat_plan():
    return plan_wire(SIZES_TIER, PERMS_TIER, native=False)


# ===========================================================================
# Topology: the rank -> node map
# ===========================================================================

class TestTopology:
    def test_flat_is_single_node(self):
        t = Topology.flat(6)
        assert t.nranks == 6 and t.nnodes == 1
        assert all(
            t.link_class(a, b) == "intra" for a in range(6) for b in range(6)
        )

    def test_blocked_partitions_contiguously(self):
        t = Topology.blocked(8, 4)
        assert t.nodes == (0, 0, 0, 0, 1, 1, 1, 1)
        assert t.nnodes == 2
        assert t.link_class(0, 3) == "intra"
        assert t.link_class(3, 4) == "inter"

    def test_validation(self):
        with pytest.raises(ValueError):
            Topology.blocked(8, 0)
        with pytest.raises(ValueError):
            Topology(nodes=())

    def test_fingerprint_content_keyed(self):
        assert Topology.blocked(8, 4).fingerprint == TOPO84.fingerprint
        assert Topology.blocked(8, 2).fingerprint != TOPO84.fingerprint
        assert Topology.flat(8).fingerprint != TOPO84.fingerprint

    def test_classify_intra_only_has_no_bundles(self):
        dsts = (tuple(r ^ 1 for r in range(8)),)
        classes, bundles = classify_and_coalesce(dsts, TOPO84)
        assert classes == ("intra",)
        assert bundles == ()

    def test_classify_bundles_by_node_vector(self):
        # inter, intra, inter — the two inter classes target the same
        # peer node from every rank, so they ride one bundle (in
        # first-appearance order)
        dsts = (
            tuple((r + 4) % 8 for r in range(8)),
            tuple(r ^ 1 for r in range(8)),
            tuple(((r + 4) % 8) ^ 1 for r in range(8)),
        )
        classes, bundles = classify_and_coalesce(dsts, TOPO84)
        assert classes == ("inter", "intra", "inter")
        assert bundles == ((0, 2),)

    def test_any_crossing_edge_makes_the_class_inter(self):
        # a +1 ring shift stays on-node for most ranks but crosses at
        # the block boundaries — the bulk-synchronous collective
        # completes at its slowest edge, so the class is inter
        dsts = (tuple((r + 1) % 8 for r in range(8)),)
        classes, _ = classify_and_coalesce(dsts, TOPO84)
        assert classes == ("inter",)

    def test_wrong_length_destination_vector_raises(self):
        with pytest.raises(ValueError):
            classify_and_coalesce(((0, 1, 2, 3),), TOPO84)


# ===========================================================================
# STORE_FORMAT 5: per-link-class wire tables persist and round-trip
# ===========================================================================

class TestStoreFormat5:
    def test_format_constants(self):
        # format 6 added the compress_table sweep; 5 (this PR's link
        # tables) stays loadable
        assert STORE_FORMAT == 6
        assert set(COMPATIBLE_FORMATS) == {2, 3, 4, 5, 6}

    def test_link_tables_roundtrip_params_json(self):
        p = synthetic_two_tier(load_ci_params())
        assert p.link_tables and set(p.link_tables) == {"intra", "inter"}
        p2 = SystemParams.from_json(p.to_json())
        assert p2.link_tables == p.link_tables
        assert p2.link_fits == p.link_fits

    def test_link_tables_roundtrip_store(self, tmp_path):
        p = synthetic_two_tier(load_ci_params())
        store = ParamsStore(tmp_path)
        store.save(p, system="sysA")
        p2 = store.load(system="sysA")
        assert p2 is not None
        assert p2.link_tables == p.link_tables
        assert p2.link_fits == p.link_fits

    def test_older_envelope_loads_as_intra_only(self, tmp_path):
        # a format-4 (pre-hierarchy) envelope has no link tables: it
        # must still load, and the model then prices every class intra
        p = synthetic_two_tier(load_ci_params())
        store = ParamsStore(tmp_path)
        path = store.save(p, system="sysB")
        env = json.loads(path.read_text())
        env["format"] = 4
        del env["params"]["link_tables"]
        del env["params"]["link_fits"]
        path.write_text(json.dumps(env))
        p2 = store.load(system="sysB")
        assert p2 is not None and p2.link_tables is None
        model = PerfModel(p2)
        a = model.t_link(4096, 1, link_class="intra")
        b = model.t_link(4096, 1, link_class="inter")
        assert a == b

    def test_synthetic_two_tier_degrades_inter(self):
        p = synthetic_two_tier(load_ci_params())
        intra = dict(p.link_tables["intra"])
        inter = dict(p.link_tables["inter"])
        assert set(intra) == set(inter)
        assert all(inter[x] > intra[x] for x in intra)

    def test_synthetic_two_tier_unit_factors_are_identity(self):
        p = synthetic_two_tier(
            load_ci_params(), latency_factor=1.0, bandwidth_factor=1.0
        )
        assert p.link_tables["inter"] == p.link_tables["intra"]


# ===========================================================================
# tier-aware pricing: the inter == intra oracle, and the coalescing win
# ===========================================================================

class TestTierPricing:
    def test_inter_equals_intra_reproduces_flat_prices_bitwise(self):
        # with equal tier tables every surcharge is exactly 0.0, so the
        # topology-annotated plan prices bit-identically to the flat
        # plan on every shared schedule and selects the same winner
        eq = PerfModel(
            synthetic_two_tier(
                load_ci_params(), latency_factor=1.0, bandwidth_factor=1.0
            )
        )
        flat_costs = eq.price_wire_schedules(_flat_plan(), native=False)
        topo_costs = eq.price_wire_schedules(_topo_plan(), native=False)
        for s, c in flat_costs.items():
            assert topo_costs[s] == c, s
        assert set(topo_costs) == set(flat_costs) | {"tiered"}
        # coalescing must WIN, not draw, to buy its correction hops
        assert topo_costs["tiered"] >= topo_costs["grouped"]
        assert min(topo_costs.values()) == min(flat_costs.values())
        best_flat = min(flat_costs, key=flat_costs.get)
        best_topo = min(topo_costs, key=topo_costs.get)
        assert best_topo == best_flat

    def test_flat_plan_ignores_link_tables(self):
        # a plan laid out without a topology prices identically whether
        # or not the params carry link tables (pre-hierarchy behaviour)
        base = PerfModel(load_ci_params())
        two = PerfModel(synthetic_two_tier(load_ci_params()))
        plan = _flat_plan()
        assert base.price_wire_schedules(plan, native=False) == \
            two.price_wire_schedules(plan, native=False)

    def test_slow_inter_makes_coalescing_win(self):
        # one slow-tier latency for the 2-member bundle beats two: the
        # tiered schedule undercuts grouped despite its correction hop
        slow = PerfModel(synthetic_two_tier(load_ci_params()))
        costs = slow.price_wire_schedules(_topo_plan(), native=False)
        assert costs["tiered"] < costs["grouped"]
        plan2, costs2 = slow.choose_wire_schedule(_topo_plan(), native=False)
        assert costs2 == costs
        assert plan2.schedule == min(costs, key=costs.get)


# ===========================================================================
# WirePlan: the tiered schedule's layout and accounting
# ===========================================================================

class TestWirePlanTiered:
    def test_topology_annotation(self):
        plan = _topo_plan()
        assert plan.link_classes == ("intra", "inter", "inter")
        assert plan.tier_bundles == ((1, 2),)
        assert plan.topology is TOPO84

    def test_tiered_accounting(self):
        plan = _topo_plan()
        tiered = reschedule(plan, "tiered")
        # one ppermute per intra class + one per bundle + one correction
        # per non-representative member == ngroups, same as grouped
        assert tiered.wire_ops == tiered.ngroups == 3
        assert tiered.correction_bytes == SIZES_TIER[2]
        assert tiered.issued_bytes == plan.wire_bytes + SIZES_TIER[2]
        assert plan.inter_messages == 2        # grouped: one per class
        assert tiered.inter_messages == 1      # tiered: one per bundle

    def test_fingerprint_keys_topology_and_schedule(self):
        flat, topo = _flat_plan(), _topo_plan()
        assert flat.fingerprint != topo.fingerprint
        tiered = reschedule(topo, "tiered")
        assert tiered.fingerprint != topo.fingerprint

    def test_tiered_requires_annotation(self):
        with pytest.raises(ValueError, match="topology-annotated"):
            reschedule(_flat_plan(), "tiered")

    def test_mismatched_topology_plans_flat(self):
        # a single-host test mesh planned against a production topology:
        # the annotation is dropped, not misapplied
        plan = plan_wire(
            SIZES_TIER, PERMS_TIER, native=False,
            topology=Topology.blocked(16, 4),
        )
        assert plan.link_classes is None
        assert plan.topology is None
        assert plan.tier_bundles == ()

    def test_tiered_in_schedule_set(self):
        assert WIRE_SCHEDULES == (
            "ragged", "uniform", "grouped", "tiered", "varlen"
        )


# ===========================================================================
# simulated-scale pricing: the 3072-process regime on measured tables
# ===========================================================================

class TestAtScale:
    def test_cost_monotone_in_ranks_on_ci_params(self):
        # the satellite oracle: predicted exchange cost is non-decreasing
        # in rank count on the checked-in CI tables
        model = PerfModel(load_ci_params())
        ladder = scale_ladder(
            model, (8, 16, 64, 256, 1024, 3072), 8, pin=False
        )
        best = [min(e.costs.values()) for e in ladder]
        assert all(b >= a - 1e-15 for a, b in zip(best, best[1:]))

    def test_flip_to_tiered_at_scale_and_pinning(self):
        dc = DecisionCache()
        model = PerfModel(synthetic_two_tier(load_ci_params()), decisions=dc)
        est = model.at_scale(3072, ranks_per_node=8)
        assert est.schedule == "tiered"
        assert not est.pinned
        assert est.costs["tiered"] <= est.costs["grouped"]
        assert est.inter_messages["tiered"] < est.inter_messages["grouped"]
        assert est.correction_bytes > 0
        # the decision is topology-keyed: the pin carries the rank->node
        # map's fingerprint in its signature
        rows = [d for d in dc.log if d.strategy == "wire/tiered"]
        assert rows and "topo=" in rows[0].signature
        # second pricing replays the pin
        again = model.at_scale(3072, ranks_per_node=8)
        assert again.pinned and again.schedule == "tiered"
        assert again.fingerprint == est.fingerprint

    def test_single_node_never_tiers(self):
        model = PerfModel(synthetic_two_tier(load_ci_params()))
        est = model.at_scale(8, ranks_per_node=8)
        assert est.nodes == 1
        assert est.schedule != "tiered"
        assert "tiered" not in est.costs

    def test_build_scale_plan_geometry(self):
        plan = build_scale_plan(3072, 8)
        assert plan.nranks == 3072
        assert plan.topology.nnodes == 384
        assert plan.grid[0] == 384
        # leading-axis classes cross nodes and coalesce per peer node
        assert "inter" in plan.link_classes
        assert plan.tier_bundles
        assert plan.correction_bytes > 0

    def test_build_scale_plan_validation(self):
        with pytest.raises(ValueError):
            build_scale_plan(10, 8)
        with pytest.raises(ValueError):
            build_scale_plan(0, 8)


# ===========================================================================
# elastic re-planning: topology-keyed pins are demoted on reshape
# ===========================================================================

def _decision(strategy, fingerprint, signature=""):
    return Decision(
        fingerprint=fingerprint, incount=1, hops=1, allow_bounding=True,
        strategy=strategy, t_pack=0.0, t_link=1e-5, t_unpack=0.0,
        signature=signature,
    )


class TestReplanOnRemesh:
    def _comm(self, dc, topology=None):
        from types import SimpleNamespace

        model = PerfModel(
            synthetic_two_tier(load_ci_params()), decisions=dc,
            topology=topology,
        )
        return SimpleNamespace(model=model)

    def test_reshape_prunes_stale_topology_pins(self):
        from repro.train.elastic import replan_on_remesh

        old = Topology.blocked(8, 4)
        new = Topology.blocked(4, 4)
        dc = DecisionCache([
            _decision("wire/tiered", "fp1", f"... topo={old.fingerprint}"),
            _decision("program/s=2", "fp2", "grid=(2,2,2)"),  # untagged
            _decision("overlap/mode=region", "fp3", ""),
            _decision("xla", "fp4", "contig"),  # topology-insensitive
            _decision("wire/grouped", "fp5", f"... topo={new.fingerprint}"),
        ])
        comm = self._comm(dc, topology=old)
        report = replan_on_remesh(comm, new)
        assert report.old_topology == old.fingerprint
        assert report.new_topology == new.fingerprint
        assert report.cache_cleared
        pruned = set(report.pruned)
        assert pruned == {
            "wire/tiered@fp1", "program/s=2@fp2", "overlap/mode=region@fp3",
        }
        kept = {d.fingerprint for d in dc.log}
        assert kept == {"fp4", "fp5"}
        assert comm.model.topology is new

    def test_same_topology_is_a_noop(self):
        from repro.train.elastic import replan_on_remesh

        topo = Topology.blocked(8, 4)
        dc = DecisionCache([
            _decision("wire/tiered", "fp1", f"topo={topo.fingerprint}"),
        ])
        comm = self._comm(dc, topology=topo)
        report = replan_on_remesh(comm, Topology.blocked(8, 4))
        assert report.npruned == 0
        assert len(dc.log) == 1

    def test_remesh_and_replan_repins_fresh(self):
        from repro.train.elastic import ElasticPolicy, replan_on_remesh

        dc = DecisionCache()
        comm = self._comm(dc, topology=Topology.blocked(8, 4))
        est = comm.model.at_scale(3072, ranks_per_node=8)
        assert comm.model.at_scale(3072, ranks_per_node=8).pinned

        policy = ElasticPolicy(model_parallel=4, global_batch=64)
        mesh, report = policy.remesh_and_replan(
            16, comm, ranks_per_node=4
        )
        assert mesh.shape == (4, 4)
        assert report.npruned >= 1
        assert comm.model.topology.nranks == 16
        # the stale 3072-rank pin is gone: pricing again is a fresh
        # (unpinned) decision, not a replay
        redo = comm.model.at_scale(3072, ranks_per_node=8)
        assert not redo.pinned
        assert redo.fingerprint == est.fingerprint


# ===========================================================================
# overlap drift: measured per-mode timings audit overlap/mode= pins
# ===========================================================================

class TestOverlapDrift:
    def _cache(self):
        return DecisionCache([
            _decision("overlap/mode=region", "fpo", "overlap trade"),
        ])

    def test_out_of_band_mode_is_flagged(self):
        from repro.fleet import DriftDetector

        dc = self._cache()
        report = DriftDetector().audit(
            dc, load_ci_params(), system="t",
            overlap_timings={
                "fpo": {"off": 5.0, "monolithic": 2.97, "region": 4.0}
            },
        )
        (f,) = [x for x in report.findings if x.fingerprint == "fpo"]
        assert f.drifted
        assert f.term == "overlap"
        assert f.source == "telemetry"
        assert f.ratio == pytest.approx(4.0 / 2.97)
        assert f.observed_ratio == pytest.approx(4.0 / 2.97)

    def test_in_band_mode_is_not_flagged(self):
        from repro.fleet import DriftDetector

        report = DriftDetector().audit(
            self._cache(), load_ci_params(), system="t",
            overlap_timings={
                "fpo": {"off": 5.0, "monolithic": 2.9, "region": 3.0}
            },
        )
        (f,) = [x for x in report.findings if x.fingerprint == "fpo"]
        assert not f.drifted
        assert f.term == ""

    def test_off_is_baseline_not_alternative(self):
        from repro.fleet import DriftDetector

        # "off" being much faster must NOT flag the pin: it is the
        # no-overlap baseline, not an alternative overlap schedule
        report = DriftDetector().audit(
            self._cache(), load_ci_params(), system="t",
            overlap_timings={"fpo": {"off": 1.0, "region": 4.0}},
        )
        (f,) = [x for x in report.findings if x.fingerprint == "fpo"]
        assert not f.drifted

    def test_demote_stale_modes_prunes_the_pin(self):
        from repro.fleet import DriftDetector, demote_stale_modes

        dc = self._cache()
        report = DriftDetector().audit(
            dc, load_ci_params(), system="t",
            overlap_timings={"fpo": {"monolithic": 1.0, "region": 4.0}},
        )
        demoted = demote_stale_modes(dc, report)
        assert demoted == ["overlap/mode=region@fpo"]
        assert dc.lookup("fpo", 1, 1, True) is None
        assert len(dc.log) == 0


class TestDecisionPrune:
    def test_prune_returns_dropped_and_rebuilds_index(self):
        dc = DecisionCache([
            _decision("wire/grouped", "a"),
            _decision("xla", "b"),
        ])
        dropped = dc.prune(lambda d: d.strategy.startswith("wire/"))
        assert [d.fingerprint for d in dropped] == ["a"]
        assert dc.lookup("a", 1, 1, True) is None
        assert dc.lookup("b", 1, 1, True) is not None
        assert len(dc.log) == 1

    def test_prune_nothing_is_harmless(self):
        dc = DecisionCache([_decision("xla", "b")])
        assert dc.prune(lambda d: False) == []
        assert len(dc.log) == 1


# ===========================================================================
# provenance: bundles and program fingerprints carry the topology
# ===========================================================================

class TestBundleTopology:
    def test_topology_roundtrips(self):
        from repro.fleet import DecisionBundle

        b = DecisionBundle(
            decisions=DecisionCache([_decision("xla", "a")]),
            generation=3, system="sys", topology=TOPO84.fingerprint,
        )
        b2 = DecisionBundle.from_json(b.to_json())
        assert b2.topology == TOPO84.fingerprint
        assert TOPO84.fingerprint in b.summary()

    def test_old_bundle_without_topology_loads(self):
        from repro.fleet import DecisionBundle

        d = json.loads(
            DecisionBundle(decisions=DecisionCache()).to_json()
        )
        del d["topology"]
        b = DecisionBundle.from_json(json.dumps(d))
        assert b.topology == ""

    def test_merge_carries_topology_only_when_unanimous(self):
        from repro.fleet import DecisionBundle, merge_bundles

        fp = TOPO84.fingerprint
        same = merge_bundles([
            DecisionBundle(decisions=DecisionCache(), topology=fp),
            DecisionBundle(decisions=DecisionCache(), topology=fp),
        ])
        assert same.topology == fp
        mixed = merge_bundles([
            DecisionBundle(decisions=DecisionCache(), topology=fp),
            DecisionBundle(decisions=DecisionCache(), topology="other"),
        ])
        assert mixed.topology == ""


class TestProgramTopologyKey:
    def test_topology_fingerprint_keys_program_decisions(self):
        from repro.halo import StencilOp, program_fingerprint
        from repro.core.datatypes import FLOAT

        op = StencilOp(radii=(1, 1, 1))
        base = program_fingerprint((2, 2, 2), (8, 8, 8), op, FLOAT)
        topo = program_fingerprint(
            (2, 2, 2), (8, 8, 8), op, FLOAT,
            topology_fingerprint=TOPO84.fingerprint,
        )
        assert base != topo
        # empty fingerprint preserves every pre-hierarchy key
        again = program_fingerprint(
            (2, 2, 2), (8, 8, 8), op, FLOAT, topology_fingerprint=""
        )
        assert again == base


# ===========================================================================
# the tiered transport is bit-exact (subprocess, 8 CPU devices)
# ===========================================================================

TIERED_TRANSPORT_CODE = r"""
import dataclasses
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import (
    Communicator, FixedPolicy, Topology, collective_payload_bytes,
    reschedule,
)
from repro.halo import HaloSpec, halo_exchange, make_halo_plan

# 2x2x2 grid, 4 ranks per node: rank = z*4 + y*2 + x, node = z — every
# delta class with a leading-axis component crosses nodes, and all four
# inter classes share the destination-node vector (one tier bundle)
spec = HaloSpec(grid=(2, 2, 2), interior=(4, 4, 4), radius=1)
topo = Topology.blocked(8, 4)
R = spec.nranks
az, ay, ax = spec.alloc
nz, ny, nx = spec.interior

comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"),
                    topology=topo)
mesh = Mesh(np.array(jax.devices()), ("ranks",))
plan = make_halo_plan(spec, comm, schedule_policy="exact")
wire = plan.wire
assert wire.schedule == "grouped", wire.schedule
assert wire.link_classes is not None
assert wire.link_classes.count("inter") == 4, wire.link_classes
assert len(wire.tier_bundles) == 1 and len(wire.tier_bundles[0]) == 4
tiered_wire = reschedule(wire, "tiered")
tiered_plan = dataclasses.replace(plan, wire=tiered_wire)

gz, gy, gx = 2 * nz, 2 * ny, 2 * nx
gvals = np.arange(gz * gy * gx, dtype=np.float32).reshape(gz, gy, gx)
locals_np = np.full((R, az, ay, ax), -1.0, np.float32)
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    locals_np[rank, 1:1+nz, 1:1+ny, 1:1+nx] = gvals[
        cz*nz:(cz+1)*nz, cy*ny:(cy+1)*ny, cx*nx:(cx+1)*nx]
x0 = jnp.asarray(locals_np.reshape(R * az, ay, ax))

def runner(p):
    return jax.jit(shard_map(
        lambda x: halo_exchange(x, spec, comm, "ranks", plan=p),
        mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"),
        check_vma=False))

grouped_fn, tiered_fn = runner(plan), runner(tiered_plan)
out_g = np.asarray(grouped_fn(x0)).reshape(R, az, ay, ax)
out_t = np.asarray(tiered_fn(x0)).reshape(R, az, ay, ax)
np.testing.assert_array_equal(out_t, out_g)
print("BITEXACT_OK")

# periodic oracle: the tiered transport fills every halo cell right
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    zz = (np.arange(az) - 1 + cz * nz) % gz
    yy = (np.arange(ay) - 1 + cy * ny) % gy
    xx = (np.arange(ax) - 1 + cx * nx) % gx
    np.testing.assert_array_equal(out_t[rank], gvals[np.ix_(zz, yy, xx)],
                                  err_msg=f"rank {rank}")
print("ORACLE_OK")

# accounting: tiered re-transmits exactly correction_bytes on the fast
# tier and issues ngroups collectives, same count as grouped — the win
# is one slow-tier message instead of four
counts = collective_payload_bytes(tiered_fn, x0)
assert tiered_wire.correction_bytes > 0
want = wire.wire_bytes + tiered_wire.correction_bytes
assert counts["total"] == want == tiered_wire.issued_bytes, (counts, want)
assert counts["ops"] == tiered_wire.wire_ops == wire.ngroups
assert tiered_wire.inter_messages == 1 and wire.inter_messages == 4
print("ACCOUNTING_OK", want)
"""


@pytest.mark.slow
def test_tiered_transport_bit_exact():
    out = run_with_devices(TIERED_TRANSPORT_CODE, ndev=8)
    assert "BITEXACT_OK" in out
    assert "ORACLE_OK" in out
    assert "ACCOUNTING_OK" in out
