"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned family runs one forward/train step on CPU, asserting output
shapes + no NaNs; plus a decode step against the family's cache."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCH_IDS, get_config, smoke_config
from repro.models import build_model
from repro.models.frontends import random_frontend_batch

BATCH, SEQ = 2, 64


def make_batch(cfg: ModelConfig, key):
    kb, kf = jax.random.split(key)
    tokens = jax.random.randint(kb, (BATCH, SEQ), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    batch.update(random_frontend_batch(cfg, kf, BATCH, SEQ))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    """One full loss+grad step on the reduced config: finite loss, finite
    grads, params update."""
    from repro.train.train_step import make_loss_fn

    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss_fn = make_loss_fn(model)
    (loss, aux), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss)), f"loss={loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # sane magnitude: xent of random init ~ log(vocab)
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(BATCH, max_len=SEQ, enc_len=SEQ)
    if cfg.family == "encdec":
        enc = model.encode(
            params,
            jax.random.normal(jax.random.PRNGKey(2), (BATCH, SEQ, cfg.d_model)),
        )
        xk, xv = model.make_cross_cache(params, enc)
        cache = {**cache, "xk": xk, "xv": xv}
    tok = jnp.zeros((BATCH,), jnp.int32)

    step = jax.jit(model.decode_step)
    for t in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        assert logits.shape == (BATCH, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "h2o-danube-1.8b", "rwkv6-7b",
                                  "zamba2-2.7b", "mixtral-8x22b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode step-by-step == full forward logits (the
    serving path is consistent with the training path).  fp32 everywhere
    incl. the KV cache, so only epsilon-level divergence is allowed —
    the bf16 cache default is a deliberate serving quantization and is
    exercised by test_decode_step_smoke instead."""
    cfg = smoke_config(arch).replace(dtype="float32", kv_cache_dtype="float32")
    if cfg.family == "moe":
        # capacity-based top-k dropping is grouping-dependent by design;
        # for the train==serve consistency check give every expert full
        # capacity (cf = E/K => zero drops in both paths)
        cfg = cfg.replace(
            moe_capacity_factor=cfg.num_experts / cfg.experts_per_token
        )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (BATCH, S), 0,
                                cfg.vocab_size)
    full_logits, _ = jax.jit(model.forward)(params, {"tokens": tokens})

    cache = model.init_cache(BATCH, max_len=max(
        S, cfg.sliding_window or S))
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t], jnp.int32(t))
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), rtol=2e-4, atol=2e-4
    )
