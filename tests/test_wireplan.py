"""Tests: the exact-byte WirePlan layer (ISSUE 3).

Covers the wire planner (segment layout, schedule ladder, grid-size
fallback), the ragged pack/unpack kernel entry points, wire-byte
accounting end-to-end (traced payload == plan == PerfModel/DecisionCache
records), asymmetric halos against the per-direction ppermute reference,
the int8 compressed-wire plugin, per-axis wire tables, and the
production communicator wiring.
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.comm import (
    Communicator,
    FixedPolicy,
    INT8_WIRE,
    PerfModel,
    SystemParams,
    TPU_V5E,
    collective_payload_bytes,
    default_registry,
)
from repro.comm.api import ROWS
from repro.comm.wireplan import plan_wire
from repro.core import BYTE, FLOAT, Subarray, TypeRegistry, Vector, WireSegment
from repro.halo import HaloSpec, make_halo_plan
from repro.kernels.pack import pack_ragged
from repro.kernels.unpack import unpack_ragged
from repro.measure import DecisionCache
from tests._subproc import run_with_devices


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("x",))


def _ring(n):
    return tuple((r, (r + 1) % n) for r in range(n))


# ===========================================================================
# the planner: exact segments, schedule ladder, thresholds
# ===========================================================================

class TestPlanWire:
    def test_exact_segment_layout(self):
        n = 4
        sizes = (10, 3, 7, 5)
        perms = (_ring(n),) * 2 + (tuple((r, (r + 2) % n) for r in range(n)),) * 2
        plan = plan_wire(sizes, perms, fingerprints=("a", "b", "c", "d"),
                         native=False)
        assert plan.ngroups == 2
        assert plan.wire_bytes == sum(sizes)
        assert plan.padding_bytes == 0
        # segments tile the flat buffer exactly, in group order
        segs = sorted(plan.segments, key=lambda s: s.offset)
        assert segs[0].offset == 0
        for a, b in zip(segs, segs[1:]):
            assert a.end == b.offset
        assert segs[-1].end == plan.wire_bytes
        assert {s.fingerprint for s in plan.segments} == {"a", "b", "c", "d"}
        # group-local offsets are consistent with the global segments
        for goff, grp in zip(plan.group_offsets, plan.groups):
            for i, off in zip(grp.transfers, grp.offsets):
                assert plan.segments[i].offset == goff + off

    def test_schedule_ladder(self):
        n = 4
        sizes = (8, 8)
        perms = (_ring(n), tuple((r, (r - 1) % n) for r in range(n)))
        # native ragged collective available -> single ragged op
        ragged = plan_wire(sizes, perms, native=True)
        assert ragged.schedule == "ragged" and ragged.wire_ops == 1
        # no native op, zero tolerance, unequal-to-rank groups -> grouped
        grouped = plan_wire(sizes, perms, native=False)
        assert grouped.schedule == "grouped" and grouped.wire_ops == 2
        assert grouped.issued_bytes == grouped.wire_bytes == 16
        # tolerance admits the padded uniform collective
        uniform = plan_wire(sizes, perms, native=False,
                            uniform_waste_tolerance=float("inf"))
        assert uniform.schedule == "uniform" and uniform.wire_ops == 1
        assert uniform.issued_bytes == n * uniform.seg_bytes

    def test_grid_size_threshold(self):
        # 32 ranks, 1 delta class: fused rows would be 31/32 zeros — the
        # plan must fall back to grouped regardless of native support
        n = 32
        plan = plan_wire((64,), (_ring(n),), native=True,
                         uniform_waste_tolerance=float("inf"))
        assert plan.schedule == "grouped"

    def test_byte_exact_uniform_is_allowed(self):
        # 1 rank, self-exchange: ngroups == nranks and zero padding —
        # the single uniform collective is byte-exact and admissible
        plan = plan_wire((8, 4), (((0, 0),), ((0, 0),)), native=False)
        assert plan.schedule == "uniform"
        assert plan.padding_bytes == 0
        assert plan.issued_bytes == plan.wire_bytes == 12

    def test_validation(self):
        with pytest.raises(ValueError, match="align"):
            plan_wire((8, 8), (((0, 0),),))
        with pytest.raises(ValueError, match="not a permutation"):
            plan_wire((8,), (((0, 0), (1, 0)),))

    def test_fingerprint_stable_and_content_keyed(self):
        a = plan_wire((8, 4), (((0, 0),), ((0, 0),)), fingerprints=("x", "y"))
        b = plan_wire((8, 4), (((0, 0),), ((0, 0),)), fingerprints=("x", "y"))
        c = plan_wire((8, 5), (((0, 0),), ((0, 0),)), fingerprints=("x", "y"))
        assert a.fingerprint == b.fingerprint
        assert a.fingerprint != c.fingerprint


# ===========================================================================
# ragged kernel entry points
# ===========================================================================

class TestRaggedKernels:
    def test_pack_unpack_ragged_roundtrip(self):
        rng = np.random.default_rng(5)
        buf = jnp.asarray(rng.integers(0, 255, (64,), dtype=np.uint8))
        leaves = [
            (0, lambda b: jax.lax.dynamic_slice(b, (0,), (8,))),
            (8, lambda b: jax.lax.dynamic_slice(b, (16,), (4,))),
            (12, lambda b: jax.lax.dynamic_slice(b, (32,), (3,))),
        ]
        wire = pack_ragged(buf, leaves, 15)
        assert wire.shape == (15,)
        w = np.asarray(wire)
        np.testing.assert_array_equal(w[0:8], np.asarray(buf)[0:8])
        np.testing.assert_array_equal(w[8:12], np.asarray(buf)[16:20])
        np.testing.assert_array_equal(w[12:15], np.asarray(buf)[32:35])

        def put(at):
            return lambda dst, part: jax.lax.dynamic_update_slice(
                dst, part, (at,)
            )

        dst = unpack_ragged(jnp.zeros((64,), jnp.uint8), wire,
                            [(0, 8, put(40)), (8, 4, put(50)), (12, 3, put(60))])
        d = np.asarray(dst)
        np.testing.assert_array_equal(d[40:48], np.asarray(buf)[0:8])
        np.testing.assert_array_equal(d[50:54], np.asarray(buf)[16:20])
        np.testing.assert_array_equal(d[60:63], np.asarray(buf)[32:35])


# ===========================================================================
# wire-byte accounting: traced payload == plan == model/decision records
# ===========================================================================

class TestWireAccounting:
    def test_neighbor_accounting_and_decision_record(self):
        dc = DecisionCache()
        comm = Communicator(axis_name="x", decisions=dc)
        send_cts = [
            comm.commit(Subarray((64,), (8,), (0,), BYTE)),
            comm.commit(Subarray((64,), (4,), (16,), BYTE)),
        ]
        recv_cts = [
            comm.commit(Subarray((64,), (8,), (32,), BYTE)),
            comm.commit(Subarray((64,), (4,), (48,), BYTE)),
        ]
        perms = [[(0, 0)], [(0, 0)]]
        strats, plan = comm.plan_neighbor(send_cts, perms)
        assert plan.wire_bytes == 12

        def body(b):
            return comm.neighbor_alltoallv(
                b, send_cts, recv_cts, perms, plan=plan, strategies=strats
            )

        fn = jax.jit(shard_map(body, mesh=_mesh1(), in_specs=P(),
                               out_specs=P(), check_vma=False))
        before_ops, before_bytes = comm.wire_ops, comm.wire_payload_bytes
        fn(jnp.arange(64, dtype=jnp.uint8))
        assert comm.wire_ops - before_ops == plan.wire_ops
        assert comm.wire_payload_bytes - before_bytes == plan.issued_bytes
        # the traced program moves exactly the plan's bytes
        counts = collective_payload_bytes(fn, jnp.arange(64, dtype=jnp.uint8))
        assert counts["total"] == plan.issued_bytes == plan.wire_bytes
        # ...and the decision cache recorded that same byte count
        rows = [d for d in dc.log if d.fingerprint == plan.fingerprint]
        assert len(rows) == 1
        assert rows[0].wire_bytes == plan.wire_bytes
        assert rows[0].strategy == f"wire/{plan.schedule}"
        assert str(plan.wire_bytes) in dc.report()

    def test_caller_plan_kept_when_strategies_omitted(self):
        # a plan built with non-default knobs must not be silently
        # re-planned (at default tolerance) just because strategies
        # weren't passed alongside it
        comm = Communicator(axis_name="x")
        send_cts = [
            comm.commit(Subarray((64,), (8,), (0,), BYTE)),
            comm.commit(Subarray((64,), (4,), (16,), BYTE)),
        ]
        recv_cts = [
            comm.commit(Subarray((64,), (8,), (32,), BYTE)),
            comm.commit(Subarray((64,), (4,), (48,), BYTE)),
        ]
        perms = [[(0, 0)], [(0, 0)]]
        sizes = tuple(ct.packed_extent() for ct in send_cts)
        custom = plan_wire(sizes, (((0, 0),), ((0, 0),)), native=False,
                           uniform_waste_tolerance=float("inf"))

        def body(b):
            return comm.neighbor_alltoallv(
                b, send_cts, recv_cts, perms, plan=custom
            )

        fn = jax.jit(shard_map(body, mesh=_mesh1(), in_specs=P(),
                               out_specs=P(), check_vma=False))
        buf = jnp.arange(64, dtype=jnp.uint8)
        out = np.asarray(fn(buf))
        want = np.arange(64, dtype=np.uint8)
        want[32:40] = want[0:8]
        want[48:52] = want[16:20]
        np.testing.assert_array_equal(out, want)
        counts = collective_payload_bytes(fn, buf)
        assert counts["ops"] == custom.wire_ops  # the caller's schedule ran
        assert counts["total"] == custom.issued_bytes
        # a plan for a different transfer count is rejected loudly
        with pytest.raises(ValueError, match="wire plan describes"):
            comm.ineighbor_alltoallv(buf, send_cts[:1], recv_cts[:1],
                                     perms[:1], plan=custom)

    def test_exchange_recorded_once_per_plan(self):
        dc = DecisionCache()
        comm = Communicator(axis_name="x", decisions=dc)
        ct = comm.commit(Subarray((64,), (8,), (0,), BYTE))
        for _ in range(3):
            comm.plan_neighbor([ct], [[(0, 0)]])
        rows = [d for d in dc.log if d.strategy.startswith("wire/")]
        assert len(rows) == 1

    def test_per_type_decisions_carry_wire_bytes(self):
        dc = DecisionCache()
        model = PerfModel(TPU_V5E, decisions=dc)
        ct = TypeRegistry().commit(Vector(16, 64, 512, BYTE))
        est = model.select(ct)
        assert est.wire_bytes > 0
        assert dc.log[0].wire_bytes == est.wire_bytes

    def test_isend_accounting(self):
        comm = Communicator(axis_name="x")
        ct = comm.commit(Subarray((64,), (8,), (0,), BYTE))

        def body(b):
            req = comm.isend(b, ct, [(0, 0)])
            return comm.irecv(b, ct, req).wait()

        fn = jax.jit(shard_map(body, mesh=_mesh1(), in_specs=P(),
                               out_specs=P(), check_vma=False))
        buf = jnp.arange(64, dtype=jnp.uint8)
        fn(buf)
        counts = collective_payload_bytes(fn, buf)
        s = comm.select(ct, 1, wire=True)
        assert counts["total"] == s.wire_bytes(ct)


# ===========================================================================
# strategy wire segments
# ===========================================================================

class TestWireSegments:
    def test_packed_extent_and_segment(self):
        ct = TypeRegistry().commit(Vector(4, 8, 16, BYTE))
        assert ct.packed_extent() == 32
        assert ct.packed_extent(3) == 96
        seg = ct.wire_segment(offset=7)
        assert seg == WireSegment(ct.fingerprint, 7, 32)
        assert seg.end == 39

    def test_strategy_segments_differ_from_packed_size(self):
        reg = TypeRegistry()
        ct = reg.commit(Vector(4, 8, 64, BYTE))     # sparse in its extent
        rows_seg = ROWS.wire_segment(ct)
        assert rows_seg.nbytes == ct.size == 32
        from repro.comm.api import BOUNDING

        bseg = BOUNDING.wire_segment(ct)
        assert bseg.nbytes == ct.block.extent      # the window, not the data
        assert bseg.nbytes != ct.size
        iseg = INT8_WIRE.wire_segment(ct)
        assert iseg.nbytes == 4 + ct.size // 4     # compressed + header
        assert iseg.fingerprint == ct.fingerprint


# ===========================================================================
# int8 compressed-wire plugin
# ===========================================================================

class TestInt8Wire:
    def test_registered_but_never_auto_selected(self):
        reg = default_registry()
        assert INT8_WIRE.name in reg
        assert INT8_WIRE not in reg.selectable()
        assert INT8_WIRE not in reg.measurable()

    def test_sendrecv_roundtrip_within_quantization_error(self):
        comm = Communicator(axis_name="x", policy=FixedPolicy(INT8_WIRE.name))
        # a strided float32 region (Subarray dims innermost-first):
        # 8 rows x 4 floats starting at column 2 of a (16, 16) array
        dt = Subarray((16, 16), (4, 8), (2, 0), FLOAT)
        ct = comm.commit(dt)
        assert INT8_WIRE.applicable(ct)
        rng = np.random.default_rng(0)
        src = rng.normal(size=(16, 16)).astype(np.float32)

        def body(b):
            return comm.sendrecv(b, jnp.zeros_like(b), ct, [(0, 0)])

        fn = jax.jit(shard_map(body, mesh=_mesh1(), in_specs=P(),
                               out_specs=P(), check_vma=False))
        out = np.asarray(fn(jnp.asarray(src)))
        region = np.s_[0:8, 2:6]
        scale = np.abs(src[region]).max() / 127.0
        np.testing.assert_allclose(out[region], src[region],
                                   atol=scale / 2 + 1e-7)
        # untouched cells stay zero
        mask = np.ones_like(src, dtype=bool)
        mask[region] = False
        assert (out[mask] == 0).all()

    def test_wire_plan_accounts_compressed_bytes(self):
        comm = Communicator(axis_name="x", policy=FixedPolicy(INT8_WIRE.name))
        ct = comm.commit(Subarray((16, 16), (4, 8), (2, 0), FLOAT))
        strats, plan = comm.plan_neighbor([ct], [[(0, 0)]])
        assert strats[0] is INT8_WIRE
        want = 4 + ct.size // 4
        assert plan.wire_bytes == want != ct.size

        def body(b):
            return comm.neighbor_alltoallv(b, [ct], [ct], [[(0, 0)]],
                                           plan=plan, strategies=strats)

        fn = jax.jit(shard_map(body, mesh=_mesh1(), in_specs=P(),
                               out_specs=P(), check_vma=False))
        x = jnp.zeros((16, 16), jnp.float32)
        counts = collective_payload_bytes(fn, x)
        assert counts["total"] == plan.issued_bytes
        assert plan.issued_bytes == want  # wire_bytes != ct.size, exactly

    def test_estimate_prices_compressed_link(self):
        model = PerfModel(TPU_V5E)
        ct = TypeRegistry().commit(Subarray((64, 64), (16, 32), (8, 0), FLOAT))
        est = model.estimate(ct, 1, INT8_WIRE.name)
        full = model.estimate(ct, 1, "rows")
        assert est.wire_bytes < full.wire_bytes
        assert est.t_link < full.t_link


# ===========================================================================
# asymmetric halos (unequal radii) vs the per-direction ppermute reference
# ===========================================================================

ASYM_HALO_CODE = r"""
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map
from repro.comm import Communicator, FixedPolicy, collective_payload_bytes
from repro.halo import HaloSpec, halo_exchange, make_halo_plan
from repro.halo.exchange import DIRECTIONS

spec = HaloSpec(grid=(2, 2, 2), interior=(6, 5, 4), radius=(2, 1, 1))
rz, ry, rx = spec.radii
nz, ny, nx = spec.interior
az, ay, ax = spec.alloc
R = spec.nranks
assert (az, ay, ax) == (10, 7, 6)

gz, gy, gx = 2 * nz, 2 * ny, 2 * nx
gvals = np.arange(gz * gy * gx, dtype=np.float32).reshape(gz, gy, gx)
locals_np = np.full((R, az, ay, ax), -1.0, np.float32)
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    locals_np[rank, rz:rz+nz, ry:ry+ny, rx:rx+nx] = gvals[
        cz*nz:(cz+1)*nz, cy*ny:(cy+1)*ny, cx*nx:(cx+1)*nx]
x0 = jnp.asarray(locals_np.reshape(R * az, ay, ax))

comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
mesh = Mesh(np.array(jax.devices()), ("ranks",))
plan = make_halo_plan(spec, comm, schedule_policy="exact")

fused = jax.jit(shard_map(
    lambda x: halo_exchange(x, spec, comm, "ranks", plan=plan),
    mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"), check_vma=False))

# reference: 26 independent sendrecv ppermutes, one per direction
ref_types = {d: (plan.send_cts[i], plan.recv_cts[i])
             for i, d in enumerate(DIRECTIONS)}
def reference(local):
    for d in DIRECTIONS:
        s, r = ref_types[d]
        local = comm.sendrecv(local, local, s, spec.perm(d), "ranks", r)
    return local
ref = jax.jit(shard_map(reference, mesh=mesh, in_specs=P("ranks"),
                        out_specs=P("ranks"), check_vma=False))

out_f = np.asarray(fused(x0)).reshape(R, az, ay, ax)
out_r = np.asarray(ref(x0)).reshape(R, az, ay, ax)
np.testing.assert_array_equal(out_f, out_r)
print("BITEXACT_OK")

# periodic oracle with per-dimension radii
for rank in range(R):
    cz, cy, cx = spec.coords(rank)
    zz = (np.arange(az) - rz + cz * nz) % gz
    yy = (np.arange(ay) - ry + cy * ny) % gy
    xx = (np.arange(ax) - rx + cx * nx) % gx
    np.testing.assert_array_equal(out_f[rank], gvals[np.ix_(zz, yy, xx)],
                                  err_msg=f"rank {rank}")
print("ORACLE_OK")

# wire accounting: the fused path transfers exactly the sum of the
# per-peer packed extents — no padding anywhere, despite the unequal
# per-dimension radii making every class a different size
counts = collective_payload_bytes(fused, x0)
want = sum(ct.packed_extent() for ct in plan.send_cts)
assert plan.wire_bytes == want, (plan.wire_bytes, want)
assert counts["total"] == want, (counts, want)
assert counts["ops"] == plan.wire.wire_ops == plan.wire.ngroups
print("WIREBYTES_OK", want)
"""


@pytest.mark.slow
def test_asymmetric_halo_bit_exact_and_ragged():
    out = run_with_devices(ASYM_HALO_CODE, ndev=8)
    assert "BITEXACT_OK" in out
    assert "ORACLE_OK" in out
    assert "WIREBYTES_OK" in out


class TestHaloSpecRadii:
    def test_scalar_radius_broadcasts(self):
        spec = HaloSpec(grid=(1, 1, 1), interior=(4, 4, 4), radius=2)
        assert spec.radii == (2, 2, 2)
        assert spec.alloc == (8, 8, 8)

    def test_asymmetric_radii(self):
        # the old scalar_radius symmetry guard is gone: asymmetric specs
        # are first-class all the way into the stencil kernels
        spec = HaloSpec(grid=(1, 1, 1), interior=(6, 5, 4), radius=(2, 1, 1))
        assert spec.radii == (2, 1, 1)
        assert spec.alloc == (10, 7, 6)
        assert not hasattr(spec, "scalar_radius")

    def test_halo_plan_wire_bytes_property(self):
        comm = Communicator(axis_name="ranks", policy=FixedPolicy("rows"))
        spec = HaloSpec(grid=(1, 1, 1), interior=(4, 4, 4), radius=(2, 2, 1))
        plan = make_halo_plan(spec, comm)
        assert plan.wire_bytes == sum(ct.packed_extent() for ct in plan.send_cts)
        assert plan.wire.padding_bytes == 0


# ===========================================================================
# per-axis wire tables
# ===========================================================================

class TestPerAxisWire:
    def _params(self):
        return SystemParams(
            name="axes",
            wire_table=((10.0, 5e-5), (20.0, 5e-5)),
            wire_latency=1e-6,
            wire_tables={
                "ici": ((10.0, 1e-6), (20.0, 1e-6)),
                "dcn": ((10.0, 9e-4), (20.0, 9e-4)),
            },
            wire_fits={"ici": (1e-7, 5e10), "dcn": (1e-4, 1e9)},
        )

    def test_roundtrip(self):
        p = self._params()
        q = SystemParams.from_json(p.to_json())
        assert q == p
        assert q.wire_tables["dcn"][0] == (10.0, 9e-4)
        assert q.wire_fits["ici"] == (1e-7, 5e10)

    def test_t_link_prices_per_axis(self):
        model = PerfModel(self._params())
        assert model.t_link(1024, axis="ici") == pytest.approx(1e-6)
        assert model.t_link(1024, axis="dcn") == pytest.approx(9e-4)
        # unknown axis / no axis falls back to the flat table
        assert model.t_link(1024) == pytest.approx(5e-5)
        assert model.t_link(1024, axis="nope") == pytest.approx(5e-5)

    def test_extra_hops_use_axis_fit(self):
        model = PerfModel(self._params())
        base = model.t_link(1024, axis="dcn")
        assert model.t_link(1024, hops=3, axis="dcn") == pytest.approx(
            base + 2 * 1e-4
        )

    def test_model_axis_binding(self):
        model = PerfModel(self._params(), axis="dcn")
        assert model.t_link(1024) == pytest.approx(9e-4)
        comm = Communicator(axis_name="dcn", params=self._params())
        assert comm.model.axis == "dcn"

    def test_selection_can_flip_per_axis(self):
        # a dense 8-byte block inside a 64-byte Subarray extent, repeated
        # twice: bounding ships the 72-byte window with zero staging,
        # the pack strategies ship 16 exact bytes.  On a fast axis the
        # free pack wins it for bounding; on a slow, byte-steep DCN axis
        # the 4.5x over-transfer must flip the selection to a pack path.
        p = SystemParams(
            name="flip",
            wire_tables={
                "ici": ((0.0, 1e-9), (30.0, 1e-9)),
                "dcn": ((0.0, 1e-9), (4.0, 1e-9), (6.0, 6e-2), (30.0, 7e-2)),
            },
            wire_fits={"ici": (1e-9, 1e12), "dcn": (1e-9, 1e6)},
        )
        reg = TypeRegistry()
        ct = reg.commit(Subarray((64,), (8,), (0,), BYTE))
        from repro.comm.api import BOUNDING

        assert BOUNDING.wire_bytes(ct, 2) == 72 > ct.packed_extent(2) == 16
        fast = PerfModel(p, axis="ici").select(ct, incount=2).strategy
        slow = PerfModel(p, axis="dcn").select(ct, incount=2).strategy
        assert fast == "bounding"
        assert slow != "bounding"


PER_AXIS_SWEEP_CODE = r"""
from repro.measure import calibrate_params, fit_latency_bandwidth
from repro.measure.bench import REDUCED_TOTAL_BYTES, measure_wire_tables

tables = measure_wire_tables({"ici": 2, "dcn": 2},
                             total_bytes=REDUCED_TOTAL_BYTES, iters=1)
assert set(tables) == {"ici", "dcn"}
for ax, rows in tables.items():
    assert len(rows) == len(REDUCED_TOTAL_BYTES)
    assert all(sec > 0 for _, sec in rows)
params = calibrate_params(reduced=True, iters=1,
                          mesh_axes={"ici": 2, "dcn": 2})
assert set(params.wire_tables) == {"ici", "dcn"}
assert set(params.wire_fits) == {"ici", "dcn"}
from repro.comm import PerfModel
m = PerfModel(params, axis="ici")
assert m.t_link(4096) > 0
print("AXES_OK")
"""


@pytest.mark.slow
def test_per_axis_wire_sweep_on_mesh():
    out = run_with_devices(PER_AXIS_SWEEP_CODE, ndev=4)
    assert "AXES_OK" in out


# ===========================================================================
# store format compatibility
# ===========================================================================

class TestStoreFormats:
    def test_format2_envelope_still_loads(self, tmp_path):
        from repro.measure import ParamsStore
        from repro.measure.fingerprint import system_fingerprint

        from repro.measure import STORE_FORMAT

        store = ParamsStore(tmp_path)
        out = store.save(SystemParams(name="x"))
        d = json.loads(out.read_text())
        assert d["format"] == STORE_FORMAT == 6
        d["format"] = 2  # what a pre-per-axis envelope looks like
        d["params"].pop("wire_tables", None)
        d["params"].pop("wire_fits", None)
        d["params"].pop("stencil_table", None)
        d["params"].pop("link_tables", None)
        d["params"].pop("link_fits", None)
        out.write_text(json.dumps(d))
        got = store.load()
        assert got is not None and got.name == "x"
        assert got.wire_tables is None

    def test_ci_params_still_loadable(self):
        from repro.measure import load_ci_params

        params = load_ci_params()
        assert params.pack_table and params.wire_table

    def test_unknown_format_refused(self, tmp_path):
        from repro.measure import ParamsStore

        store = ParamsStore(tmp_path)
        out = store.save(SystemParams(name="x"))
        d = json.loads(out.read_text())
        d["format"] = 1
        out.write_text(json.dumps(d))
        assert store.load() is None


# ===========================================================================
# production communicator (train/serve wiring)
# ===========================================================================

class TestProductionCommunicator:
    def test_second_run_pins_decisions(self, tmp_path, monkeypatch):
        import repro.measure.store as store_mod
        from repro.measure.production import production_communicator

        monkeypatch.setattr(
            store_mod, "calibrate_params",
            lambda name=None, reduced=False: SystemParams(name="fake"),
        )
        dt = Vector(4096, 8, 4096, BYTE)

        comm1, save1 = production_communicator(tmp_path, axis_name="data")
        first = comm1.select(comm1.commit(dt)).name
        assert len(comm1.model.decisions) == 1
        save1()

        comm2, _ = production_communicator(tmp_path, axis_name="data")
        dc2 = comm2.model.decisions
        assert len(dc2) == 1  # loaded from disk, model not consulted
        assert comm2.select(comm2.commit(dt)).name == first
        assert dc2.pinned_hits >= 1

    def test_no_calibrate_falls_back_to_analytic(self, tmp_path):
        from repro.measure.production import production_communicator

        comm, _ = production_communicator(tmp_path, calibrate=False)
        assert comm.model.params.name == TPU_V5E.name

    def test_train_loop_reports_comm_stats(self, tmp_path):
        from repro.configs.base import ModelConfig
        from repro.launch.train import train

        cfg = ModelConfig(
            name="tiny", family="dense", num_layers=1, d_model=32,
            num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=128,
            remat=False,
        )
        comm = Communicator(axis_name="data")
        out = train(cfg, steps=1, seq_len=8, global_batch=2,
                    ckpt_dir=str(tmp_path / "ckpt"), comm=comm)
        assert out["comm_stats"]["wire_ops"] == comm.wire_ops
